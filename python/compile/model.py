"""GPT-style decoder LM (the paper's language-model benchmarks).

Pre-LN transformer with learned positions and an untied LM head. The six
linears per block (wq/wk/wv/wo/fc/proj) are LoGra-instrumentable; the
``logra.modules`` config selects "all" or "mlp" (the paper's Llama3 run
watches only MLP linears; its GPT2/counterfactual runs watch everything).

Loss convention follows the paper's LogIX example: per-sample loss is the
SUM of token cross-entropies over positions 0..T-2 predicting 1..T-1 (the
sequence gradient is the sum of token gradients — the outlier phenomenon
§F.2 discusses).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import nn
from .config import Config, LmModelConfig


def param_spec(m: LmModelConfig) -> nn.ParamSpec:
    e: List = [
        ("tok_emb", (m.vocab, m.d_model)),
        ("pos_emb", (m.seq_len, m.d_model)),
    ]
    for l in range(m.n_layers):
        e += [
            (f"l{l}.ln1.g", (m.d_model,)),
            (f"l{l}.ln1.b", (m.d_model,)),
            (f"l{l}.wq.w", (m.d_model, m.d_model)),
            (f"l{l}.wq.b", (m.d_model,)),
            (f"l{l}.wk.w", (m.d_model, m.d_model)),
            (f"l{l}.wk.b", (m.d_model,)),
            (f"l{l}.wv.w", (m.d_model, m.d_model)),
            (f"l{l}.wv.b", (m.d_model,)),
            (f"l{l}.wo.w", (m.d_model, m.d_model)),
            (f"l{l}.wo.b", (m.d_model,)),
            (f"l{l}.ln2.g", (m.d_model,)),
            (f"l{l}.ln2.b", (m.d_model,)),
            (f"l{l}.fc.w", (m.d_ff, m.d_model)),
            (f"l{l}.fc.b", (m.d_ff,)),
            (f"l{l}.proj.w", (m.d_model, m.d_ff)),
            (f"l{l}.proj.b", (m.d_model,)),
        ]
    e += [
        ("lnf.g", (m.d_model,)),
        ("lnf.b", (m.d_model,)),
        ("head.w", (m.vocab, m.d_model)),
        ("head.b", (m.vocab,)),
    ]
    return nn.ParamSpec(tuple(e))


def module_specs(cfg: Config) -> List[nn.ModuleSpec]:
    m = cfg.lm
    mods: List[nn.ModuleSpec] = []
    for l in range(m.n_layers):
        if cfg.logra.modules == "all":
            for name in ("wq", "wk", "wv", "wo"):
                mods.append(nn.ModuleSpec(f"l{l}.{name}", m.d_model, m.d_model))
        mods.append(nn.ModuleSpec(f"l{l}.fc", m.d_model, m.d_ff))
        mods.append(nn.ModuleSpec(f"l{l}.proj", m.d_ff, m.d_model))
    return mods


def init_params(cfg: Config, seed) -> jnp.ndarray:
    """GPT-2-style init (N(0, 0.02), zero biases, unit LN gains)."""
    m = cfg.lm
    spec = param_spec(m)
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, shape in spec.entries:
        if name.endswith(".b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
    return spec.pack(params)


def forward(cfg: Config, p: Dict[str, jnp.ndarray], tokens, cap: nn.Capture):
    """Logits [B, T, V]."""
    m = cfg.lm
    b, t = tokens.shape
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :t]
    instrument_attn = cfg.logra.modules == "all"
    for l in range(m.n_layers):
        x = nn.layer_norm(h, p[f"l{l}.ln1.g"], p[f"l{l}.ln1.b"])
        if instrument_attn:
            q = cap.linear(p, f"l{l}.wq", x)
            k = cap.linear(p, f"l{l}.wk", x)
            v = cap.linear(p, f"l{l}.wv", x)
        else:
            q = nn.plain_linear(p, f"l{l}.wq", x)
            k = nn.plain_linear(p, f"l{l}.wk", x)
            v = nn.plain_linear(p, f"l{l}.wv", x)
        a = nn.causal_attention(q, k, v, m.n_heads)
        o = (
            cap.linear(p, f"l{l}.wo", a)
            if instrument_attn
            else nn.plain_linear(p, f"l{l}.wo", a)
        )
        h = h + o
        x2 = nn.layer_norm(h, p[f"l{l}.ln2.g"], p[f"l{l}.ln2.b"])
        f = nn.gelu(cap.linear(p, f"l{l}.fc", x2))
        h = h + cap.linear(p, f"l{l}.proj", f)
    hf = nn.layer_norm(h, p["lnf.g"], p["lnf.b"])
    return jnp.dot(hf, p["head.w"].T) + p["head.b"]


def per_sample_loss(cfg: Config, flat_params, tokens, cap: nn.Capture):
    """Summed next-token CE per sequence, [B]. Also returns logits."""
    p = param_spec(cfg.lm).unpack(flat_params)
    logits = forward(cfg, p, tokens, cap)
    tok_loss = nn.cross_entropy_per_token(logits[:, :-1], tokens[:, 1:])
    return tok_loss.sum(axis=-1), logits


def mean_hidden(cfg: Config, flat_params, tokens):
    """Mean final hidden state [B, d] (rep-similarity baseline)."""
    m = cfg.lm
    p = param_spec(m).unpack(flat_params)
    b, t = tokens.shape
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :t]
    for l in range(m.n_layers):
        x = nn.layer_norm(h, p[f"l{l}.ln1.g"], p[f"l{l}.ln1.b"])
        q = nn.plain_linear(p, f"l{l}.wq", x)
        k = nn.plain_linear(p, f"l{l}.wk", x)
        v = nn.plain_linear(p, f"l{l}.wv", x)
        a = nn.causal_attention(q, k, v, m.n_heads)
        h = h + nn.plain_linear(p, f"l{l}.wo", a)
        x2 = nn.layer_norm(h, p[f"l{l}.ln2.g"], p[f"l{l}.ln2.b"])
        h = h + nn.plain_linear(
            p, f"l{l}.proj", nn.gelu(nn.plain_linear(p, f"l{l}.fc", x2))
        )
    hf = nn.layer_norm(h, p["lnf.g"], p["lnf.b"])
    return hf.mean(axis=1)
