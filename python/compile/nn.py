"""Build-time neural-net primitives with LoGra activation capture.

Parameters live as ONE flat f32 vector on the Rust/PJRT boundary (simple,
layout-stable interchange); ``ParamSpec`` maps names/shapes to flat slices
and the AOT manifest records the layout for the Rust side.

LoGra capture (paper Fig. 2 / LogIX ``watch``): every instrumented linear
``y = x W^T + b`` additionally (1) records its input ``x`` and (2) adds a
zero-valued *probe* to ``y``. Differentiating the summed loss w.r.t. the
probe yields exactly ``dL/dy`` per sample — the backward activation LoGra
needs — without any framework-hook machinery, mirroring how LogIX's
bottleneck layer turns projected-gradient extraction into plain autodiff.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ param packing


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Ordered (name, shape) table with flat-vector offsets."""

    entries: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @property
    def total(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.entries)

    def offsets(self) -> Dict[str, Tuple[int, Tuple[int, ...]]]:
        out, off = {}, 0
        for name, shape in self.entries:
            n = 1
            for d in shape:
                n *= d
            out[name] = (off, shape)
            off += n
        return out

    def unpack(self, flat) -> Dict[str, jnp.ndarray]:
        out = {}
        for name, (off, shape) in self.offsets().items():
            n = 1
            for d in shape:
                n *= d
            out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        return out

    def pack(self, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        chunks = []
        for name, shape in self.entries:
            assert params[name].shape == shape, (name, params[name].shape, shape)
            chunks.append(params[name].reshape(-1))
        return jnp.concatenate(chunks)


# ------------------------------------------------------------ module table


@dataclasses.dataclass(frozen=True)
class ModuleSpec:
    """One LoGra-instrumented linear module."""

    name: str
    n_in: int
    n_out: int


def probe_shapes(
    modules: Sequence[ModuleSpec], batch: int, seq: int
) -> List[Tuple[int, int, int]]:
    """Probe tensor shapes, [B, T, n_out] per instrumented module."""
    return [(batch, seq, m.n_out) for m in modules]


def zero_probes(modules: Sequence[ModuleSpec], batch: int, seq: int):
    return [jnp.zeros(s, jnp.float32) for s in probe_shapes(modules, batch, seq)]


class Capture:
    """Mutable capture context threaded through a forward pass.

    ``probes`` is the ordered list of probe tensors (zeros at the
    evaluation point); each instrumented linear consumes the next probe and
    appends its input activation to ``xs``.
    """

    def __init__(self, probes: Sequence[jnp.ndarray]):
        self.probes = list(probes)
        self.xs: List[jnp.ndarray] = []
        self._idx = 0

    def linear(self, p: Dict[str, jnp.ndarray], name: str, x: jnp.ndarray):
        """Instrumented ``y = x @ W^T + b + probe``; records x."""
        w = p[f"{name}.w"]
        b = p[f"{name}.b"]
        y = jnp.dot(x, w.T) + b
        if self.probes:
            y = y + self.probes[self._idx]
            self.xs.append(x)
            self._idx += 1
        return y


def plain_linear(p: Dict[str, jnp.ndarray], name: str, x: jnp.ndarray):
    return jnp.dot(x, p[f"{name}.w"].T) + p[f"{name}.b"]


# ------------------------------------------------------------ primitives


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def causal_attention(q, k, v, n_heads: int):
    """Multi-head causal self-attention. q/k/v: [B, T, d]."""
    b, t, d = q.shape
    hd = d // n_heads

    def split(x):
        return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)  # [B,H,T,hd]

    qh, kh, vh = split(q), split(k), split(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(b, t, d)


def cross_entropy_per_token(logits, targets):
    """-log p(target) per position. logits [.., V], targets [..] int."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]


# ------------------------------------------------------------ grad capture


def grads_and_capture(
    loss_fn: Callable, modules: Sequence[ModuleSpec], batch: int, seq: int
):
    """Evaluate dL/dprobe (backward activations) + forward captures.

    ``loss_fn(probes) -> (scalar_loss, (per_sample_loss, xs))`` where the
    scalar loss is the SUM over the batch so probe grads are per-sample.

    Returns: (dprobes list [B,T,n_out], per_sample_loss [B], xs list
    [B,T,n_in]).
    """
    probes = zero_probes(modules, batch, seq)
    grad_fn = jax.grad(loss_fn, has_aux=True)
    dprobes, (per_loss, xs) = grad_fn(probes)
    return dprobes, per_loss, xs
