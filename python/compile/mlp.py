"""MLP classifier (Figure-4 vision benchmarks: FMNIST-like / CIFAR-like).

Mirrors the paper's 3-layer-MLP counterfactual benchmark. Every linear is
LoGra-instrumented. Inputs are flat feature vectors (the synthetic image
generators live in ``rust/src/data/images.rs``).
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import nn
from .config import Config, MlpModelConfig


def param_spec(m: MlpModelConfig) -> nn.ParamSpec:
    dims = [m.input_dim] + list(m.hidden) + [m.classes]
    entries = []
    for i in range(len(dims) - 1):
        entries.append((f"fc{i}.w", (dims[i + 1], dims[i])))
        entries.append((f"fc{i}.b", (dims[i + 1],)))
    return nn.ParamSpec(tuple(entries))


def module_specs(cfg: Config) -> List[nn.ModuleSpec]:
    m = cfg.mlp
    dims = [m.input_dim] + list(m.hidden) + [m.classes]
    return [
        nn.ModuleSpec(f"fc{i}", n_in=dims[i], n_out=dims[i + 1])
        for i in range(len(dims) - 1)
    ]


def init_params(cfg: Config, seed) -> jnp.ndarray:
    """He-initialized flat parameter vector; ``seed`` is a u32 scalar."""
    m = cfg.mlp
    spec = param_spec(m)
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}
    dims = [m.input_dim] + list(m.hidden) + [m.classes]
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        params[f"fc{i}.w"] = (
            jax.random.normal(sub, (dims[i + 1], dims[i]), jnp.float32) * scale
        )
        params[f"fc{i}.b"] = jnp.zeros((dims[i + 1],), jnp.float32)
    return spec.pack(params)


def forward(cfg: Config, p: Dict[str, jnp.ndarray], images, cap: nn.Capture):
    """Logits [B, C]. ``images`` [B, D] f32 in [0,1]-ish.

    Activations are carried with a singleton time axis so that the LoGra
    projection kernel's [B, T, n] contract is shared with the LM.
    """
    m = cfg.mlp
    h = images[:, None, :]  # [B, 1, D]
    n_layers = len(m.hidden) + 1
    for i in range(n_layers):
        h = cap.linear(p, f"fc{i}", h)
        if i + 1 < n_layers:
            h = jax.nn.relu(h)
    return h[:, 0, :]  # [B, C]


def per_sample_loss(cfg: Config, flat_params, images, labels, cap: nn.Capture):
    spec = param_spec(cfg.mlp)
    p = spec.unpack(flat_params)
    logits = forward(cfg, p, images, cap)
    return nn.cross_entropy_per_token(logits, labels), logits


def penultimate(cfg: Config, flat_params, images):
    """Last hidden representation [B, h_last] (rep-similarity baseline)."""
    m = cfg.mlp
    p = param_spec(m).unpack(flat_params)
    h = images[:, None, :]
    for i in range(len(m.hidden)):
        h = nn.plain_linear(p, f"fc{i}", h)
        h = jax.nn.relu(h)
    return h[:, 0, :]
