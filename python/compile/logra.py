"""LoGra entry-point assembly: the L2 functions that get AOT-lowered.

Every function here is shape-closed over a ``Config`` and takes/returns flat
f32 vectors (plus integer token / label tensors) so the Rust runtime can
drive them with a fixed literal layout recorded in the manifest.

Projection-matrix packing (shared with Rust): for module order
``module_specs(cfg)``, concatenate per module ``P_i`` ([k_in, n_in],
row-major) then ``P_o`` ([k_out, n_out], row-major) into one flat vector.
The EKFAC variant uses the same packing with full-rank k == n (the KFAC
eigenbasis rotation; corrected eigenvalues are fitted in Rust from the
rotated gradients it returns).
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import mlp as mlp_mod
from . import model as lm_mod
from . import nn
from .config import Config
from .kernels import covariance, logra_project


# ------------------------------------------------------------ dispatch


def modules_of(cfg: Config) -> List[nn.ModuleSpec]:
    return (
        lm_mod.module_specs(cfg) if cfg.kind == "lm" else mlp_mod.module_specs(cfg)
    )


def param_spec_of(cfg: Config):
    return (
        lm_mod.param_spec(cfg.lm)
        if cfg.kind == "lm"
        else mlp_mod.param_spec(cfg.mlp)
    )


def seq_of(cfg: Config) -> int:
    return cfg.lm.seq_len if cfg.kind == "lm" else 1


def loss_with_capture(cfg: Config, flat_params, batch, cap: nn.Capture):
    """Per-sample loss [B] under capture. ``batch`` is (tokens,) for LM and
    (images, labels) for MLP."""
    if cfg.kind == "lm":
        (tokens,) = batch
        loss, _ = lm_mod.per_sample_loss(cfg, flat_params, tokens, cap)
        return loss
    images, labels = batch
    loss, _ = mlp_mod.per_sample_loss(cfg, flat_params, images, labels, cap)
    return loss


# ------------------------------------------------------------ P packing


def proj_lengths(cfg: Config, full_rank: bool = False) -> List[Tuple[int, int]]:
    """Per-module (len(P_i), len(P_o)) in the flat projection vector."""
    out = []
    for m in modules_of(cfg):
        ki = m.n_in if full_rank else cfg.logra.k_in
        ko = m.n_out if full_rank else cfg.logra.k_out
        out.append((ki * m.n_in, ko * m.n_out))
    return out


def proj_total(cfg: Config, full_rank: bool = False) -> int:
    return sum(a + b for a, b in proj_lengths(cfg, full_rank))


def unpack_projections(cfg: Config, flat_p, full_rank: bool = False):
    """Flat projection vector -> [(P_i, P_o)] per module."""
    out, off = [], 0
    for m in modules_of(cfg):
        ki = m.n_in if full_rank else cfg.logra.k_in
        ko = m.n_out if full_rank else cfg.logra.k_out
        pi = jax.lax.dynamic_slice(flat_p, (off,), (ki * m.n_in,)).reshape(ki, m.n_in)
        off += ki * m.n_in
        po = jax.lax.dynamic_slice(flat_p, (off,), (ko * m.n_out,)).reshape(
            ko, m.n_out
        )
        off += ko * m.n_out
        out.append((pi, po))
    return out


def k_total(cfg: Config, full_rank: bool = False) -> int:
    if full_rank:
        return sum(m.n_in * m.n_out for m in modules_of(cfg))
    return len(modules_of(cfg)) * cfg.logra.k_in * cfg.logra.k_out


# ------------------------------------------------------------ entry points


def logra_log(cfg: Config, flat_params, flat_p, batch, full_rank: bool = False):
    """Per-sample projected gradients.

    Returns (G [B, K], per_sample_loss [B]) where K = k_total(cfg, full_rank)
    and G rows concatenate per-module vec(P_o DW_l P_i^T) blocks in module
    order — the layout the Rust gradient store and Hessian service assume.
    """
    mods = modules_of(cfg)
    batch_size = batch[0].shape[0]
    seq = seq_of(cfg)

    def lf(probes):
        cap = nn.Capture(probes)
        loss = loss_with_capture(cfg, flat_params, batch, cap)
        return loss.sum(), (loss, cap.xs)

    dprobes, per_loss, xs = nn.grads_and_capture(lf, mods, batch_size, seq)
    projs = unpack_projections(cfg, flat_p, full_rank)
    blocks = []
    for (pi, po), x, dx in zip(projs, xs, dprobes):
        blocks.append(logra_project(x, dx, pi, po))
    return jnp.concatenate(blocks, axis=1), per_loss


def cov_stats(cfg: Config, flat_params, batch):
    """KFAC factor contributions for this batch.

    Returns one flat vector concatenating, per module, ``C_F`` ([n_in²],
    sum of x x^T rows) then ``C_B`` ([n_out²], sum of dx dx^T rows). Rust
    accumulates these across the logging stream, eigendecomposes, and uses
    the top-k eigenvectors as the LoGra-PCA init / the full basis for EKFAC.
    """
    mods = modules_of(cfg)
    batch_size = batch[0].shape[0]
    seq = seq_of(cfg)

    def lf(probes):
        cap = nn.Capture(probes)
        loss = loss_with_capture(cfg, flat_params, batch, cap)
        return loss.sum(), (loss, cap.xs)

    dprobes, _, xs = nn.grads_and_capture(lf, mods, batch_size, seq)
    chunks = []
    for m, x, dx in zip(mods, xs, dprobes):
        chunks.append(covariance(x).reshape(-1))
        chunks.append(covariance(dx).reshape(-1))
    return jnp.concatenate(chunks)


def cov_lengths(cfg: Config) -> List[Tuple[int, int]]:
    return [(m.n_in * m.n_in, m.n_out * m.n_out) for m in modules_of(cfg)]


def full_grads(cfg: Config, flat_params, batch):
    """Per-sample FULL flattened gradients [B, n_params].

    The O(b·n) object the paper's baselines (grad-dot, TRAK projection,
    EKFAC recompute) pay for; kept for small configs only.
    """

    def single(flat_params, *example):
        cap = nn.Capture([])
        ex = tuple(e[None] for e in example)
        loss = loss_with_capture(cfg, flat_params, ex, cap)
        return loss[0]

    grad_one = jax.grad(single, argnums=0)
    return jax.vmap(lambda *ex: grad_one(flat_params, *ex))(*batch)
