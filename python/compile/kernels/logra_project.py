"""LoGra projected per-sample gradient kernel (paper Eq. 6) — Pallas.

The paper's compute hot-spot: reconstruct the *projected* per-sample weight
gradient directly from projected forward/backward activations, never
materializing the full ``DW = dx^T x`` (that naive path is the
``logra_project_ref`` oracle):

    G[b] = sum_t (P_o dx[b,t]) (P_i x[b,t])^T
         = (dx[b] @ P_o^T)^T @ (x[b] @ P_i^T)          # [k_out, k_in]

Complexity per sample drops from O(T*n_in*n_out + n*k) (materialize + project)
to O(T*sqrt(n)*sqrt(k) + T*k) — the paper's O(b*sqrt(n*k)) claim.

TPU mapping (DESIGN.md §8): grid over the batch; per grid step the block
holds one sample's activations plus both projection matrices in VMEM
(P_i/P_o are k×√n ≈ KBs, vs the 128 TB naive P for an 8B model); the two
skinny matmuls and the [k,T]×[T,k] contraction all feed the MXU. On this
testbed the kernel runs under ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls) — numerics only; perf is estimated structurally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dx_ref, pi_ref, po_ref, o_ref):
    # Blocks: x [1,T,n_in], dx [1,T,n_out], pi [k_in,n_in], po [k_out,n_out].
    x = x_ref[0]                      # [T, n_in]
    dx = dx_ref[0]                    # [T, n_out]
    px = jnp.dot(x, pi_ref[...].T, preferred_element_type=jnp.float32)   # [T, k_in]
    pdx = jnp.dot(dx, po_ref[...].T, preferred_element_type=jnp.float32)  # [T, k_out]
    g = jnp.dot(pdx.T, px, preferred_element_type=jnp.float32)            # [k_out, k_in]
    o_ref[0] = g.reshape(-1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def logra_project(x, dx, p_in, p_out):
    """Per-sample projected gradients.

    Args:
      x:     [B, T, n_in] forward activations.
      dx:    [B, T, n_out] backward activations.
      p_in:  [k_in, n_in].
      p_out: [k_out, n_out].

    Returns: [B, k_out * k_in] float32.
    """
    b, t, n_in = x.shape
    _, _, n_out = dx.shape
    k_in, _ = p_in.shape
    k_out, _ = p_out.shape
    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, t, n_in), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, n_out), lambda i: (i, 0, 0)),
            pl.BlockSpec((k_in, n_in), lambda i: (0, 0)),
            pl.BlockSpec((k_out, n_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k_out * k_in), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k_out * k_in), jnp.float32),
        interpret=True,
    )(x, dx, p_in, p_out)
