"""Pure-jnp correctness oracles for the Pallas kernels.

Each oracle implements the mathematically obvious ("naive") computation the
kernel must match bit-for-bit (up to float accumulation order). The oracles
intentionally take the *expensive* route the paper's LoGra kernel avoids —
e.g. ``logra_project_ref`` materializes the full per-sample gradient
``DW = dx^T x`` and only then projects it (the O(b*n*k) naive gradient
projection of TRAK / Arnoldi-IF, paper section 2) — so that a kernel/ref
match is also a check of the Eq. (6) Kronecker identity:

    (P_i (x) P_o) vec(DW) = vec( (P_o dx_t)(P_i x_t)^T summed over t ).
"""

from __future__ import annotations

import jax.numpy as jnp


def logra_project_ref(x, dx, p_in, p_out):
    """Naive projected per-sample gradient.

    Args:
      x:     [B, T, n_in]   forward activations (layer input).
      dx:    [B, T, n_out]  backward activations (grad of summed loss wrt
                            layer pre-activation output).
      p_in:  [k_in, n_in]   input-side projection.
      p_out: [k_out, n_out] output-side projection.

    Returns:
      [B, k_out * k_in] projected per-sample gradients, row-major over
      (k_out, k_in) — i.e. vec(P_o DW P_i^T) with C-order vec.
    """
    # Full per-sample weight gradient: DW[b] = sum_t dx[b,t] x[b,t]^T.
    dw = jnp.einsum("bto,bti->boi", dx, x)  # [B, n_out, n_in]
    proj = jnp.einsum("oO,bOI,iI->boi", p_out, dw, p_in)  # [B, k_out, k_in]
    return proj.reshape(proj.shape[0], -1)


def score_ref(g_test, g_train):
    """Influence dot-product: S = G_te @ G_tr^T.

    Args:
      g_test:  [B_te, K] (already iHVP-preconditioned by the caller).
      g_train: [B_tr, K].

    Returns: [B_te, B_tr] scores.
    """
    return g_test @ g_train.T


def covariance_ref(a):
    """Uncentered activation covariance (KFAC factor contribution).

    Args:
      a: [B, T, n] activations (or [R, n] pre-flattened rows).

    Returns: [n, n] sum over all rows of a a^T.
    """
    rows = a.reshape(-1, a.shape[-1])
    return rows.T @ rows
