"""Influence dot-product kernel — Pallas.

The recurring phase of the paper's valuation system (Table 1, right half):
``S = G_te @ G_tr^T`` where ``G_te`` rows are iHVP-preconditioned test
gradients and ``G_tr`` rows stream in from the memory-mapped gradient store.
A tiled matmul over a (test-tile, train-tile) grid; K (the total projected
dimension) is small by construction, so each tile keeps its full-K operands
resident.

TPU mapping: [bm,K]x[K,bn] MXU tiles; the train-side tile is the natural
unit the Rust prefetcher reads from disk, so the HBM→VMEM stream mirrors the
disk→host stream one level up (DESIGN.md §8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...].T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def score(g_test, g_train, block_m: int = 0, block_n: int = 0):
    """S[i, j] = <g_test[i], g_train[j]>.

    Args:
      g_test:  [M, K] preconditioned test gradients.
      g_train: [N, K] stored train gradients.
      block_m / block_n: tile sizes (0 = whole axis). Axes not divisible by
        the tile are zero-padded; the pad is sliced away from the result.

    Returns: [M, N] float32 scores.
    """
    m, k = g_test.shape
    n, k2 = g_train.shape
    assert k == k2, (k, k2)
    bm = block_m or m
    bn = block_n or n
    pm = (-m) % bm
    pn = (-n) % bn
    a = jnp.pad(g_test, ((0, pm), (0, 0))) if pm else g_test
    b = jnp.pad(g_train, ((0, pn), (0, 0))) if pn else g_train
    mm, nn = m + pm, n + pn
    out = pl.pallas_call(
        _kernel,
        grid=(mm // bm, nn // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=True,
    )(a, b)
    return out[:m, :n]
