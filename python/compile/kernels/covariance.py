"""Uncentered activation covariance kernel (KFAC factors) — Pallas.

Accumulates ``C = sum_r a_r a_r^T`` over all (batch, time) rows of an
activation tensor. These are the ``C_F`` / ``C_B`` Kronecker factors of the
KFAC Hessian approximation (paper §3.2); their eigenbases drive both the
LoGra PCA initialization and the EKFAC-influence baseline.

Grid iterates sequentially over row tiles (TPU grids and interpret mode are
both sequential), accumulating into the single output block — the classic
Pallas reduction idiom with a first-step zero-init.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    o_ref[...] += jnp.dot(a.T, a, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def covariance(a, block_rows: int = 0):
    """C = rows(a)^T rows(a), rows = reshape(a, [-1, n]).

    Args:
      a: [..., n] activations; leading axes are flattened into rows.
      block_rows: rows per grid step (0 = all rows in one step). Row counts
        not divisible by the tile are zero-padded (zero rows are exact
        no-ops for an uncentered covariance).

    Returns: [n, n] float32.
    """
    n = a.shape[-1]
    rows = a.reshape(-1, n)
    r = rows.shape[0]
    br = block_rows or r
    pad = (-r) % br
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    rr = r + pad
    return pl.pallas_call(
        _kernel,
        grid=(rr // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(rows)
