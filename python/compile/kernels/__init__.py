"""L1: Pallas kernels for the paper's compute hot-spots.

All kernels lower with ``interpret=True`` so the resulting HLO runs on any
PJRT backend, including the Rust CPU client (real-TPU Mosaic lowering is
compile-only on this testbed; see DESIGN.md section 8 for the hardware
adaptation analysis).
"""

from .covariance import covariance
from .logra_project import logra_project
from .score import score

__all__ = ["covariance", "logra_project", "score"]
