"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

Usage (driven by ``make artifacts``):

    cd python && python -m compile.aot --config ../configs/lm_tiny.toml \
        --out ../artifacts/lm_tiny

HLO text (NOT ``lowered.compiler_ir("hlo")`` protos, NOT ``.serialize()``)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the Rust ``xla`` 0.1.6
crate) rejects; the text parser reassigns ids and round-trips cleanly.
Lowered with ``return_tuple=True`` — the Rust side unwraps with
``to_tuple``.

The manifest (``manifest.txt``, flat ``key=value`` lines, parsed by
``rust/src/runtime/manifest.rs``) records every shape/offset convention the
Rust coordinator needs: flat-param layout, LoGra module table with
gradient-block and projection-vector offsets, covariance layout, and the
fixed batch shapes each entry point was closed over.
"""

from __future__ import annotations

import argparse
import os
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import logra, mlp as mlp_mod, model as lm_mod, optim
from .config import Config, load


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def batch_specs(cfg: Config, b: int) -> Tuple:
    """ShapeDtypeStructs for one data batch (LM: tokens; MLP: images+labels)."""
    if cfg.kind == "lm":
        return (i32(b, cfg.lm.seq_len),)
    return (f32(b, cfg.mlp.input_dim), i32(b))


def build_entries(cfg: Config):
    """[(name, fn, arg_specs, output_desc)] for every artifact."""
    spec = logra.param_spec_of(cfg)
    n = spec.total
    kk = logra.k_total(cfg)
    kf = logra.k_total(cfg, full_rank=True)
    pn = logra.proj_total(cfg)
    pf = logra.proj_total(cfg, full_rank=True)
    tb, lb = cfg.train.batch, cfg.log_batch
    qb, tc = cfg.test_batch, cfg.train_chunk

    def init(seed):
        if cfg.kind == "lm":
            return (lm_mod.init_params(cfg, seed),)
        return (mlp_mod.init_params(cfg, seed),)

    def train_step(params, m, v, step, *batch):
        def mean_loss(p):
            from . import nn

            cap = nn.Capture([])
            return logra.loss_with_capture(cfg, p, batch, cap).mean()

        loss, grad = jax.value_and_grad(mean_loss)(params)
        p2, m2, v2, s2 = optim.apply_update(cfg, params, m, v, step, grad)
        return (p2, m2, v2, s2, loss)

    def eval_loss(params, *batch):
        from . import nn

        cap = nn.Capture([])
        if cfg.kind == "lm":
            (tokens,) = batch
            loss, _ = lm_mod.per_sample_loss(cfg, params, tokens, cap)
            return (loss,)
        images, labels = batch
        loss, logits = mlp_mod.per_sample_loss(cfg, params, images, labels, cap)
        return (loss, logits)

    def logra_log(params, flat_p, *batch):
        g, loss = logra.logra_log(cfg, params, flat_p, batch)
        return (g, loss)

    def ekfac_log(params, flat_q, *batch):
        g, loss = logra.logra_log(cfg, params, flat_q, batch, full_rank=True)
        return (g, loss)

    def cov_stats(params, *batch):
        return (logra.cov_stats(cfg, params, batch),)

    def full_grad(params, *batch):
        return (logra.full_grads(cfg, params, batch),)

    def reprs(params, *batch):
        if cfg.kind == "lm":
            (tokens,) = batch
            return (lm_mod.mean_hidden(cfg, params, tokens),)
        images, _ = batch
        return (mlp_mod.penultimate(cfg, params, images),)

    def score(g_test, g_train):
        from .kernels import score as score_kernel

        return (score_kernel(g_test, g_train),)

    entries = [
        ("init", init, (u32(),)),
        ("train_step", train_step, (f32(n), f32(n), f32(n), i32(), *batch_specs(cfg, tb))),
        ("eval_loss", eval_loss, (f32(n), *batch_specs(cfg, lb))),
        ("logra_log", logra_log, (f32(n), f32(pn), *batch_specs(cfg, lb))),
        ("cov_stats", cov_stats, (f32(n), *batch_specs(cfg, lb))),
        ("full_grad", full_grad, (f32(n), *batch_specs(cfg, lb))),
        ("reprs", reprs, (f32(n), *batch_specs(cfg, lb))),
        ("score", score, (f32(qb, kk), f32(tc, kk))),
        ("ekfac_log", ekfac_log, (f32(n), f32(pf), *batch_specs(cfg, lb))),
        ("score_full", score, (f32(qb, kf), f32(lb, kf))),
    ]
    if cfg.kind == "lm":

        def logits(params, tokens):
            from . import nn

            p = lm_mod.param_spec(cfg.lm).unpack(params)
            return (lm_mod.forward(cfg, p, tokens, nn.Capture([])),)

        entries.append(("logits", logits, (f32(n), i32(1, cfg.lm.seq_len))))
    return entries


def write_manifest(cfg: Config, out_dir: str, entry_names: Sequence[str]) -> None:
    spec = logra.param_spec_of(cfg)
    mods = logra.modules_of(cfg)
    lines: List[str] = []
    add = lines.append
    add(f"name={cfg.name}")
    add(f"kind={cfg.kind}")
    add(f"n_params={spec.total}")
    add(f"k_in={cfg.logra.k_in}")
    add(f"k_out={cfg.logra.k_out}")
    add(f"k_total={logra.k_total(cfg)}")
    add(f"k_full={logra.k_total(cfg, full_rank=True)}")
    add(f"proj_len={logra.proj_total(cfg)}")
    add(f"proj_len_full={logra.proj_total(cfg, full_rank=True)}")
    add(f"train_batch={cfg.train.batch}")
    add(f"log_batch={cfg.log_batch}")
    add(f"test_batch={cfg.test_batch}")
    add(f"train_chunk={cfg.train_chunk}")
    if cfg.kind == "lm":
        add(f"vocab={cfg.lm.vocab}")
        add(f"seq_len={cfg.lm.seq_len}")
        add(f"d_model={cfg.lm.d_model}")
        add(f"repr_dim={cfg.lm.d_model}")
    else:
        add(f"input_dim={cfg.mlp.input_dim}")
        add(f"classes={cfg.mlp.classes}")
        add(f"repr_dim={cfg.mlp.hidden[-1]}")
    add(f"n_modules={len(mods)}")
    g_off = gf_off = p_off = pf_off = c_off = 0
    k2 = cfg.logra.k_in * cfg.logra.k_out
    for i, m in enumerate(mods):
        add(f"module.{i}.name={m.name}")
        add(f"module.{i}.n_in={m.n_in}")
        add(f"module.{i}.n_out={m.n_out}")
        add(f"module.{i}.g_off={g_off}")
        add(f"module.{i}.g_len={k2}")
        add(f"module.{i}.gfull_off={gf_off}")
        add(f"module.{i}.gfull_len={m.n_in * m.n_out}")
        add(f"module.{i}.p_off={p_off}")
        add(f"module.{i}.pfull_off={pf_off}")
        add(f"module.{i}.cov_off={c_off}")
        g_off += k2
        gf_off += m.n_in * m.n_out
        p_off += cfg.logra.k_in * m.n_in + cfg.logra.k_out * m.n_out
        pf_off += m.n_in * m.n_in + m.n_out * m.n_out
        c_off += m.n_in * m.n_in + m.n_out * m.n_out
    add(f"cov_len={c_off}")
    off = 0
    for i, (name, shape) in enumerate(spec.entries):
        sz = 1
        for d in shape:
            sz *= d
        add(f"param.{i}.name={name}")
        add(f"param.{i}.off={off}")
        add(f"param.{i}.shape={'x'.join(str(d) for d in shape)}")
        off += sz
    add(f"n_param_tensors={len(spec.entries)}")
    add("entries=" + ",".join(entry_names))
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", required=True)
    ap.add_argument("--out", required=True)
    ap.add_argument("--only", default="", help="comma list of entries to rebuild")
    args = ap.parse_args()
    cfg = load(args.config)
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    names = []
    for name, fn, specs in build_entries(cfg):
        names.append(name)
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] {cfg.name}/{name}: {len(text)} chars")
    write_manifest(cfg, args.out, names)
    print(f"[aot] {cfg.name}: manifest + {len(names)} entries -> {args.out}")


if __name__ == "__main__":
    main()
