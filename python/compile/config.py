"""Shared build-time configuration.

Configs live in ``configs/*.toml`` and are parsed both here (for AOT
lowering) and by the Rust coordinator (``rust/src/config``). Only the
TOML subset that the hand-rolled Rust parser understands is allowed:
``[section]`` headers, ``key = value`` with int / float / string / bool /
flat int-lists, and ``#`` comments.
"""

from __future__ import annotations

import dataclasses
import tomllib
from typing import List


@dataclasses.dataclass(frozen=True)
class LmModelConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class MlpModelConfig:
    input_dim: int
    hidden: List[int]
    classes: int


@dataclasses.dataclass(frozen=True)
class LograConfig:
    k_in: int
    k_out: int
    modules: str = "all"  # "all" | "mlp" (LM only: restrict to MLP linears)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch: int
    lr: float
    weight_decay: float
    optimizer: str  # "adamw" | "sgdm"
    momentum: float = 0.9
    grad_clip: float = 0.0


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    kind: str  # "lm" | "mlp"
    model: "LmModelConfig | MlpModelConfig"
    logra: LograConfig
    train: TrainConfig
    log_batch: int
    test_batch: int
    train_chunk: int

    @property
    def lm(self) -> LmModelConfig:
        assert self.kind == "lm"
        return self.model  # type: ignore[return-value]

    @property
    def mlp(self) -> MlpModelConfig:
        assert self.kind == "mlp"
        return self.model  # type: ignore[return-value]


def load(path: str) -> Config:
    with open(path, "rb") as f:
        raw = tomllib.load(f)
    kind = raw["meta"]["kind"]
    m = raw["model"]
    if kind == "lm":
        model = LmModelConfig(
            vocab=m["vocab"],
            d_model=m["d_model"],
            n_layers=m["n_layers"],
            n_heads=m["n_heads"],
            d_ff=m["d_ff"],
            seq_len=m["seq_len"],
        )
    elif kind == "mlp":
        model = MlpModelConfig(
            input_dim=m["input_dim"],
            hidden=list(m["hidden"]),
            classes=m["classes"],
        )
    else:
        raise ValueError(f"unknown model kind {kind!r}")
    lg = raw["logra"]
    tr = raw["train"]
    return Config(
        name=raw["meta"]["name"],
        kind=kind,
        model=model,
        logra=LograConfig(
            k_in=lg["k_in"],
            k_out=lg["k_out"],
            modules=lg.get("modules", "all"),
        ),
        train=TrainConfig(
            batch=tr["batch"],
            lr=float(tr["lr"]),
            weight_decay=float(tr["weight_decay"]),
            optimizer=tr["optimizer"],
            momentum=float(tr.get("momentum", 0.9)),
            grad_clip=float(tr.get("grad_clip", 0.0)),
        ),
        log_batch=raw["log"]["batch"],
        test_batch=raw["score"]["test_batch"],
        train_chunk=raw["score"]["train_chunk"],
    )
