"""Flat-vector optimizers (AdamW / SGD-momentum) for the train_step artifact.

State is two flat f32 vectors (m, v) regardless of optimizer (SGD-M leaves v
untouched) so the Rust driver has a single train-step calling convention.
``step`` is an i32 scalar used for Adam bias correction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import Config


def clip_by_global_norm(g, max_norm: float):
    if max_norm <= 0.0:
        return g
    norm = jnp.sqrt(jnp.sum(g * g))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return g * scale


def apply_update(cfg: Config, params, m, v, step, grad):
    """One optimizer step. Returns (params', m', v', step+1)."""
    t = cfg.train
    grad = clip_by_global_norm(grad, t.grad_clip)
    new_step = step + 1
    if t.optimizer == "adamw":
        b1, b2, eps = 0.9, 0.999, 1e-8
        m2 = b1 * m + (1.0 - b1) * grad
        v2 = b2 * v + (1.0 - b2) * grad * grad
        tf = new_step.astype(jnp.float32)
        mhat = m2 / (1.0 - b1**tf)
        vhat = v2 / (1.0 - b2**tf)
        upd = mhat / (jnp.sqrt(vhat) + eps) + t.weight_decay * params
        return params - t.lr * upd, m2, v2, new_step
    if t.optimizer == "sgdm":
        m2 = t.momentum * m + grad + t.weight_decay * params
        return params - t.lr * m2, m2, v, new_step
    raise ValueError(f"unknown optimizer {t.optimizer!r}")
