"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes (batch, seq, widths, ranks, tile sizes) and checks
``assert_allclose`` against the pure-jnp oracles in ``compile.kernels.ref``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import covariance, logra_project, score
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def _arr(rng, shape, dtype=np.float32, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------- logra


@settings(**SETTINGS)
@given(
    b=st.integers(1, 5),
    t=st.integers(1, 9),
    n_in=st.integers(1, 24),
    n_out=st.integers(1, 24),
    k_in=st.integers(1, 8),
    k_out=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_logra_project_matches_ref(b, t, n_in, n_out, k_in, k_out, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, t, n_in))
    dx = _arr(rng, (b, t, n_out))
    pi = _arr(rng, (k_in, n_in))
    po = _arr(rng, (k_out, n_out))
    got = np.asarray(logra_project(x, dx, pi, po))
    want = np.asarray(ref.logra_project_ref(x, dx, pi, po))
    assert got.shape == (b, k_out * k_in)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_logra_project_kron_identity():
    """Eq. (6): projecting activations == projecting vec(DW) with P_i ⊗ P_o."""
    rng = np.random.default_rng(7)
    b, t, n_in, n_out, k_in, k_out = 2, 4, 6, 5, 3, 2
    x = _arr(rng, (b, t, n_in))
    dx = _arr(rng, (b, t, n_out))
    pi = _arr(rng, (k_in, n_in))
    po = _arr(rng, (k_out, n_out))
    got = np.asarray(logra_project(x, dx, pi, po))
    # Explicit Kronecker route: P = P_o ⊗ P_i applied to vec(DW) (C-order).
    dw = np.einsum("bto,bti->boi", dx, x).reshape(b, -1)
    p = np.kron(po, pi)  # [k_out*k_in, n_out*n_in] for C-order vec.
    want = dw @ p.T
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_logra_project_zero_dx_is_zero():
    rng = np.random.default_rng(1)
    x = _arr(rng, (2, 3, 8))
    dx = np.zeros((2, 3, 4), np.float32)
    pi = _arr(rng, (2, 8))
    po = _arr(rng, (2, 4))
    assert np.all(np.asarray(logra_project(x, dx, pi, po)) == 0.0)


def test_logra_project_linear_in_dx():
    rng = np.random.default_rng(2)
    x = _arr(rng, (2, 3, 8))
    dx = _arr(rng, (2, 3, 4))
    pi = _arr(rng, (2, 8))
    po = _arr(rng, (2, 4))
    one = np.asarray(logra_project(x, dx, pi, po))
    three = np.asarray(logra_project(x, 3.0 * dx, pi, po))
    assert_allclose(three, 3.0 * one, rtol=1e-5, atol=1e-5)


def test_logra_project_additive_over_time():
    """The t-sum structure: concat along T == sum of the two halves."""
    rng = np.random.default_rng(3)
    x1, x2 = _arr(rng, (2, 3, 8)), _arr(rng, (2, 5, 8))
    d1, d2 = _arr(rng, (2, 3, 4)), _arr(rng, (2, 5, 4))
    pi = _arr(rng, (2, 8))
    po = _arr(rng, (2, 4))
    whole = np.asarray(
        logra_project(
            np.concatenate([x1, x2], axis=1), np.concatenate([d1, d2], axis=1), pi, po
        )
    )
    parts = np.asarray(logra_project(x1, d1, pi, po)) + np.asarray(
        logra_project(x2, d2, pi, po)
    )
    assert_allclose(whole, parts, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- score


@settings(**SETTINGS)
@given(
    m=st.integers(1, 17),
    n=st.integers(1, 33),
    k=st.integers(1, 40),
    bm=st.integers(0, 8),
    bn=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_matches_ref(m, n, k, bm, bn, seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, (m, k))
    b = _arr(rng, (n, k))
    got = np.asarray(score(a, b, block_m=min(bm, m), block_n=min(bn, n)))
    want = np.asarray(ref.score_ref(a, b))
    assert got.shape == (m, n)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_score_tiling_invariance():
    """Same result for every tile decomposition (incl. padded tails)."""
    rng = np.random.default_rng(11)
    a = _arr(rng, (10, 32))
    b = _arr(rng, (14, 32))
    base = np.asarray(score(a, b))
    for bm, bn in [(1, 1), (3, 5), (4, 7), (10, 14), (8, 8)]:
        tiled = np.asarray(score(a, b, block_m=bm, block_n=bn))
        assert_allclose(tiled, base, rtol=1e-5, atol=1e-5)


def test_score_orthogonal_rows():
    eye = np.eye(6, 16, dtype=np.float32)
    s = np.asarray(score(eye, eye))
    assert_allclose(s, np.eye(6, dtype=np.float32), atol=1e-6)


# ---------------------------------------------------------------- covariance


@settings(**SETTINGS)
@given(
    b=st.integers(1, 6),
    t=st.integers(1, 9),
    n=st.integers(1, 24),
    br=st.integers(0, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_covariance_matches_ref(b, t, n, br, seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, (b, t, n))
    got = np.asarray(covariance(a, block_rows=br))
    want = np.asarray(ref.covariance_ref(a))
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_covariance_symmetric_psd():
    rng = np.random.default_rng(5)
    a = _arr(rng, (4, 8, 12))
    c = np.asarray(covariance(a, block_rows=8))
    assert_allclose(c, c.T, atol=1e-5)
    evals = np.linalg.eigvalsh(c)
    assert evals.min() >= -1e-3


def test_covariance_2d_input():
    rng = np.random.default_rng(6)
    a = _arr(rng, (30, 7))
    got = np.asarray(covariance(a, block_rows=4))
    assert_allclose(got, np.asarray(ref.covariance_ref(a)), rtol=1e-4, atol=1e-4)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
