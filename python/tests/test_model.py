"""L2 model correctness: LoGra capture vs full autodiff, training sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import logra, mlp as mlp_mod, model as lm_mod, nn, optim
from compile.config import load

LM_CFG = load("../configs/lm_tiny.toml")
MLP_CFG = load("../configs/mlp_fmnist.toml")


def _lm_batch(rng, b, cfg=LM_CFG):
    return (jnp.asarray(rng.integers(0, cfg.lm.vocab, size=(b, cfg.lm.seq_len)), jnp.int32),)


def _mlp_batch(rng, b, cfg=MLP_CFG):
    x = jnp.asarray(rng.normal(size=(b, cfg.mlp.input_dim)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, cfg.mlp.classes, size=(b,)), jnp.int32)
    return (x, y)


def _rand_proj(rng, cfg, full_rank=False):
    return jnp.asarray(
        rng.normal(size=(logra.proj_total(cfg, full_rank),)).astype(np.float32) * 0.3
    )


@pytest.fixture(scope="module")
def lm_params():
    return lm_mod.init_params(LM_CFG, jnp.uint32(0))


@pytest.fixture(scope="module")
def mlp_params():
    return mlp_mod.init_params(MLP_CFG, jnp.uint32(0))


# ------------------------------------------------- capture == autodiff


@pytest.mark.parametrize("kind", ["lm", "mlp"])
def test_logra_log_matches_projected_full_grad(kind, lm_params, mlp_params):
    """G rows from the capture path == P-projected slices of the full
    per-sample gradient: validates probes, capture ordering, and block
    layout end to end."""
    rng = np.random.default_rng(0)
    cfg = LM_CFG if kind == "lm" else MLP_CFG
    params = lm_params if kind == "lm" else mlp_params
    batch = _lm_batch(rng, 4) if kind == "lm" else _mlp_batch(rng, 4)
    flat_p = _rand_proj(rng, cfg)

    g, loss = logra.logra_log(cfg, params, flat_p, batch)
    full = logra.full_grads(cfg, params, batch)  # [B, n_params]

    spec = logra.param_spec_of(cfg)
    offsets = spec.offsets()
    projs = logra.unpack_projections(cfg, flat_p)
    mods = logra.modules_of(cfg)
    col = 0
    for m, (pi, po) in zip(mods, projs):
        off, shape = offsets[m.name + ".w"]
        size = shape[0] * shape[1]
        dw = np.asarray(full[:, off : off + size]).reshape(-1, shape[0], shape[1])
        want = np.einsum("oO,bOI,iI->boi", po, dw, pi).reshape(dw.shape[0], -1)
        got = np.asarray(g[:, col : col + want.shape[1]])
        assert_allclose(got, want, rtol=5e-3, atol=5e-3)
        col += want.shape[1]
    assert col == logra.k_total(cfg)
    assert np.all(np.isfinite(np.asarray(loss)))


def test_ekfac_full_rank_projection_is_lossless(mlp_params):
    """With identity 'projections', logra_log returns the raw per-module
    weight gradients (the EKFAC logging path with Q = I)."""
    rng = np.random.default_rng(1)
    cfg = MLP_CFG
    batch = _mlp_batch(rng, 3)
    mods = logra.modules_of(cfg)
    chunks = []
    for m in mods:
        chunks.append(np.eye(m.n_in, dtype=np.float32).reshape(-1))
        chunks.append(np.eye(m.n_out, dtype=np.float32).reshape(-1))
    flat_q = jnp.asarray(np.concatenate(chunks))
    g, _ = logra.logra_log(cfg, mlp_params, flat_q, batch, full_rank=True)

    full = logra.full_grads(cfg, mlp_params, batch)
    spec = logra.param_spec_of(cfg)
    offsets = spec.offsets()
    col = 0
    for m in mods:
        off, shape = offsets[m.name + ".w"]
        size = shape[0] * shape[1]
        want = np.asarray(full[:, off : off + size])
        got = np.asarray(g[:, col : col + size])
        assert_allclose(got, want, rtol=2e-3, atol=2e-3)
        col += size


def test_cov_stats_psd_and_layout(lm_params):
    rng = np.random.default_rng(2)
    cfg = LM_CFG
    batch = _lm_batch(rng, 4)
    flat = np.asarray(logra.cov_stats(cfg, lm_params, batch))
    assert flat.shape == (sum(a + b for a, b in logra.cov_lengths(cfg)),)
    off = 0
    for (fl, bl), m in zip(logra.cov_lengths(cfg), logra.modules_of(cfg)):
        cf = flat[off : off + fl].reshape(m.n_in, m.n_in)
        off += fl
        cb = flat[off : off + bl].reshape(m.n_out, m.n_out)
        off += bl
        for c in (cf, cb):
            assert_allclose(c, c.T, atol=1e-3)
            assert np.linalg.eigvalsh(c).min() >= -1e-2


# ------------------------------------------------- loss / training


def test_lm_loss_is_per_sample(lm_params):
    """Permuting the batch permutes losses and gradient rows."""
    rng = np.random.default_rng(3)
    cfg = LM_CFG
    (tokens,) = _lm_batch(rng, 4)
    flat_p = _rand_proj(rng, cfg)
    g1, l1 = logra.logra_log(cfg, lm_params, flat_p, (tokens,))
    perm = jnp.asarray([2, 0, 3, 1])
    g2, l2 = logra.logra_log(cfg, lm_params, flat_p, (tokens[perm],))
    assert_allclose(np.asarray(l2), np.asarray(l1)[np.asarray(perm)], rtol=1e-5)
    assert_allclose(np.asarray(g2), np.asarray(g1)[np.asarray(perm)], rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("kind", ["lm", "mlp"])
def test_train_step_reduces_loss(kind, lm_params, mlp_params):
    rng = np.random.default_rng(4)
    cfg = LM_CFG if kind == "lm" else MLP_CFG
    params = lm_params if kind == "lm" else mlp_params
    batch = _lm_batch(rng, cfg.train.batch) if kind == "lm" else _mlp_batch(rng, cfg.train.batch)

    def mean_loss(p):
        cap = nn.Capture([])
        return logra.loss_with_capture(cfg, p, batch, cap).mean()

    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    step = jnp.int32(0)
    l0 = float(mean_loss(params))
    for _ in range(20):
        loss, grad = jax.value_and_grad(mean_loss)(params)
        params, m, v, step = optim.apply_update(cfg, params, m, v, step, grad)
    l1 = float(mean_loss(params))
    assert l1 < l0, (l0, l1)


def test_init_deterministic_and_seed_sensitive():
    a = np.asarray(lm_mod.init_params(LM_CFG, jnp.uint32(7)))
    b = np.asarray(lm_mod.init_params(LM_CFG, jnp.uint32(7)))
    c = np.asarray(lm_mod.init_params(LM_CFG, jnp.uint32(8)))
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (logra.param_spec_of(LM_CFG).total,)


def test_optimizers_update_params():
    rng = np.random.default_rng(5)
    for cfg in (LM_CFG, MLP_CFG):  # adamw and sgdm respectively
        n = 64
        p = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        p2, m2, v2, s2 = optim.apply_update(
            cfg, p, jnp.zeros(n), jnp.zeros(n), jnp.int32(0), g
        )
        assert not np.allclose(np.asarray(p2), np.asarray(p))
        assert int(s2) == 1


def test_grad_clip_bounds_update_norm():
    cfg = LM_CFG  # grad_clip = 1.0
    g = jnp.full((100,), 100.0)
    clipped = optim.clip_by_global_norm(g, cfg.train.grad_clip)
    assert float(jnp.sqrt(jnp.sum(clipped**2))) <= cfg.train.grad_clip + 1e-4


def test_repr_shapes(lm_params, mlp_params):
    rng = np.random.default_rng(6)
    (tokens,) = _lm_batch(rng, 3)
    h = lm_mod.mean_hidden(LM_CFG, lm_params, tokens)
    assert h.shape == (3, LM_CFG.lm.d_model)
    x, y = _mlp_batch(rng, 3)
    r = mlp_mod.penultimate(MLP_CFG, mlp_params, x)
    assert r.shape == (3, MLP_CFG.mlp.hidden[-1])


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
