"""AOT manifest + lowering invariants (cheap: no full artifact builds)."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, logra
from compile.config import load

LM_CFG = load("../configs/lm_tiny.toml")
MLP_CFG = load("../configs/mlp_fmnist.toml")


def _manifest_dict(cfg, tmp_path):
    names = [n for n, _, _ in aot.build_entries(cfg)]
    aot.write_manifest(cfg, str(tmp_path), names)
    out = {}
    with open(os.path.join(tmp_path, "manifest.txt")) as f:
        for line in f:
            k, _, v = line.strip().partition("=")
            out[k] = v
    return out


@pytest.mark.parametrize("cfg", [LM_CFG, MLP_CFG], ids=["lm", "mlp"])
def test_manifest_offsets_consistent(cfg, tmp_path):
    man = _manifest_dict(cfg, tmp_path)
    n_mod = int(man["n_modules"])
    assert n_mod == len(logra.modules_of(cfg))
    # Gradient blocks tile [0, k_total) without gaps.
    end = 0
    for i in range(n_mod):
        assert int(man[f"module.{i}.g_off"]) == end
        end += int(man[f"module.{i}.g_len"])
    assert end == int(man["k_total"])
    # Full-rank blocks tile [0, k_full).
    end = 0
    for i in range(n_mod):
        assert int(man[f"module.{i}.gfull_off"]) == end
        end += int(man[f"module.{i}.gfull_len"])
    assert end == int(man["k_full"])
    # Param table covers [0, n_params).
    n_tensors = int(man["n_param_tensors"])
    off = 0
    for i in range(n_tensors):
        assert int(man[f"param.{i}.off"]) == off
        shape = [int(d) for d in man[f"param.{i}.shape"].split("x")]
        sz = 1
        for d in shape:
            sz *= d
        off += sz
    assert off == int(man["n_params"])
    # Covariance layout end == cov_len.
    want_cov = sum(a + b for a, b in logra.cov_lengths(cfg))
    assert int(man["cov_len"]) == want_cov


def test_score_entry_lowers_to_hlo_text():
    cfg = LM_CFG
    entries = {n: (fn, specs) for n, fn, specs in aot.build_entries(cfg)}
    fn, specs = entries["score"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    assert "ROOT" in text


def test_entry_list_complete():
    names = [n for n, _, _ in aot.build_entries(LM_CFG)]
    for required in [
        "init",
        "train_step",
        "eval_loss",
        "logra_log",
        "cov_stats",
        "full_grad",
        "reprs",
        "score",
        "ekfac_log",
        "score_full",
        "logits",
    ]:
        assert required in names
    mlp_names = [n for n, _, _ in aot.build_entries(MLP_CFG)]
    assert "logits" not in mlp_names  # LM-only entry


def test_proj_total_matches_unpack():
    cfg = LM_CFG
    flat = jnp.zeros((logra.proj_total(cfg),), jnp.float32)
    projs = logra.unpack_projections(cfg, flat)
    assert len(projs) == len(logra.modules_of(cfg))
    for (pi, po), m in zip(projs, logra.modules_of(cfg)):
        assert pi.shape == (cfg.logra.k_in, m.n_in)
        assert po.shape == (cfg.logra.k_out, m.n_out)
