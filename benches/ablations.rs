//! Bench: design-choice ablations called out in DESIGN.md §3 —
//! (a) IO/compute overlap (writer queue depth, scan prefetch),
//! (b) HLO score program vs native fallback,
//! (c) scoring chunk size,
//! (d) damping sweep effect on self-retrieval rank.

use logra::coordinator::{projected_grads, run_logging, LoggingOptions};
use logra::data::corpus::{generate, CorpusSpec};
use logra::hessian::random_projections;
use logra::model::dataset::Dataset;
use logra::model::trainer::Trainer;
use logra::runtime::Runtime;
use logra::util::bench::{bench, report_metric, BenchOpts};
use logra::util::rng::Pcg32;
use logra::valuation::{Normalization, QueryEngine};

fn main() {
    let root = std::env::current_dir().expect("cwd");
    if !root.join("artifacts").join("lm_tiny").join("manifest.txt").exists() {
        eprintln!("ablations bench skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::open_named(&root, "lm_tiny").expect("runtime");
    let man = rt.manifest.clone();
    let n_train = 512usize;
    let corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, n_train, 9));
    let ds = Dataset::Lm(&corpus);
    let trainer = Trainer::new(&rt);
    let st = trainer.init(0).expect("init");
    let mut rng = Pcg32::seeded(1);
    let proj = random_projections(&man, &mut rng);
    let run_dir = root.join("runs").join("ablations");
    let _ = std::fs::create_dir_all(&run_dir);

    // ---------- (a) writer queue depth (IO overlap in logging).
    for cap in [1usize, 4, 16] {
        let dir = run_dir.join(format!("store-cap{cap}"));
        let res = bench(
            &format!("logging.queue_cap{cap}"),
            BenchOpts { warmup_iters: 1, iters: 3, max_seconds: 120.0 },
            || {
                let _ = std::fs::remove_dir_all(&dir);
                let opts = LoggingOptions { queue_cap: cap, fit_hessian: true };
                run_logging(&rt, &ds, &st.params, &proj, &dir, &opts).expect("log");
            },
        );
        report_metric(
            &format!("ablation.logging.tokens_per_s.cap{cap}"),
            (n_train * man.seq_len) as f64 / res.summary().mean,
            "tokens_per_s",
        );
    }

    // Build one store + engine for the scan ablations.
    let dir = run_dir.join("store-main");
    let _ = std::fs::remove_dir_all(&dir);
    let (store, hess, _) =
        run_logging(&rt, &ds, &st.params, &proj, &dir, &LoggingOptions::default())
            .expect("log");
    let precond = hess.unwrap().preconditioner(0.1).expect("precond");
    let qidx: Vec<usize> = (0..man.test_batch).collect();
    let (g, _) = projected_grads(&rt, &ds, &qidx, &st.params, &proj).expect("grads");

    // ---------- (b) HLO Pallas-score program vs native matmul.
    for (label, use_hlo) in [("hlo", true), ("native", false)] {
        let mut engine = QueryEngine::new(&rt, &store, &precond);
        engine.use_hlo = use_hlo;
        let res = bench(
            &format!("scan.{label}"),
            BenchOpts { warmup_iters: 1, iters: 5, max_seconds: 60.0 },
            || {
                let _ = engine
                    .values_matrix(&g, qidx.len(), Normalization::None)
                    .expect("scan");
            },
        );
        report_metric(
            &format!("ablation.scan.pairs_per_s.{label}"),
            (qidx.len() * store.rows()) as f64 / res.summary().mean,
            "pairs_per_s",
        );
    }

    // ---------- (c) RelatIF overhead (self-influence cache amortization).
    {
        let engine = QueryEngine::new(&rt, &store, &precond);
        // Cold: includes building the self-influence cache.
        let t = std::time::Instant::now();
        let _ = engine.query(&g, qidx.len(), 5, Normalization::RelatIf).unwrap();
        let cold = t.elapsed().as_secs_f64();
        let t = std::time::Instant::now();
        let _ = engine.query(&g, qidx.len(), 5, Normalization::RelatIf).unwrap();
        let warm = t.elapsed().as_secs_f64();
        report_metric("ablation.relatif.cold_s", cold, "s");
        report_metric("ablation.relatif.warm_s", warm, "s");
    }

    // ---------- (d) damping sweep -> self-retrieval quality.
    let hess2 = {
        // Re-log to regain the Hessian (consumed above).
        let dir2 = run_dir.join("store-damp");
        let _ = std::fs::remove_dir_all(&dir2);
        let (_, h, _) =
            run_logging(&rt, &ds, &st.params, &proj, &dir2, &LoggingOptions::default())
                .expect("log");
        h.unwrap()
    };
    for damp in [0.01f32, 0.1, 1.0, 10.0] {
        let p = hess2.preconditioner(damp).expect("precond");
        let engine = QueryEngine::new(&rt, &store, &p);
        let res = engine.query(&g, qidx.len(), 5, Normalization::None).unwrap();
        let hits = qidx
            .iter()
            .enumerate()
            .filter(|(i, &qi)| res[*i].top.iter().any(|&(_, id)| id == qi as u64))
            .count();
        report_metric(
            &format!("ablation.damping.self_retrieval@5.d{damp}"),
            hits as f64 / qidx.len() as f64,
            "frac",
        );
    }
    println!("ablations done");
}
