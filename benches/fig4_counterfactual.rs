//! Bench: Figure 4 — counterfactual accuracy (brittleness + LDS) at a
//! budget-scaled size. `cargo bench --bench fig4_counterfactual`.
//!
//! Env overrides: LOGRA_FIG4_CONFIG (default mlp_fmnist; `all` for every
//! benchmark), LOGRA_FIG4_NTRAIN, LOGRA_FIG4_SUBSETS.

use logra::eval::fig4::{render_markdown, run_fig4, Fig4Scale};
use logra::eval::{BrittlenessConfig, LdsConfig};
use logra::util::bench::report_metric;

fn main() {
    let root = std::env::current_dir().expect("cwd");
    let config = std::env::var("LOGRA_FIG4_CONFIG").unwrap_or_else(|_| "mlp_fmnist".into());
    let configs: Vec<String> = if config == "all" {
        vec!["mlp_fmnist".into(), "mlp_cifar".into(), "lm_wikitext".into()]
    } else {
        vec![config]
    };
    let n_train: usize = std::env::var("LOGRA_FIG4_NTRAIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let subsets: usize = std::env::var("LOGRA_FIG4_SUBSETS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    for c in configs {
        if !root.join("artifacts").join(&c).join("manifest.txt").exists() {
            eprintln!("fig4 bench skipped for {c}: run `make artifacts`");
            continue;
        }
        let scale = Fig4Scale {
            n_train,
            n_test_pool: 48,
            n_test: 4,
            base_epochs: 3,
            brittle: BrittlenessConfig {
                removal_counts: vec![8, 32],
                retrain_seeds: vec![100],
                epochs: 3,
            },
            lds: LdsConfig { n_subsets: subsets, epochs: 3, ..Default::default() },
            ..Default::default()
        };
        let out = run_fig4(&root, &c, &scale).expect("fig4");
        println!("\n{}", render_markdown(&out));
        for o in &out.outcomes {
            if let Some(l) = o.lds {
                report_metric(&format!("fig4.{c}.{}.lds", o.method), l, "spearman");
            }
            if let Some(b) = &o.brittleness {
                for (k, v) in &b.per_k {
                    report_metric(
                        &format!("fig4.{c}.{}.brittleness.k{k}", o.method),
                        *v,
                        if out.kind == "mlp" { "flip_frac" } else { "dloss" },
                    );
                }
            }
        }
    }
}
