//! Bench: substrate microbenchmarks — host linalg (matmul_t, eigh),
//! store scan bandwidth, sharded parallel scan throughput, quantized
//! (int8) scan and two-stage scan-then-rescore throughput, persistent
//! scan-pool serving throughput under concurrent query admission, top-k
//! throughput. These locate the L3 hot-path costs for the perf pass
//! (DESIGN.md §7).
//!
//! Emits `BENCH_scan.json` (rows/s for the f32 scan, the quantized scan,
//! the two-stage engine, and the IVF engine at a pruned probe; IVF
//! recall@10 on a clustered corpus plus a full-probe bit-identity bit;
//! kernel-level rows/s for the dispatched f32 and int8 scan microkernels
//! vs the naive reference kernels they replaced; queries/s for the pool
//! at concurrency 1/4/8 vs per-query thread spawn, plus the pooled
//! concurrency-8 p50/p99 query latency read from the observability
//! histograms; storage bytes per codec) so the scan perf trajectory is
//! tracked across PRs — CI gates on it against `BENCH_baseline.json`
//! (see `scripts/bench_gate.py`).

use std::sync::Arc;
use std::time::Instant;

use logra::coordinator::Metrics;
use logra::hessian::BlockHessian;
use logra::linalg::{eigh, Matrix};
use logra::session::{stage_spec, Combine, Session, SessionConfig, SessionManifest, SESSION_VERSION};
use logra::store::{
    build_index, quantize_store, shard_store, GradStore, GradStoreWriter, IvfIndex,
    QuantShardedStore, ShardedStore,
};
use logra::util::bench::{bench, report_metric, BenchOpts};
use logra::util::rng::Pcg32;
use logra::util::topk::TopK;
use logra::valuation::{
    BackendConfig, IvfEngine, Normalization, ParallelQueryEngine, QueryEngine, QueryRequest,
    ScanBackend, ScanPool, TwoStageEngine,
};

fn main() {
    let mut rng = Pcg32::seeded(7);

    // matmul_t at scoring shapes: [8, K] x [chunk, K].
    for (m, n, k) in [(8usize, 256usize, 192usize), (8, 1024, 192), (8, 1024, 768)] {
        let a = Matrix::random_normal(&mut rng, m, k, 1.0);
        let b = Matrix::random_normal(&mut rng, n, k, 1.0);
        let res = bench(
            &format!("matmul_t.{m}x{n}x{k}"),
            BenchOpts { warmup_iters: 2, iters: 20, max_seconds: 20.0 },
            || {
                let c = a.matmul_t(&b);
                std::hint::black_box(&c);
            },
        );
        let flops = 2.0 * (m * n * k) as f64;
        report_metric(
            &format!("micro.matmul_t.gflops.{m}x{n}x{k}"),
            flops / res.summary().mean / 1e9,
            "gflops",
        );
    }

    // Jacobi eigh across Hessian-block sizes.
    for n in [16usize, 64, 128, 256] {
        let b = Matrix::random_normal(&mut rng, n + 8, n, 1.0);
        let s = b.transpose().matmul(&b);
        let res = bench(
            &format!("eigh.{n}"),
            BenchOpts { warmup_iters: 1, iters: 5, max_seconds: 30.0 },
            || {
                let e = eigh(&s);
                std::hint::black_box(&e.eigenvalues);
            },
        );
        report_metric(&format!("micro.eigh.ms.{n}"), res.summary().mean * 1e3, "ms");
    }

    // Scan microkernels in isolation (no store, no heaps): rows/s through
    // the dispatched kernel layer vs the naive reference kernels the
    // engines ran before the kernel subsystem — the before/after of the
    // SIMD register-tiling work, and the kernel-level floors
    // BENCH_scan.json carries for the CI gate.
    let (kernel_f32_rows_per_s, kernel_q8_rows_per_s) = {
        use logra::linalg::kernels::{self, ScanScratch};
        use logra::store::quant::{blocks_of, dot_q8, quantize_rows};

        let k = 192usize;
        let nt = 8usize;
        let len = 1024usize;
        let mut a = vec![0.0f32; nt * k];
        let mut b = vec![0.0f32; len * k];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        println!("kernel arm: {}", kernels::kernel_arm().name());
        let opts = BenchOpts { warmup_iters: 2, iters: 30, max_seconds: 20.0 };

        let naive_f32 = bench("kernel.f32.naive", opts, || {
            let c = logra::linalg::matrix::matmul_t_slices(&a, nt, &b, len, k);
            std::hint::black_box(&c);
        })
        .summary()
        .mean;
        let mut scratch = ScanScratch::new();
        let tiled_f32 = bench("kernel.f32.tiled", opts, || {
            let out = scratch.score_buf(nt * len);
            kernels::matmul_t_into(&a, nt, &b, len, k, out);
            std::hint::black_box(&out[0]);
        })
        .summary()
        .mean;
        let f32_rows = len as f64 / tiled_f32;
        report_metric("micro.kernel.f32.rows_per_s", f32_rows, "rows/s");
        report_metric("micro.kernel.f32.speedup_vs_naive", naive_f32 / tiled_f32, "x");

        let (ac, asc) = quantize_rows(&a, nt, k);
        let (bc, bsc) = quantize_rows(&b, len, k);
        let blocks = blocks_of(k);
        let naive_q8 = bench("kernel.q8.naive", opts, || {
            // The pre-kernel shape: a fresh output Vec and a per-pair
            // dot_q8 walk (test-row-major, chunk streamed nt times).
            let mut out = vec![0.0f32; nt * len];
            for t in 0..nt {
                for j in 0..len {
                    out[t * len + j] = dot_q8(
                        &ac[t * k..(t + 1) * k],
                        &asc[t * blocks..(t + 1) * blocks],
                        &bc[j * k..(j + 1) * k],
                        &bsc[j * blocks..(j + 1) * blocks],
                    );
                }
            }
            std::hint::black_box(&out);
        })
        .summary()
        .mean;
        let kernel_q8 = bench("kernel.q8.kernel", opts, || {
            let out = scratch.score_buf(nt * len);
            kernels::scan_q8_into(&ac, &asc, nt, &bc, &bsc, len, k, out);
            std::hint::black_box(&out[0]);
        })
        .summary()
        .mean;
        let q8_rows = len as f64 / kernel_q8;
        report_metric("micro.kernel.q8.rows_per_s", q8_rows, "rows/s");
        report_metric("micro.kernel.q8.speedup_vs_naive", naive_q8 / kernel_q8, "x");
        (f32_rows, q8_rows)
    };

    // Store sequential scan bandwidth.
    {
        let dir = std::env::temp_dir().join("logra-microbench-store");
        let _ = std::fs::remove_dir_all(&dir);
        let k = 192usize;
        let rows = 4096usize;
        let mut w = GradStoreWriter::create(&dir, k).unwrap();
        let mut buf = vec![0.0f32; 256 * k];
        for b in 0..(rows / 256) {
            rng.fill_normal(&mut buf, 1.0);
            let ids: Vec<u64> = (b as u64 * 256..(b as u64 + 1) * 256).collect();
            w.append(&ids, &buf).unwrap();
        }
        w.finalize().unwrap();
        let store = GradStore::open(&dir).unwrap();
        let res = bench(
            "store.scan",
            BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 20.0 },
            || {
                let mut acc = 0.0f32;
                let mut at = 0;
                while at < store.rows() {
                    let len = 512.min(store.rows() - at);
                    store.prefetch(at + len, 512.min(store.rows().saturating_sub(at + len)));
                    let c = store.chunk(at, len);
                    acc += c[0] + c[c.len() - 1];
                    at += len;
                }
                std::hint::black_box(acc);
            },
        );
        let bytes = (rows * k * 4) as f64;
        report_metric("micro.store.scan_gbps", bytes / res.summary().mean / 1e9, "GB/s");
    }

    // Sharded parallel scan: full influence queries (precondition + score
    // + top-k merge) at 1 vs N workers over the same 8-shard store.
    {
        let src = std::env::temp_dir().join("logra-microbench-shard-src");
        let _ = std::fs::remove_dir_all(&src);
        let k = 192usize;
        let rows = 8192usize;
        let mut w = GradStoreWriter::create(&src, k).unwrap();
        let mut buf = vec![0.0f32; 256 * k];
        let mut hess = BlockHessian::single_block(k);
        for b in 0..(rows / 256) {
            rng.fill_normal(&mut buf, 1.0);
            hess.accumulate(&buf, 256);
            let ids: Vec<u64> = (b as u64 * 256..(b as u64 + 1) * 256).collect();
            w.append(&ids, &buf).unwrap();
        }
        w.finalize().unwrap();
        let precond = Arc::new(hess.preconditioner(0.1).unwrap());

        let sharded_dir = std::env::temp_dir().join("logra-microbench-shard-dst");
        let _ = std::fs::remove_dir_all(&sharded_dir);
        shard_store(&src, &sharded_dir, 8).unwrap();
        let store = Arc::new(ShardedStore::open(&sharded_dir).unwrap());

        let nt = 8usize;
        let mut test = vec![0.0f32; nt * k];
        rng.fill_normal(&mut test, 1.0);
        let mut baseline = None;
        for workers in [1usize, 2, 4] {
            let engine = ParallelQueryEngine::new(
                store.clone(),
                precond.clone(),
                BackendConfig { workers, chunk_len: 512, ..Default::default() },
            );
            let res = bench(
                &format!("store.parallel_scan.w{workers}"),
                BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 30.0 },
                || {
                    let out = engine
                        .query(QueryRequest::gradients(test.clone(), nt, 10))
                        .unwrap();
                    std::hint::black_box(&out);
                },
            );
            let mean = res.summary().mean;
            let pairs = (rows * nt) as f64;
            report_metric(
                &format!("micro.store.parallel_scan.mpairs_per_s.w{workers}"),
                pairs / mean / 1e6,
                "M pairs/s",
            );
            match baseline {
                None => baseline = Some(mean),
                Some(b) => report_metric(
                    &format!("micro.store.parallel_scan.speedup.w{workers}"),
                    b / mean,
                    "x vs 1 worker",
                ),
            }
        }

        // Quantized scan + two-stage rescore vs the f32 scan — same rows,
        // same k, same queries, all single-worker so the comparison is
        // codec vs codec, not parallelism. Feeds BENCH_scan.json.
        let quant_dir = std::env::temp_dir().join("logra-microbench-shard-q8");
        let _ = std::fs::remove_dir_all(&quant_dir);
        quantize_store(&sharded_dir, &quant_dir).unwrap();
        let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
        let single = GradStore::open(&src).unwrap();
        let topk = 10usize;

        let f32_engine = QueryEngine::new_native(&single, &precond, 512);
        let f32_mean = bench(
            "store.scan_f32.seq",
            BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 30.0 },
            || {
                let out = f32_engine.query(&test, nt, topk, Normalization::None).unwrap();
                std::hint::black_box(&out);
            },
        )
        .summary()
        .mean;

        // rescore_factor 1: the smallest exact pool — effectively the pure
        // int8 coarse-scan cost.
        let mut ts_means = [0.0f64; 2];
        for (slot, factor) in [(0usize, 1usize), (1, 4)] {
            let engine = TwoStageEngine::new(
                quant.clone(),
                store.clone(),
                precond.clone(),
                BackendConfig {
                    workers: 1,
                    chunk_len: 512,
                    rescore_factor: factor,
                    ..Default::default()
                },
            )
            .unwrap();
            ts_means[slot] = bench(
                &format!("store.scan_q8.rf{factor}"),
                BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 30.0 },
                || {
                    let out = engine
                        .query(QueryRequest::gradients(test.clone(), nt, topk))
                        .unwrap();
                    std::hint::black_box(&out);
                },
            )
            .summary()
            .mean;
        }
        let (quant_mean, two_stage_mean) = (ts_means[0], ts_means[1]);

        let f32_rows_per_s = rows as f64 / f32_mean;
        let quant_rows_per_s = rows as f64 / quant_mean;
        let two_stage_rows_per_s = rows as f64 / two_stage_mean;
        report_metric("micro.store.scan_f32.rows_per_s", f32_rows_per_s, "rows/s");
        report_metric("micro.store.scan_q8.rows_per_s", quant_rows_per_s, "rows/s");
        report_metric("micro.store.two_stage.rows_per_s", two_stage_rows_per_s, "rows/s");
        report_metric(
            "micro.store.scan_q8.speedup",
            f32_mean / quant_mean,
            "x vs f32 scan",
        );

        let f32_bytes = store.storage_bytes();
        let q8_bytes = quant.storage_bytes();
        report_metric(
            "micro.store.q8.compression",
            f32_bytes as f64 / q8_bytes as f64,
            "x smaller",
        );

        // Persistent scan pool under concurrent query admission: queries/s
        // at concurrency 1, 4, 8 on one warm 4-worker pool, vs the
        // per-query thread-spawn path at concurrency 8 with the SAME
        // worker count. The pool amortizes spawn cost and interleaves
        // shard tasks, so pool-at-c8 should meet or beat spawn-at-c8.
        let pool_workers = 4usize;
        let queries_per_client = 6usize;
        let pool = Arc::new(ScanPool::spawn(pool_workers));
        let pooled = Arc::new(ParallelQueryEngine::new(
            store.clone(),
            precond.clone(),
            BackendConfig { chunk_len: 512, pool: Some(pool.clone()), ..Default::default() },
        ));
        // Sanity (and warmup): pooled results are bit-identical to the
        // sequential scan, so the throughput numbers measure the real
        // serving path.
        {
            let want = f32_engine.query(&test, nt, topk, Normalization::None).unwrap();
            let got = pooled.query(QueryRequest::gradients(test.clone(), nt, topk)).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.top, b.top, "pooled scan diverged from sequential");
            }
        }
        let run_clients = |engine: &Arc<ParallelQueryEngine>, clients: usize| -> f64 {
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for _ in 0..clients {
                    let engine = engine.clone();
                    let test = &test;
                    s.spawn(move || {
                        for _ in 0..queries_per_client {
                            let out = engine
                                .query(QueryRequest::gradients(test.clone(), nt, topk))
                                .unwrap();
                            std::hint::black_box(&out);
                        }
                    });
                }
            });
            (clients * queries_per_client) as f64 / t0.elapsed().as_secs_f64()
        };
        // Each concurrency level runs on its own engine with a fresh
        // Metrics attached, so the gated pool qps numbers include the
        // observability overhead (histograms + trace spans) that the real
        // serving path pays, and so the c8 latency percentiles below come
        // from exactly that run's histogram.
        let mut pool_qps = [0.0f64; 3];
        let mut pool_c8_p50_ms = 0.0f64;
        let mut pool_c8_p99_ms = 0.0f64;
        for (slot, conc) in [(0usize, 1usize), (1, 4), (2, 8)] {
            let metrics = Arc::new(Metrics::default());
            let observed = Arc::new(ParallelQueryEngine::new(
                store.clone(),
                precond.clone(),
                BackendConfig {
                    chunk_len: 512,
                    pool: Some(pool.clone()),
                    metrics: Some(metrics.clone()),
                    ..Default::default()
                },
            ));
            pool_qps[slot] = run_clients(&observed, conc);
            report_metric(
                &format!("micro.store.pool.qps.c{conc}"),
                pool_qps[slot],
                "queries/s",
            );
            if conc == 8 {
                let snap = metrics.obs.query_latency.snapshot();
                pool_c8_p50_ms = snap.percentile_ms(50.0);
                pool_c8_p99_ms = snap.percentile_ms(99.0);
                report_metric("micro.store.pool.p50_ms.c8", pool_c8_p50_ms, "ms");
                report_metric("micro.store.pool.p99_ms.c8", pool_c8_p99_ms, "ms");
            }
        }
        let spawned = Arc::new(ParallelQueryEngine::new(
            store.clone(),
            precond.clone(),
            BackendConfig { workers: pool_workers, chunk_len: 512, ..Default::default() },
        ));
        let spawn_qps_c8 = run_clients(&spawned, 8);
        report_metric("micro.store.spawn.qps.c8", spawn_qps_c8, "queries/s");
        report_metric(
            "micro.store.pool.speedup_vs_spawn.c8",
            pool_qps[2] / spawn_qps_c8,
            "x vs per-query spawn",
        );
        let pool_snap = pool.snapshot();
        report_metric(
            "micro.store.pool.busy_seconds",
            pool_snap.total_busy_seconds(),
            "s",
        );
        pool.shutdown();

        // IVF stage-0 probe: query throughput at nprobe 4/16 on the same
        // corpus and queries as the scans above, plus the full-probe
        // bit-identity bit the CI gate holds at 1.0.
        build_index(&quant_dir, 16, 42).unwrap();
        let index = Arc::new(IvfIndex::open(&quant_dir, &quant).unwrap());
        let ivf_cfg = |nprobe: usize| BackendConfig {
            workers: 1,
            chunk_len: 512,
            rescore_factor: 4,
            nprobe,
            ..Default::default()
        };
        let ivf = IvfEngine::new(
            quant.clone(),
            index.clone(),
            store.clone(),
            precond.clone(),
            ivf_cfg(4),
        )
        .unwrap();
        let ann_mean = bench(
            "store.scan_ivf.np4",
            BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 30.0 },
            || {
                let out = ivf.query(QueryRequest::gradients(test.clone(), nt, topk)).unwrap();
                std::hint::black_box(&out);
            },
        )
        .summary()
        .mean;
        let ann_rows_per_s = rows as f64 / ann_mean;
        report_metric("micro.store.ivf.rows_per_s", ann_rows_per_s, "rows/s at np4/16");
        report_metric("micro.store.ivf.speedup_vs_two_stage", two_stage_mean / ann_mean, "x");

        let full = IvfEngine::new(
            quant.clone(),
            index.clone(),
            store.clone(),
            precond.clone(),
            ivf_cfg(16),
        )
        .unwrap();
        let two = TwoStageEngine::new(
            quant.clone(),
            store.clone(),
            precond.clone(),
            ivf_cfg(16),
        )
        .unwrap();
        let want = two.query(QueryRequest::gradients(test.clone(), nt, topk)).unwrap();
        let got = full.query(QueryRequest::gradients(test.clone(), nt, topk)).unwrap();
        let identical = got.iter().zip(&want).all(|(a, b)| a.top == b.top);
        let ann_full_probe_bitident = if identical { 1.0f64 } else { 0.0 };
        report_metric("micro.store.ivf.full_probe_bitident", ann_full_probe_bitident, "1=yes");

        // Recall@10 at nprobe 2/8 on a CLUSTERED corpus vs the exact scan
        // — the geometry IVF exists for; the gaussian corpus above has no
        // cluster structure a pruned probe could respect.
        let ann_recall_at_10 = {
            let csrc = std::env::temp_dir().join("logra-microbench-ivf-src");
            let _ = std::fs::remove_dir_all(&csrc);
            let ck = 32usize;
            let centers = 8usize;
            let per_center = 100usize;
            let mut cvecs = vec![0.0f32; centers * ck];
            rng.fill_normal(&mut cvecs, 4.0);
            let mut w = GradStoreWriter::create(&csrc, ck).unwrap();
            let mut row = vec![0.0f32; ck];
            let mut noise = vec![0.0f32; ck];
            for c in 0..centers {
                for r in 0..per_center {
                    rng.fill_normal(&mut noise, 0.2);
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = cvecs[c * ck + j] + noise[j];
                    }
                    w.append(&[(c * per_center + r) as u64], &row).unwrap();
                }
            }
            w.finalize().unwrap();
            let csharded = std::env::temp_dir().join("logra-microbench-ivf-sharded");
            let _ = std::fs::remove_dir_all(&csharded);
            shard_store(&csrc, &csharded, 2).unwrap();
            let cquant = std::env::temp_dir().join("logra-microbench-ivf-q8");
            let _ = std::fs::remove_dir_all(&cquant);
            quantize_store(&csharded, &cquant).unwrap();
            build_index(&cquant, centers, 42).unwrap();
            let cexact = Arc::new(ShardedStore::open(&csharded).unwrap());
            let cq = Arc::new(QuantShardedStore::open(&cquant).unwrap());
            let cindex = Arc::new(IvfIndex::open(&cquant, &cq).unwrap());
            // Near-isotropic preconditioner so the cluster geometry
            // survives preconditioning.
            let mut iso = vec![0.0f32; 256 * ck];
            rng.fill_normal(&mut iso, 1.0);
            let mut ch = BlockHessian::single_block(ck);
            ch.accumulate(&iso, 256);
            let cprecond = Arc::new(ch.preconditioner(0.1).unwrap());
            let reference = ParallelQueryEngine::new(
                cexact.clone(),
                cprecond.clone(),
                BackendConfig { chunk_len: 512, ..Default::default() },
            );
            let pruned = IvfEngine::new(
                cq,
                cindex,
                cexact,
                cprecond,
                BackendConfig {
                    chunk_len: 512,
                    rescore_factor: 4,
                    nprobe: 2,
                    ..Default::default()
                },
            )
            .unwrap();
            let mut hits = 0usize;
            let mut total = 0usize;
            for c in 0..centers {
                for _ in 0..2 {
                    rng.fill_normal(&mut noise, 0.2);
                    let q: Vec<f32> =
                        (0..ck).map(|j| cvecs[c * ck + j] + noise[j]).collect();
                    let exact10 =
                        reference.query(QueryRequest::gradients(q.clone(), 1, 10)).unwrap();
                    let ivf10 = pruned.query(QueryRequest::gradients(q, 1, 10)).unwrap();
                    let want_ids: Vec<u64> =
                        exact10[0].top.iter().map(|&(_, id)| id).collect();
                    hits += ivf10[0].top.iter().filter(|&&(_, id)| want_ids.contains(&id)).count();
                    total += 10;
                }
            }
            hits as f64 / total as f64
        };
        report_metric("micro.store.ivf.recall_at_10", ann_recall_at_10, "frac at np2/8");

        // Multi-stage session fan-out: TWO stages over one shared pool,
        // one query scored against both concurrently, vs the same two
        // stage queries run back-to-back through the identical session
        // machinery. The fan-out interleaves both stages' shard tasks on
        // the shared workers, so it should beat sequential. Feeds the
        // gated `session_2stage_qps` key.
        let session_2stage_qps = {
            let sess_dir = std::env::temp_dir().join("logra-microbench-session");
            let _ = std::fs::remove_dir_all(&sess_dir);
            SessionManifest {
                version: SESSION_VERSION,
                stages: vec![
                    stage_spec("a", sharded_dir.clone()),
                    stage_spec("b", sharded_dir.clone()),
                ],
            }
            .save(&sess_dir)
            .unwrap();
            let session = Session::open(
                &sess_dir,
                SessionConfig { combine: Combine::WeightedSum, workers: 4 },
            )
            .unwrap();
            let opts = BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 30.0 };
            let fan_mean = bench("session.2stage.fanout", opts, || {
                let out = session
                    .query(QueryRequest::gradients(test.clone(), nt, topk))
                    .unwrap();
                std::hint::black_box(&out);
            })
            .summary()
            .mean;
            let subsets = [vec!["a".to_string()], vec!["b".to_string()]];
            let seq_mean = bench("session.2stage.sequential", opts, || {
                for subset in &subsets {
                    let out = session
                        .query_stages(
                            QueryRequest::gradients(test.clone(), nt, topk),
                            Some(subset.as_slice()),
                        )
                        .unwrap();
                    std::hint::black_box(&out);
                }
            })
            .summary()
            .mean;
            let qps = 1.0 / fan_mean;
            report_metric("micro.session.2stage.qps", qps, "queries/s");
            report_metric(
                "micro.session.2stage.speedup_vs_sequential",
                seq_mean / fan_mean,
                "x vs back-to-back stages",
            );
            session.shutdown();
            qps
        };

        let json = format!(
            "{{\n  \"rows\": {rows},\n  \"k\": {k},\n  \"nt\": {nt},\n  \"topk\": {topk},\n  \
             \"kernel_arm\": \"{}\",\n  \
             \"kernel_f32_rows_per_s\": {kernel_f32_rows_per_s:.1},\n  \
             \"kernel_q8_rows_per_s\": {kernel_q8_rows_per_s:.1},\n  \
             \"f32_rows_per_s\": {f32_rows_per_s:.1},\n  \
             \"quant_rows_per_s\": {quant_rows_per_s:.1},\n  \
             \"two_stage_rows_per_s\": {two_stage_rows_per_s:.1},\n  \
             \"ann_rows_per_s\": {ann_rows_per_s:.1},\n  \
             \"ann_recall_at_10\": {ann_recall_at_10:.4},\n  \
             \"ann_full_probe_bitident\": {ann_full_probe_bitident:.1},\n  \
             \"quant_speedup_vs_f32\": {:.3},\n  \
             \"f32_storage_bytes\": {f32_bytes},\n  \
             \"quant_storage_bytes\": {q8_bytes},\n  \
             \"compression_ratio\": {:.3},\n  \
             \"pool_workers\": {pool_workers},\n  \
             \"pool_c1_qps\": {:.1},\n  \
             \"pool_c4_qps\": {:.1},\n  \
             \"pool_c8_qps\": {:.1},\n  \
             \"pool_c8_p50_ms\": {pool_c8_p50_ms:.3},\n  \
             \"pool_c8_p99_ms\": {pool_c8_p99_ms:.3},\n  \
             \"session_2stage_qps\": {session_2stage_qps:.1},\n  \
             \"spawn_c8_qps\": {spawn_qps_c8:.1}\n}}\n",
            logra::linalg::kernel_arm().name(),
            f32_mean / quant_mean,
            f32_bytes as f64 / q8_bytes as f64,
            pool_qps[0],
            pool_qps[1],
            pool_qps[2],
        );
        std::fs::write("BENCH_scan.json", &json).unwrap();
        println!("wrote BENCH_scan.json");
    }

    // Top-k under a firehose of scores.
    {
        let scores: Vec<f64> = (0..1_000_000).map(|_| rng.normal()).collect();
        let res = bench(
            "topk.1M",
            BenchOpts { warmup_iters: 1, iters: 10, max_seconds: 20.0 },
            || {
                let mut tk = TopK::new(10);
                for (i, &s) in scores.iter().enumerate() {
                    tk.push(s, i as u64);
                }
                std::hint::black_box(tk.into_sorted());
            },
        );
        report_metric(
            "micro.topk.melem_per_s",
            1.0 / res.summary().mean,
            "M elems/s",
        );
    }
}
