//! Bench: Table 1 — LoGra vs EKFAC logging & influence efficiency.
//!
//! `cargo bench --bench table1_efficiency` (env LOGRA_BENCH_CONFIG /
//! LOGRA_BENCH_NTRAIN override the defaults; lm_small reproduces the
//! paper-shaped gap at larger cost).

use logra::eval::table1::{run_table1, TABLE1_HEADER};
use logra::util::bench::report_metric;

fn main() {
    let root = std::env::current_dir().expect("cwd");
    if !root.join("artifacts").join("lm_tiny").join("manifest.txt").exists() {
        eprintln!("table1 bench skipped: run `make artifacts` first");
        return;
    }
    let config = std::env::var("LOGRA_BENCH_CONFIG").unwrap_or_else(|_| "lm_tiny".into());
    let n_train: usize = std::env::var("LOGRA_BENCH_NTRAIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(384);
    let n_test: usize = 4;
    println!("== Table 1 reproduction ({config}, n_train={n_train}) ==");
    let rows = run_table1(&root, &config, n_train, n_test, 4).expect("table1");
    println!("{TABLE1_HEADER}");
    for r in &rows {
        println!("{}", r.render());
    }
    // Machine-readable headline: throughput ratio (paper: up to 6,500x).
    let logra_inf = rows
        .iter()
        .find(|r| r.system == "LoGra" && r.phase == "influence")
        .unwrap();
    let ekfac_inf = rows
        .iter()
        .find(|r| r.system == "EKFAC" && r.phase == "influence")
        .unwrap();
    report_metric("table1.logra_influence", logra_inf.throughput, "pairs_per_s");
    report_metric("table1.ekfac_influence", ekfac_inf.throughput, "pairs_per_s");
    report_metric(
        "table1.influence_speedup",
        logra_inf.throughput / ekfac_inf.throughput,
        "x",
    );
    let logra_log = rows.iter().find(|r| r.system == "LoGra" && r.phase == "logging").unwrap();
    let ekfac_log = rows.iter().find(|r| r.system == "EKFAC" && r.phase == "logging").unwrap();
    report_metric("table1.logra_logging", logra_log.throughput, "tokens_per_s");
    report_metric("table1.ekfac_logging", ekfac_log.throughput, "tokens_per_s");
    assert!(
        logra_inf.throughput > ekfac_inf.throughput,
        "Table-1 shape violated: LoGra influence not faster than EKFAC"
    );
}
