//! Quickstart: the whole valuation loop in ~60 lines.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Generates a tiny topic-labelled corpus, trains the tiny LM briefly,
//! logs projected gradients for every training document (LoGra), fits the
//! projected Fisher, and asks: "which training documents are most
//! valuable for this query?"

use std::sync::Arc;

use anyhow::Result;
use logra::coordinator::{projected_grads, run_logging, LoggingOptions};
use logra::data::corpus::{generate, CorpusSpec, TOPIC_NAMES};
use logra::hessian::random_projections;
use logra::model::dataset::Dataset;
use logra::model::trainer::Trainer;
use logra::runtime::Runtime;
use logra::util::rng::Pcg32;
use logra::valuation::{Normalization, QueryRequest, Valuator};

fn main() -> Result<()> {
    let root = std::env::current_dir()?;
    let rt = Runtime::open_named(&root, "lm_tiny")?;
    let man = rt.manifest.clone();

    // 1. Data: 256 synthetic documents with ground-truth topics.
    let corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, 256, 42));
    let ds = Dataset::Lm(&corpus);

    // 2. Train the model for a couple of epochs.
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(0)?;
    let all: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Pcg32::seeded(1);
    let losses = trainer.train(&mut st, &ds, &all, 3, &mut rng)?;
    println!("train loss per epoch: {losses:?}");

    // 3. Logging phase: projected gradients for ALL train docs -> disk,
    //    projected Fisher accumulated inline.
    let proj = random_projections(&man, &mut rng);
    let store_dir = root.join("runs").join("quickstart-store");
    let (store, hessian, report) =
        run_logging(&rt, &ds, &st.params, &proj, &store_dir, &LoggingOptions::default())?;
    println!(
        "logged {} rows at {:.0} tokens/s ({} on disk)",
        report.rows,
        report.tokens_per_sec,
        logra::util::memory::human_bytes(report.storage_bytes)
    );

    // 4. Query: value training docs for a held-out document. One facade
    //    call opens the fabric (codec auto-detected) and serves top-k.
    drop(store);
    let precond = Arc::new(hessian.unwrap().preconditioner(0.1)?);
    let valuator = Valuator::open(&store_dir)?
        .preconditioner(precond)
        .normalization(Normalization::RelatIf)
        .build()?;
    let query_corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, 4, 777));
    let qds = Dataset::Lm(&query_corpus);
    let (g, _) = projected_grads(&rt, &qds, &[0, 1, 2, 3], &st.params, &proj)?;
    let results = valuator.query(QueryRequest::gradients(g, 4, 5))?;
    for (qi, res) in results.iter().enumerate() {
        let qt = query_corpus.docs[qi].topic;
        println!("\nquery {qi} (topic {}):", TOPIC_NAMES[qt]);
        for &(score, id) in &res.top {
            let doc = &corpus.docs[id as usize];
            println!(
                "  [{score:+.3}] doc {id} (topic {}) {}",
                TOPIC_NAMES[doc.topic],
                corpus.render(&doc.tokens[..12])
            );
        }
    }
    Ok(())
}
