//! Figure-5 demo: value MODEL GENERATIONS against the training corpus and
//! print the most valuable documents (ℓ-RelatIF), with the measurable
//! topic-match statistic the synthetic corpus enables.
//!
//! ```text
//! cargo run --release --example qualitative [-- --n-train 512 --epochs 6]
//! ```

use anyhow::Result;
use logra::eval::qualitative::{render, run_qualitative};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = logra::cli::parse(&args, &["n-train", "epochs", "topk", "config"])?;
    let root = std::env::current_dir()?;
    let out = run_qualitative(
        &root,
        &parsed.flag_or("config", "lm_tiny"),
        parsed.usize_or("n-train", 512)?,
        8,
        parsed.usize_or("topk", 4)?,
        parsed.usize_or("epochs", 6)?,
    )?;
    println!("{}", render(&out));
    anyhow::ensure!(
        out.topic_match_rate > out.chance_rate,
        "retrieval should beat chance"
    );
    Ok(())
}
