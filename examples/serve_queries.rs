//! Serving demo: the dynamic-batching valuation service under concurrent
//! load (Figure 1's test-time path as an online service).
//!
//! ```text
//! cargo run --release --example serve_queries [-- --clients 4 --requests 32]
//!
//! # Interleaved serving on the persistent scan pool: shard the store,
//! # give the pool 4 warm workers, and admit up to 4 query batches whose
//! # shard tasks interleave (no head-of-line blocking on a large query):
//! cargo run --release --example serve_queries -- \
//!     --clients 8 --shards 4 --scan-workers 4 --concurrency 4
//! ```
//!
//! Reports per-request latency percentiles, sustained throughput, the
//! dynamic batcher's mean batch fill, and (when a pool is active) the scan
//! pool's worker/task counters.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use logra::coordinator::{run_logging, LoggingOptions, ServiceConfig, ValuationService};
use logra::data::corpus::{generate, CorpusSpec};
use logra::hessian::random_projections;
use logra::model::dataset::Dataset;
use logra::model::trainer::Trainer;
use logra::runtime::Runtime;
use logra::util::rng::Pcg32;
use logra::util::stats::{percentile, summarize};
use logra::valuation::Normalization;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = logra::cli::parse(
        &args,
        &[
            "clients",
            "requests",
            "n-train",
            "shards",
            "scan-workers",
            "rescore-factor",
            "concurrency",
        ],
    )?;
    let n_clients = parsed.usize_or("clients", 4)?;
    let n_requests = parsed.usize_or("requests", 24)?;
    let n_train = parsed.usize_or("n-train", 512)?;
    let n_shards = parsed.usize_or("shards", 1)?;
    let scan_workers = parsed.usize_or("scan-workers", 1)?;
    // `--quantized` serves the two-stage path: int8 coarse scan over a
    // quantized copy, exact rescore of rescore_factor x topk candidates.
    let quantized = parsed.has_switch("quantized");
    let rescore_factor = parsed.usize_or("rescore-factor", 4)?;
    // `--concurrency N`: query batches admitted to the scan pool before
    // the batcher blocks — N > 1 interleaves batches' shard tasks on the
    // pool's warm workers.
    let concurrency = parsed.usize_or("concurrency", 2)?;

    let root = std::env::current_dir()?;
    let artifact_dir = root.join("artifacts").join("lm_tiny");
    let rt = Runtime::open(&artifact_dir)?;
    let man = rt.manifest.clone();

    // Prepare model + store (offline phase).
    let corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, n_train, 42));
    let ds = Dataset::Lm(&corpus);
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(0)?;
    let all: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Pcg32::seeded(1);
    trainer.train(&mut st, &ds, &all, 2, &mut rng)?;
    let proj = random_projections(&man, &mut rng);
    let store_dir = root.join("runs").join("serve-store");
    let (store, hessian, _) =
        run_logging(&rt, &ds, &st.params, &proj, &store_dir, &LoggingOptions::default())?;
    println!("store ready: {} rows", store.rows());
    drop(store);
    drop(rt);

    // Optionally reshard the store so the parallel engine has shards to
    // fan out over (`--shards 4 --scan-workers 4`).
    let store_dir = if n_shards > 1 {
        let sharded = root.join("runs").join("serve-store-sharded");
        let _ = std::fs::remove_dir_all(&sharded);
        let man = logra::store::shard_store(&store_dir, &sharded, n_shards)?;
        println!("resharded into {} shards", man.n_shards());
        sharded
    } else {
        store_dir
    };

    // Optionally quantize the (possibly resharded) store so the service
    // can run the two-stage int8-scan + exact-rescore path.
    let quant_dir = if quantized {
        let qdir = root.join("runs").join("serve-store-q8");
        let _ = std::fs::remove_dir_all(&qdir);
        let man = logra::store::quantize_store(&store_dir, &qdir)?;
        println!("quantized copy ready ({} rows, int8 codec)", man.total_rows());
        Some(qdir)
    } else {
        None
    };

    // Online phase: spawn the service, hammer it from client threads.
    let svc = Arc::new(ValuationService::spawn(ServiceConfig {
        artifact_dir,
        store_dir,
        params: st.params.clone(),
        proj_flat: proj,
        hessian: hessian.unwrap(),
        damping: 0.1,
        norm: Normalization::RelatIf,
        max_wait: Duration::from_millis(4),
        scan_workers,
        quantized_scan: quantized,
        rescore_factor,
        quant_dir,
        max_in_flight: concurrency.max(1),
    })?);

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let svc2 = svc.clone();
        let queries: Vec<Vec<i32>> = (0..n_requests)
            .map(|q| corpus.docs[(c * 37 + q * 13) % corpus.docs.len()].tokens.clone())
            .collect();
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::new();
            for q in queries {
                let t = Instant::now();
                let res = svc2.query(q, 5).expect("query failed");
                assert_eq!(res.top.len(), 5);
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut latencies = Vec::new();
    for h in handles {
        latencies.extend(h.join().expect("client thread"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = summarize(&latencies);
    let snap = svc.metrics.snapshot();
    println!("\n-- serving report --");
    if let Some(kind) = svc.backend_kind() {
        println!("scan backend       {}", kind.name());
    }
    println!("requests           {}", latencies.len());
    println!("throughput         {:.1} req/s", latencies.len() as f64 / wall);
    println!(
        "latency mean/p50/p95/p99  {:.1} / {:.1} / {:.1} / {:.1} ms",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        percentile(&latencies, 99.0) * 1e3
    );
    println!("batches            {} (mean fill {:.2})", snap.batches, snap.mean_batch_fill());
    println!(
        "scan throughput    {:.0} (train,test) pairs/s",
        snap.pairs_per_sec(1)
    );
    println!(
        "worker time        grad {:.3}s  scan {:.3}s",
        snap.grad_seconds, snap.scan_seconds
    );
    if snap.shards_scanned > 0 {
        println!(
            "parallel scan      {} shard scans, concurrency {:.2}x",
            snap.shards_scanned,
            snap.scan_concurrency()
        );
    }
    if snap.candidates_rescored > 0 {
        println!(
            "two-stage scan     stage1 {:.3}s  stage2 {:.3}s  rescored {} rows ({:.2}% of scanned)",
            snap.stage1_seconds,
            snap.stage2_seconds,
            snap.candidates_rescored,
            snap.rescore_fraction() * 100.0
        );
    }
    if let Some(pool) = svc.scan_pool() {
        let ps = pool.snapshot();
        println!(
            "scan pool          {} workers (actual), {} queries admitted, \
             {} tasks done ({} failed), busy {:.3}s, queue depth {}",
            ps.workers,
            ps.queries_submitted,
            ps.tasks_completed,
            ps.tasks_failed,
            ps.total_busy_seconds(),
            ps.queue_depth
        );
    }
    Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    Ok(())
}
