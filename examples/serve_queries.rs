//! Serving demo: the dynamic-batching valuation service under concurrent
//! load (Figure 1's test-time path as an online service).
//!
//! ```text
//! cargo run --release --example serve_queries [-- --clients 4 --requests 32]
//!
//! # Interleaved serving on the persistent scan pool: shard the store,
//! # give the pool 4 warm workers, and admit up to 4 query batches whose
//! # shard tasks interleave (no head-of-line blocking on a large query):
//! cargo run --release --example serve_queries -- \
//!     --clients 8 --shards 4 --scan-workers 4 --concurrency 4
//! ```
//!
//! Reports per-request latency percentiles, sustained throughput, the
//! dynamic batcher's mean batch fill, and (when a pool is active) the scan
//! pool's worker/task counters.
//!
//! Observability flags (both modes):
//!   --metrics            print the Prometheus text exposition at the end
//!   --metrics-out FILE   write the exposition to FILE
//!   --trace-out FILE     write the span ring as Chrome trace-event JSON
//!
//! `--offline` skips the PJRT runtime entirely: it synthesizes a gradient
//! store on disk (optionally sharded / quantized) and serves it through
//! the [`Valuator`] facade on a warm scan pool — the shape CI uses to
//! validate the exposition and trace without artifacts.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use logra::coordinator::{run_logging, LoggingOptions, Metrics, ServiceConfig, ValuationService};
use logra::data::corpus::{generate, CorpusSpec};
use logra::hessian::random_projections;
use logra::model::dataset::Dataset;
use logra::model::trainer::Trainer;
use logra::obs::{chrome_trace_json, render_exposition};
use logra::runtime::Runtime;
use logra::util::rng::Pcg32;
use logra::util::stats::{percentile, summarize};
use logra::valuation::{
    Backend, Normalization, PoolMode, QueryRequest, ScanBackend, Valuator,
};

/// Write/print the exposition and trace per the shared observability
/// flags. `extra_gauges` carries store-shape context into the exposition.
fn emit_observability(
    parsed: &logra::cli::Args,
    metrics: &Metrics,
    pool: Option<logra::valuation::PoolSnapshot>,
    extra_gauges: &[(&str, &str, f64)],
) -> Result<()> {
    let expo = render_exposition(metrics, pool.as_ref(), extra_gauges);
    if let Some(path) = parsed.flag("metrics-out") {
        std::fs::write(path, &expo)?;
        println!("wrote exposition -> {path}");
    }
    if parsed.has_switch("metrics") {
        println!("\n-- metrics exposition --");
        print!("{expo}");
    }
    if let Some(path) = parsed.flag("trace-out") {
        let events = metrics.obs.trace.events();
        std::fs::write(path, chrome_trace_json(&events))?;
        println!("wrote {} span events -> {path}", events.len());
    }
    Ok(())
}

/// Runtime-free serving: synthesize a store, serve it via the Valuator on
/// a pooled backend, and report the same latency/exposition surface.
fn run_offline(parsed: &logra::cli::Args) -> Result<()> {
    let n_requests = parsed.usize_or("requests", 24)?;
    let n_train = parsed.usize_or("n-train", 256)?;
    let n_shards = parsed.usize_or("shards", 1)?;
    let scan_workers = parsed.usize_or("scan-workers", 1)?;
    let quantized = parsed.has_switch("quantized");
    let rescore_factor = parsed.usize_or("rescore-factor", 4)?;
    let n_clients =
        parsed.usize_or("clients", 4)?.max(parsed.usize_or("concurrency", 1)?).max(1);
    let k = 64usize;

    // Synthetic store fabric (no runtime, no artifacts).
    let root = std::env::current_dir()?;
    let base = root.join("runs").join("serve-offline-store");
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base)?;
    let mut rng = Pcg32::seeded(0x0FF1);
    let mut rows = vec![0.0f32; n_train * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n_train as u64).collect();
    let mut w = logra::store::GradStoreWriter::create(&base, k)?;
    w.append(&ids, &rows)?;
    w.finalize()?;
    let store_dir = if n_shards > 1 {
        let sharded = root.join("runs").join("serve-offline-sharded");
        let _ = std::fs::remove_dir_all(&sharded);
        logra::store::shard_store(&base, &sharded, n_shards)?;
        sharded
    } else {
        base
    };
    let store_dir = if quantized {
        let qdir = root.join("runs").join("serve-offline-q8");
        let _ = std::fs::remove_dir_all(&qdir);
        logra::store::quantize_store(&store_dir, &qdir)?;
        qdir
    } else {
        store_dir
    };
    println!("offline store ready: {n_train} rows, k={k}, {n_shards} shards");

    let metrics = Arc::new(Metrics::default());
    let backend =
        if quantized { Backend::Quantized { rescore_factor } } else { Backend::Auto };
    let valuator = Valuator::open(&store_dir)?
        .backend(backend)
        .workers(scan_workers)
        .fit_from_store(0.1)
        .pool(PoolMode::Auto)
        .metrics(metrics.clone())
        .build()?;
    println!("scan backend       {}", valuator.kind().name());

    // Hammer the valuator from client threads; each query reuses a stored
    // row as its gradient (the store-only query shape). A failed query
    // counts against its client instead of killing the thread — the
    // summary reports per-client error counts.
    let t0 = Instant::now();
    let vref = &valuator;
    let outcomes: Vec<(Vec<f64>, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|c| {
                s.spawn(move || -> (Vec<f64>, usize) {
                    let mut lat = Vec::new();
                    let mut errors = 0usize;
                    for q in 0..n_requests {
                        let row = (c * 37 + q * 13) % n_train;
                        let g = vref.gradient_row(row).expect("row in range");
                        let t = Instant::now();
                        match vref.query(QueryRequest::gradients(g, 1, 5)) {
                            Ok(res) if res[0].top.len() == 5.min(n_train) => {
                                lat.push(t.elapsed().as_secs_f64());
                            }
                            Ok(res) => {
                                eprintln!(
                                    "client {c} query {q}: expected {} results, got {}",
                                    5.min(n_train),
                                    res[0].top.len()
                                );
                                errors += 1;
                            }
                            Err(e) => {
                                eprintln!("client {c} query {q}: {e}");
                                errors += 1;
                            }
                        }
                    }
                    (lat, errors)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut latencies = Vec::new();
    let mut per_client_errors = Vec::with_capacity(n_clients);
    for (lat, errors) in outcomes {
        latencies.extend(lat);
        per_client_errors.push(errors);
    }
    let n_errors: usize = per_client_errors.iter().sum();
    let s = summarize(&latencies);
    println!("\n-- serving report (offline) --");
    println!("requests           {} ok / {} errors", latencies.len(), n_errors);
    if n_errors > 0 {
        println!("per-client errors  {per_client_errors:?}");
    }
    println!("throughput         {:.1} req/s", latencies.len() as f64 / wall);
    println!(
        "latency mean/p50/p95/p99  {:.1} / {:.1} / {:.1} / {:.1} ms",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        percentile(&latencies, 99.0) * 1e3
    );
    let lat = metrics.obs.query_latency.snapshot();
    println!(
        "histogram p50/p95/p99     {:.1} / {:.1} / {:.1} ms ({} samples)",
        lat.percentile_ms(50.0),
        lat.percentile_ms(95.0),
        lat.percentile_ms(99.0),
        lat.count
    );
    if let Some(pool) = valuator.scan_pool() {
        let ps = pool.snapshot();
        println!(
            "scan pool          {} workers, {} queries, {} tasks, busy {:.3}s",
            ps.workers,
            ps.queries_submitted,
            ps.tasks_completed,
            ps.total_busy_seconds()
        );
    }
    let pool_snap = valuator.scan_pool().map(|p| p.snapshot());
    emit_observability(
        parsed,
        &metrics,
        pool_snap,
        &[
            ("logra_store_rows", "Rows in the served store.", n_train as f64),
            ("logra_store_k", "Projected gradient dimension.", k as f64),
        ],
    )?;
    valuator.shutdown();
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = logra::cli::parse(
        &args,
        &[
            "clients",
            "requests",
            "n-train",
            "shards",
            "scan-workers",
            "rescore-factor",
            "concurrency",
            "metrics-out",
            "trace-out",
        ],
    )?;
    if parsed.has_switch("offline") {
        return run_offline(&parsed);
    }
    let n_clients = parsed.usize_or("clients", 4)?;
    let n_requests = parsed.usize_or("requests", 24)?;
    let n_train = parsed.usize_or("n-train", 512)?;
    let n_shards = parsed.usize_or("shards", 1)?;
    let scan_workers = parsed.usize_or("scan-workers", 1)?;
    // `--quantized` serves the two-stage path: int8 coarse scan over a
    // quantized copy, exact rescore of rescore_factor x topk candidates.
    let quantized = parsed.has_switch("quantized");
    let rescore_factor = parsed.usize_or("rescore-factor", 4)?;
    // `--concurrency N`: query batches admitted to the scan pool before
    // the batcher blocks — N > 1 interleaves batches' shard tasks on the
    // pool's warm workers.
    let concurrency = parsed.usize_or("concurrency", 2)?;

    let root = std::env::current_dir()?;
    let artifact_dir = root.join("artifacts").join("lm_tiny");
    let rt = Runtime::open(&artifact_dir)?;
    let man = rt.manifest.clone();

    // Prepare model + store (offline phase).
    let corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, n_train, 42));
    let ds = Dataset::Lm(&corpus);
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(0)?;
    let all: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Pcg32::seeded(1);
    trainer.train(&mut st, &ds, &all, 2, &mut rng)?;
    let proj = random_projections(&man, &mut rng);
    let store_dir = root.join("runs").join("serve-store");
    let (store, hessian, _) =
        run_logging(&rt, &ds, &st.params, &proj, &store_dir, &LoggingOptions::default())?;
    let store_rows = store.rows();
    println!("store ready: {store_rows} rows");
    drop(store);
    drop(rt);

    // Optionally reshard the store so the parallel engine has shards to
    // fan out over (`--shards 4 --scan-workers 4`).
    let store_dir = if n_shards > 1 {
        let sharded = root.join("runs").join("serve-store-sharded");
        let _ = std::fs::remove_dir_all(&sharded);
        let man = logra::store::shard_store(&store_dir, &sharded, n_shards)?;
        println!("resharded into {} shards", man.n_shards());
        sharded
    } else {
        store_dir
    };

    // Optionally quantize the (possibly resharded) store so the service
    // can run the two-stage int8-scan + exact-rescore path. The service
    // opens whatever fabric `store_dir` holds, so point it at the
    // quantized copy (its manifest records the f32 rescore companion).
    let (store_dir, backend) = if quantized {
        let qdir = root.join("runs").join("serve-store-q8");
        let _ = std::fs::remove_dir_all(&qdir);
        let man = logra::store::quantize_store(&store_dir, &qdir)?;
        println!("quantized copy ready ({} rows, int8 codec)", man.total_rows());
        (qdir, Backend::Quantized { rescore_factor })
    } else {
        (store_dir, Backend::Auto)
    };

    // Online phase: spawn the service, hammer it from client threads.
    let svc = Arc::new(ValuationService::spawn(ServiceConfig {
        artifact_dir,
        store_dir,
        params: st.params.clone(),
        proj_flat: proj,
        hessian: hessian.unwrap(),
        damping: 0.1,
        norm: Normalization::RelatIf,
        max_wait: Duration::from_millis(4),
        scan_workers,
        backend,
        max_in_flight: concurrency.max(1),
    })?);

    // A failed query counts against its client instead of killing the
    // thread — the summary reports per-client error counts.
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let svc2 = svc.clone();
        let queries: Vec<Vec<i32>> = (0..n_requests)
            .map(|q| corpus.docs[(c * 37 + q * 13) % corpus.docs.len()].tokens.clone())
            .collect();
        handles.push(std::thread::spawn(move || -> (Vec<f64>, usize) {
            let mut lat = Vec::new();
            let mut errors = 0usize;
            for (q, tokens) in queries.into_iter().enumerate() {
                let t = Instant::now();
                match svc2.query(tokens, 5) {
                    Ok(res) if res.top.len() == 5 => lat.push(t.elapsed().as_secs_f64()),
                    Ok(res) => {
                        eprintln!(
                            "client {c} query {q}: expected 5 results, got {}",
                            res.top.len()
                        );
                        errors += 1;
                    }
                    Err(e) => {
                        eprintln!("client {c} query {q}: {e}");
                        errors += 1;
                    }
                }
            }
            (lat, errors)
        }));
    }
    let mut latencies = Vec::new();
    let mut per_client_errors = Vec::with_capacity(n_clients);
    for h in handles {
        let (lat, errors) = h.join().expect("client thread");
        latencies.extend(lat);
        per_client_errors.push(errors);
    }
    let n_errors: usize = per_client_errors.iter().sum();
    let wall = t0.elapsed().as_secs_f64();
    let s = summarize(&latencies);
    let snap = svc.metrics.snapshot();
    println!("\n-- serving report --");
    if let Some(kind) = svc.backend_kind() {
        println!("scan backend       {}", kind.name());
    }
    println!("requests           {} ok / {} errors", latencies.len(), n_errors);
    if n_errors > 0 {
        println!("per-client errors  {per_client_errors:?}");
    }
    println!("throughput         {:.1} req/s", latencies.len() as f64 / wall);
    println!(
        "latency mean/p50/p95/p99  {:.1} / {:.1} / {:.1} / {:.1} ms",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        percentile(&latencies, 99.0) * 1e3
    );
    println!("batches            {} (mean fill {:.2})", snap.batches, snap.mean_batch_fill());
    println!(
        "scan throughput    {:.0} (train,test) pairs/s",
        snap.pairs_per_sec(1)
    );
    println!(
        "worker time        grad {:.3}s  scan {:.3}s",
        snap.grad_seconds, snap.scan_seconds
    );
    if snap.shards_scanned > 0 {
        println!(
            "parallel scan      {} shard scans, concurrency {:.2}x",
            snap.shards_scanned,
            snap.scan_concurrency()
        );
    }
    if snap.candidates_rescored > 0 {
        println!(
            "two-stage scan     stage1 {:.3}s  stage2 {:.3}s  rescored {} rows ({:.2}% of scanned)",
            snap.stage1_seconds,
            snap.stage2_seconds,
            snap.candidates_rescored,
            snap.rescore_fraction() * 100.0
        );
    }
    if let Some(pool) = svc.scan_pool() {
        let ps = pool.snapshot();
        println!(
            "scan pool          {} workers (actual), {} queries admitted, \
             {} tasks done ({} failed), busy {:.3}s, queue depth {}",
            ps.workers,
            ps.queries_submitted,
            ps.tasks_completed,
            ps.tasks_failed,
            ps.total_busy_seconds(),
            ps.queue_depth
        );
    }
    let pool_snap = svc.scan_pool().map(|p| p.snapshot());
    emit_observability(
        &parsed,
        &svc.metrics,
        pool_snap,
        &[("logra_store_rows", "Rows in the served store.", store_rows as f64)],
    )?;
    Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    Ok(())
}
