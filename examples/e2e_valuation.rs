//! END-TO-END DRIVER (DESIGN.md §3; recorded in EXPERIMENTS.md).
//!
//! Exercises every layer of the system on a real small workload:
//!   1. train the `lm_small` transformer (~1.7M params) for a few hundred
//!      steps on a synthetic topic corpus, logging the loss curve;
//!   2. run the LoGra logging phase over the full training set (store +
//!      projected Fisher), reporting throughput/memory/storage;
//!   3. answer influence queries — both held-out documents and MODEL
//!      GENERATIONS — through the query engine with ℓ-RelatIF;
//!   4. report the headline metrics: influence throughput (pairs/s),
//!      topic-match rate of top-valued docs, and the LoGra-vs-EKFAC
//!      throughput ratio on a subsample.
//!
//! Flags: --steps N (default 300) --n-train N (default 2048) --fast
//! (shrink everything for CI).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use logra::baselines::{EkfacValuator, Valuator as BaselineValuator};
use logra::coordinator::{projected_grads, run_logging, LoggingOptions};
use logra::data::corpus::{generate, CorpusSpec, TOPIC_NAMES};
use logra::hessian::random_projections;
use logra::model::dataset::Dataset;
use logra::model::generate::generate as lm_generate;
use logra::model::trainer::Trainer;
use logra::runtime::Runtime;
use logra::util::memory::{human_bytes, peak_rss_bytes};
use logra::util::rng::Pcg32;
use logra::valuation::{Normalization, QueryRequest, Valuator};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = logra::cli::parse(&args, &["steps", "n-train", "config"])?;
    let fast = parsed.has_switch("fast");
    let config = parsed.flag_or("config", if fast { "lm_tiny" } else { "lm_small" });
    let steps = parsed.usize_or("steps", if fast { 30 } else { 300 })?;
    let n_train = parsed.usize_or("n-train", if fast { 256 } else { 2048 })?;

    let root = std::env::current_dir()?;
    let rt = Runtime::open_named(&root, &config)?;
    let man = rt.manifest.clone();
    println!(
        "== e2e: {} ({} params, K={}, seq_len={}) ==",
        man.name, man.n_params, man.k_total, man.seq_len
    );

    // ---- 1. Train.
    let corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, n_train, 42));
    let ds = Dataset::Lm(&corpus);
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(0)?;
    let mut rng = Pcg32::seeded(1);
    let mut order: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut order);
    let batches = ds.batches(&order, man.train_batch);
    let t0 = Instant::now();
    let mut loss_curve: Vec<(usize, f32)> = Vec::new();
    let mut step = 0usize;
    'outer: loop {
        for b in &batches {
            let loss = trainer.step(&mut st, b)?;
            step += 1;
            if step % (steps / 10).max(1) == 0 || step == 1 {
                loss_curve.push((step, loss));
            }
            if step >= steps {
                break 'outer;
            }
        }
        rng.shuffle(&mut order);
    }
    let train_secs = t0.elapsed().as_secs_f64();
    println!("\n-- loss curve ({} steps, {:.1}s, {:.0} tokens/s) --", step, train_secs,
        (step * man.train_batch * man.seq_len) as f64 / train_secs);
    for (s, l) in &loss_curve {
        println!("  step {s:>5}  loss {l:.4}");
    }
    let first = loss_curve.first().unwrap().1;
    let last = loss_curve.last().unwrap().1;
    anyhow::ensure!(last < first, "training failed to reduce loss");

    // ---- 2. Logging phase.
    let proj = random_projections(&man, &mut rng);
    let store_dir = root.join("runs").join("e2e-store");
    let (store, hessian, rep) =
        run_logging(&rt, &ds, &st.params, &proj, &store_dir, &LoggingOptions::default())?;
    println!(
        "\n-- logging -- {} rows | {:.0} tokens/s | storage {} | peak RSS {}",
        rep.rows,
        rep.tokens_per_sec,
        human_bytes(rep.storage_bytes),
        human_bytes(rep.peak_rss_bytes)
    );

    // ---- 3. Queries, through the one-call session facade (fabric opened
    //         once, codec auto-detected, native SIMD scan kernels).
    let precond = Arc::new(hessian.unwrap().preconditioner(0.1)?);
    let valuator = Valuator::open(&store_dir)?
        .preconditioner(precond)
        .normalization(Normalization::RelatIf)
        .build()?;
    let n_queries = man.test_batch;
    // Held-out docs (one per topic) + model generations.
    let held = generate(CorpusSpec::new(man.vocab, man.seq_len, n_queries, 4242));
    let hds = Dataset::Lm(&held);
    let qidx: Vec<usize> = (0..n_queries).collect();
    let (qg, _) = projected_grads(&rt, &hds, &qidx, &st.params, &proj)?;
    let t1 = Instant::now();
    let results = valuator.query(QueryRequest::gradients(qg, n_queries, 10))?;
    let scan_secs = t1.elapsed().as_secs_f64();
    let pairs = (n_queries * store.rows()) as f64;
    println!(
        "\n-- influence -- {:.0} (train,test) pairs/s over {} stored rows",
        pairs / scan_secs,
        store.rows()
    );
    let mut matches = 0usize;
    let mut total = 0usize;
    for (qi, res) in results.iter().enumerate() {
        let qt = held.docs[qi].topic;
        for &(_, id) in res.top.iter().take(5) {
            if corpus.docs[id as usize].topic == qt {
                matches += 1;
            }
            total += 1;
        }
    }
    let match_rate = matches as f64 / total as f64;
    println!(
        "top-5 topic-match rate (held-out queries): {:.2} (chance {:.2})",
        match_rate,
        1.0 / TOPIC_NAMES.len() as f64
    );

    // Model-generation query (the paper's Fig-5 setting).
    let gen = lm_generate(&rt, &st.params, &corpus.docs[0].tokens[..8], 0.8, &mut rng)?;
    println!("\nmodel generation: {}", corpus.render(&gen[..24]));
    let gen_holder = logra::data::Corpus {
        layout: corpus.layout.clone(),
        docs: vec![logra::data::corpus::Doc { id: 0, topic: 0, tokens: gen.clone() }],
        seq_len: corpus.seq_len,
    };
    let gds = Dataset::Lm(&gen_holder);
    let (gg, _) = projected_grads(&rt, &gds, &[0], &st.params, &proj)?;
    let gres = valuator.query(QueryRequest::gradients(gg, 1, 5))?;
    for &(s, id) in &gres[0].top {
        let d = &corpus.docs[id as usize];
        println!("  [{s:+.3}] doc {id} ({}) {}", TOPIC_NAMES[d.topic], corpus.render(&d.tokens[..12]));
    }

    // ---- 4. EKFAC comparison on a subsample (full EKFAC is the point:
    //         it cannot afford the full set).
    let sub = 256.min(n_train);
    let sub_corpus = generate(CorpusSpec::new(man.vocab, man.seq_len, sub, 42));
    let sub_ds = Dataset::Lm(&sub_corpus);
    let mut ek = EkfacValuator::new(&rt, &sub_ds, &hds, &st.params);
    let t2 = Instant::now();
    let _ = ek.values(&qidx)?;
    let ek_secs = t2.elapsed().as_secs_f64();
    let ek_pairs_per_s = (n_queries * sub) as f64 / ek_secs;
    let logra_pairs_per_s = pairs / scan_secs;
    println!(
        "\n-- headline -- LoGra {:.0} pairs/s vs EKFAC {:.0} pairs/s  ({:.0}x)",
        logra_pairs_per_s,
        ek_pairs_per_s,
        logra_pairs_per_s / ek_pairs_per_s
    );
    println!("peak RSS end of run: {}", human_bytes(peak_rss_bytes()));
    anyhow::ensure!(match_rate > 1.5 / TOPIC_NAMES.len() as f64, "retrieval no better than chance");
    anyhow::ensure!(logra_pairs_per_s > ek_pairs_per_s, "LoGra slower than EKFAC?!");
    println!("\ne2e OK");
    Ok(())
}
