//! Multi-stage session: one query, many checkpoints, one shared pool.
//!
//! ```text
//! cargo run --release --example session_stages
//! ```
//!
//! Synthesizes two gradient stores standing in for a pretrain and a
//! finetune checkpoint of the same model (same projection width `k`,
//! different gradients), binds them into one session via `session.json`,
//! and scores a single query against BOTH stages over one shared scan
//! pool — then prints the per-stage rankings next to the weighted-sum
//! combination. The offline twin of `logra session init` + `logra
//! session query`; point the manifest at real logged stores to compare
//! actual checkpoints.

use anyhow::Result;
use logra::session::{
    stage_spec, Combine, Session, SessionConfig, SessionManifest, StageSpec, SESSION_VERSION,
};
use logra::store::{shard_store, GradStoreWriter};
use logra::util::rng::Pcg32;
use logra::valuation::QueryRequest;

const N_TRAIN: usize = 512;
const K: usize = 64;
const SHARDS: usize = 4;

/// One synthetic sharded stage store: `n` rows of `K`-wide gradients
/// drawn from the stage's own rng stream (checkpoints diverge).
fn stage_store(dir: &std::path::Path, stream: u64) -> Result<()> {
    let mut rows = vec![0.0f32; N_TRAIN * K];
    Pcg32::new(1234, stream).fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..N_TRAIN as u64).collect();
    let flat = dir.with_extension("src");
    let _ = std::fs::remove_dir_all(&flat);
    std::fs::create_dir_all(&flat)?;
    let mut w = GradStoreWriter::create(&flat, K)?;
    w.append(&ids, &rows)?;
    w.finalize()?;
    let _ = std::fs::remove_dir_all(dir);
    shard_store(&flat, dir, SHARDS)?;
    std::fs::remove_dir_all(&flat)?;
    Ok(())
}

fn main() -> Result<()> {
    let dir = std::env::current_dir()?.join("runs").join("session-example");
    std::fs::create_dir_all(&dir)?;
    stage_store(&dir.join("pretrain"), 0)?;
    stage_store(&dir.join("finetune"), 1)?;

    // The finetune stage gets double weight in the combined ranking;
    // both stages keep the default fisher preconditioner and no
    // normalization (weighted-sum needs ONE shared norm across stages).
    let manifest = SessionManifest {
        version: SESSION_VERSION,
        stages: vec![
            StageSpec { weight: 0.5, ..stage_spec("pretrain", "pretrain") },
            stage_spec("finetune", "finetune"),
        ],
    };
    manifest.save(&dir)?;

    let sess = Session::open(
        &dir,
        SessionConfig { combine: Combine::WeightedSum, workers: 4 },
    )?;
    println!(
        "session {} — {} stages over {} shared workers",
        sess.dir().display(),
        sess.stages().len(),
        sess.pool().workers()
    );

    // Query by gradient: row 3 of the pretrain store is the reference
    // row space, scored against EVERY stage (shard tasks interleave on
    // the shared pool rather than running stage after stage).
    let g = sess.gradient_row(3).expect("row 3 exists");
    let report = sess.query(QueryRequest::gradients(g, 1, 5))?;

    for sr in &report.stages {
        println!("\nstage {} (weight {}):", sr.name, sr.weight);
        for &(score, id) in &sr.results[0].top {
            println!("  [{score:+.4}] row {id}");
        }
    }
    if let Some(combined) = &report.combined {
        println!("\ncombined ({}):", report.combine.name());
        for &(score, id) in &combined[0].top {
            println!("  [{score:+.4}] row {id}");
        }
    }

    sess.shutdown();
    Ok(())
}
