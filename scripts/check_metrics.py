#!/usr/bin/env python3
"""CI validator for the Prometheus text exposition logra emits.

Parses the exposition produced by `serve_queries --metrics-out` (or
`logra store stat --metrics`) and enforces the format invariants the
renderer in rust/src/obs/export.rs promises:

1. Every sample line belongs to a family that declared both `# HELP` and
   `# TYPE` before its first sample.
2. Metric names and label syntax match the Prometheus grammar subset we
   emit (`name{label="value",...} number`).
3. Histogram families are internally consistent: `le` values strictly
   increase, cumulative bucket counts are monotone non-decreasing, the
   `+Inf` bucket equals `_count`, and `_sum`/`_count` are present.
4. Values parse as finite floats.

Exit status: 0 = valid, 1 = violation, 2 = usage/IO error.
"""

import math
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>\S+)$"
)
LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="[^"\\]*"$')


def base_family(name: str) -> str:
    """Family a sample belongs to (histogram series share one TYPE)."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def main() -> int:
    if len(sys.argv) != 2:
        print(f"usage: {sys.argv[0]} <exposition.prom>")
        return 2
    try:
        with open(sys.argv[1]) as f:
            lines = f.read().splitlines()
    except OSError as e:
        print(f"check_metrics: cannot read {sys.argv[1]}: {e}")
        return 2

    errors = []
    helped, typed = set(), {}
    samples = []  # (name, labels_dict, value)
    for ln, line in enumerate(lines, 1):
        if not line:
            errors.append(f"line {ln}: blank line in exposition")
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not NAME_RE.match(parts[2]):
                errors.append(f"line {ln}: malformed HELP: {line!r}")
            else:
                helped.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or not NAME_RE.match(parts[2]) or parts[3] not in (
                "counter",
                "gauge",
                "histogram",
            ):
                errors.append(f"line {ln}: malformed TYPE: {line!r}")
            else:
                typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            errors.append(f"line {ln}: unexpected comment: {line!r}")
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {ln}: unparseable sample: {line!r}")
            continue
        name = m.group("name")
        labels = {}
        if m.group("labels"):
            for pair in m.group("labels").split(","):
                if not LABEL_RE.match(pair):
                    errors.append(f"line {ln}: bad label {pair!r}")
                    continue
                k, v = pair.split("=", 1)
                labels[k] = v.strip('"')
        try:
            value = float(m.group("value"))
        except ValueError:
            errors.append(f"line {ln}: non-numeric value: {line!r}")
            continue
        if not math.isfinite(value):
            errors.append(f"line {ln}: non-finite value: {line!r}")
            continue
        family = base_family(name)
        if family not in typed:
            errors.append(f"line {ln}: sample {name} before any TYPE for {family}")
        if family not in helped:
            errors.append(f"line {ln}: sample {name} before any HELP for {family}")
        samples.append((name, labels, value))

    # Histogram internal consistency, checked PER SERIES: a histogram
    # family may be emitted once per label combination (e.g. one bucket
    # series per session stage, labeled {stage="..."}), so buckets,
    # _sum, and _count are grouped by their labels minus `le` and each
    # group must be internally consistent on its own.
    def series_key(labels):
        return tuple(sorted((k, v) for k, v in labels.items() if k != "le"))

    for family, kind in typed.items():
        if kind != "histogram":
            continue
        keys = []
        for name, labels, _ in samples:
            if base_family(name) == family and series_key(labels) not in keys:
                keys.append(series_key(labels))
        for key in keys:
            tag = family + ("{%s}" % ",".join(f'{k}="{v}"' for k, v in key) if key else "")
            buckets = [
                (labels.get("le"), value)
                for name, labels, value in samples
                if name == f"{family}_bucket" and series_key(labels) == key
            ]
            counts = [
                v
                for name, labels, v in samples
                if name == f"{family}_count" and series_key(labels) == key
            ]
            sums = [
                v
                for name, labels, v in samples
                if name == f"{family}_sum" and series_key(labels) == key
            ]
            if len(counts) != 1 or len(sums) != 1:
                errors.append(f"{tag}: expected exactly one _count and one _sum")
                continue
            if not buckets or buckets[-1][0] != "+Inf":
                errors.append(f"{tag}: bucket series must end with le=\"+Inf\"")
                continue
            if buckets[-1][1] != counts[0]:
                errors.append(
                    f"{tag}: +Inf bucket {buckets[-1][1]} != _count {counts[0]}"
                )
            prev_le, prev_n = -math.inf, -math.inf
            for le, n in buckets[:-1]:
                try:
                    le_v = float(le)
                except (TypeError, ValueError):
                    errors.append(f"{tag}: non-numeric le {le!r}")
                    continue
                if le_v <= prev_le:
                    errors.append(f"{tag}: le values not strictly increasing at {le}")
                if n < prev_n:
                    errors.append(f"{tag}: cumulative counts decreased at le={le}")
                prev_le, prev_n = le_v, n
            if buckets[:-1] and buckets[-2][1] > counts[0]:
                errors.append(f"{tag}: last finite bucket exceeds _count")

    if not samples:
        errors.append("no samples at all — empty or truncated exposition")
    if errors:
        for e in errors:
            print(f"check_metrics: {e}")
        print(f"check_metrics FAILED ({len(errors)} violations)")
        return 1
    n_hist = sum(1 for k in typed.values() if k == "histogram")
    print(
        f"check_metrics passed: {len(samples)} samples, "
        f"{len(typed)} families ({n_hist} histograms)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
