#!/usr/bin/env python3
"""CI bench-regression gate for the scan microbench.

Compares the freshly produced BENCH_scan.json (written by
`cargo bench --bench microbench`) against the committed
BENCH_baseline.json and fails when any gated throughput metric drops by
more than the threshold (default 30%, override with --threshold or the
BENCH_GATE_THRESHOLD env var).

Two kinds of checks:

1. Cross-run absolute floors (machine-sensitive): rows/s of the f32,
   quantized, and two-stage scans, pool queries/s at concurrency 8, and
   the end-to-end `logra serve` SLO at concurrency 8 (serve_c8_qps floor,
   serve_c8_p50_ms/p99_ms ceilings, written by `logra loadgen
   --bench-out`), each gated at (1 - threshold) * baseline. The committed seed baseline
   is deliberately CONSERVATIVE (set well below typical CI-runner
   throughput) so it only catches catastrophic regressions until someone
   re-baselines on real CI hardware.
2. Intra-run ratio (machine-independent): the persistent scan pool at
   concurrency 8 must not lose badly to the per-query thread-spawn path
   at equal worker count (default floor 0.75x — generous CI-noise slack
   on the "pool meets or beats spawn" expectation; tune with the
   BENCH_POOL_VS_SPAWN_FLOOR env var, 0 disables).
3. Absolute quality floors (machine-independent correctness): IVF
   recall@10 of a pruned probe on the clustered bench corpus must stay
   >= 0.95, and a full probe must stay bit-identical to the two-stage
   engine (ann_full_probe_bitident == 1.0). These ignore --threshold:
   wrong answers are not a throughput trade-off.

Re-baselining (e.g. after an intentional trade-off, or to tighten the
seed floors to your CI hardware):

    cargo bench --bench microbench
    python3 scripts/bench_gate.py --rebaseline
    git add BENCH_baseline.json   # commit the new floors

Exit status: 0 = pass, 1 = regression, 2 = usage/IO error.
"""

import argparse
import json
import os
import sys

# Metrics gated against the committed baseline (higher is better). The
# kernel_* keys gate the scan microkernels directly (no store/pool
# overhead), so a kernel-level regression trips even if engine-level
# noise masks it.
GATED_KEYS = [
    "kernel_f32_rows_per_s",
    "kernel_q8_rows_per_s",
    "f32_rows_per_s",
    "quant_rows_per_s",
    "two_stage_rows_per_s",
    "ann_rows_per_s",
    "pool_c8_qps",
    "session_2stage_qps",
    "serve_c8_qps",
]

# Quality metrics gated at an ABSOLUTE floor, independent of baseline and
# threshold: these are correctness properties of the IVF index (recall of
# a pruned probe on the clustered bench corpus; bit-identity of a full
# probe vs the two-stage engine), not machine-sensitive throughput. A
# drop here means the index returns wrong answers, and no amount of
# CI-runner noise excuses it.
ABSOLUTE_FLOOR_KEYS = {
    "ann_recall_at_10": 0.95,
    "ann_full_probe_bitident": 1.0,
}

# Latency metrics gated the other way around (lower is better): the
# pooled concurrency-8 run's per-query p50/p99 from the observability
# histograms must not exceed baseline / (1 - threshold). Seeds are
# conservative ceilings; tighten via --rebaseline on real CI hardware.
LATENCY_GATED_KEYS = [
    "pool_c8_p50_ms",
    "pool_c8_p99_ms",
    "serve_c8_p50_ms",
    "serve_c8_p99_ms",
]

# Pool-vs-spawn floor at equal worker count. The microbench's pool-vs-
# spawn comparison is short (48 queries per concurrency level), so on
# noisy shared CI runners the honest expectation "pool >= spawn" needs
# real slack: default 0.75, override with BENCH_POOL_VS_SPAWN_FLOOR
# (set 0 to disable the check entirely on a hopeless runner).
POOL_VS_SPAWN_FLOOR = float(os.environ.get("BENCH_POOL_VS_SPAWN_FLOOR", "0.75"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default="BENCH_scan.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=float(os.environ.get("BENCH_GATE_THRESHOLD", "0.30")),
        help="allowed fractional drop vs baseline (default 0.30)",
    )
    ap.add_argument(
        "--rebaseline",
        action="store_true",
        help="overwrite the baseline with the current results and exit",
    )
    args = ap.parse_args()

    try:
        with open(args.current) as f:
            cur = json.load(f)
    except OSError as e:
        print(f"bench gate: cannot read {args.current}: {e}")
        return 2

    if args.rebaseline:
        with open(args.baseline, "w") as f:
            json.dump(cur, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"bench gate: baseline rewritten from {args.current}")
        return 0

    try:
        with open(args.baseline) as f:
            base = json.load(f)
    except OSError as e:
        print(f"bench gate: cannot read {args.baseline}: {e}")
        return 2

    failures = []
    for key in GATED_KEYS:
        b, c = base.get(key), cur.get(key)
        if b is None or c is None:
            # Tolerate schema drift in either file; absence is not a
            # regression signal, just say so in the log.
            print(f"bench gate: skipping {key} (missing from baseline or current)")
            continue
        floor = (1.0 - args.threshold) * float(b)
        ok = float(c) >= floor
        print(
            f"bench gate: {key:24s} baseline {float(b):14.1f}  "
            f"current {float(c):14.1f}  floor {floor:14.1f}  "
            f"{'ok' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(key)

    for key in LATENCY_GATED_KEYS:
        b, c = base.get(key), cur.get(key)
        if b is None or c is None:
            print(f"bench gate: skipping {key} (missing from baseline or current)")
            continue
        ceiling = float(b) / (1.0 - args.threshold)
        ok = float(c) <= ceiling
        print(
            f"bench gate: {key:24s} baseline {float(b):14.1f}  "
            f"current {float(c):14.1f}  ceiling {ceiling:12.1f}  "
            f"{'ok' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(key)

    for key, floor in ABSOLUTE_FLOOR_KEYS.items():
        c = cur.get(key)
        if c is None:
            print(f"bench gate: skipping {key} (missing from current)")
            continue
        ok = float(c) >= floor
        print(
            f"bench gate: {key:24s} absolute floor {floor:6.2f}  "
            f"current {float(c):14.4f}  {'ok' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append(key)

    pc8, sc8 = cur.get("pool_c8_qps"), cur.get("spawn_c8_qps")
    if pc8 is not None and sc8 is not None and float(sc8) > 0.0:
        ratio = float(pc8) / float(sc8)
        ok = ratio >= POOL_VS_SPAWN_FLOOR
        print(
            f"bench gate: pool_c8 / spawn_c8      ratio {ratio:14.3f}  "
            f"floor {POOL_VS_SPAWN_FLOOR:14.3f}  {'ok' if ok else 'REGRESSION'}"
        )
        if not ok:
            failures.append("pool_vs_spawn_c8")

    if failures:
        print(f"bench gate FAILED: {', '.join(failures)}")
        print("(intentional? re-baseline: python3 scripts/bench_gate.py --rebaseline)")
        return 1
    print("bench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
