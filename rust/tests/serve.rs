//! Socket-level integration tests for `logra serve` — real TCP
//! connections against a [`Server`] bound to port 0.
//!
//! Load-bearing properties of the serving layer:
//!
//! 1. **Wire fidelity**: a `POST /query` response re-parses to the exact
//!    bits `Valuator::query` produces locally (ids AND score bits) and
//!    carries the full QueryReport breakdown.
//! 2. **Malformed input degrades structurally**: bad bodies get a 400
//!    with a `{"error":{...}}` JSON body — no hang, no panic — and the
//!    keep-alive connection keeps serving afterwards.
//! 3. **Deadlines are enforced**: a query whose deadline expires while
//!    queued behind heavy work gets a 504 and its unstarted shard tasks
//!    are skipped (`tasks_cancelled` rises on the pool).
//! 4. **Disconnects cancel**: dropping the connection mid-query cancels
//!    the query the same way, observable as `logra_serve_disconnects_total`
//!    and `logra_pool_tasks_cancelled_total` on `/metrics`.
//! 5. **`/metrics` scrapes**: the exposition carries the shared, pool,
//!    and `logra_serve_*` families; `/healthz` and `/debug/trace` parse.

use std::io::BufReader;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use logra::coordinator::Metrics;
use logra::serve::{http, loadgen, ServeConfig, Server};
use logra::store::{shard_store, GradStoreWriter};
use logra::util::json::{self, Json};
use logra::util::rng::Pcg32;
use logra::valuation::{PoolMode, QueryRequest, ScanBackend, Valuator};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-serve-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write an n x k store and shard it: cancellation needs a pool-backed
/// (sharded) fabric — a 1-shard store resolves to the eager sequential
/// engine, which has nothing left to cancel by the time a client waits.
fn sharded_store(name: &str, n: usize, k: usize, shards: usize, seed: u64) -> PathBuf {
    let src = tmpdir(&format!("{name}-src"));
    let mut rng = Pcg32::seeded(seed);
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n as u64).map(|i| i * 3 + 5).collect();
    let mut w = GradStoreWriter::create(&src, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    let dir = tmpdir(name);
    shard_store(&src, &dir, shards).unwrap();
    dir
}

/// Boot a server on a free port over a pool-backed valuator; the test
/// keeps its own `Arc<Valuator>` handle to query locally and to read the
/// pool snapshot.
fn start_server(
    dir: &Path,
    workers: usize,
    tweak: impl FnOnce(&mut ServeConfig),
) -> (Server, Arc<Valuator>, String) {
    let metrics = Arc::new(Metrics::default());
    let valuator = Arc::new(
        Valuator::open(dir)
            .unwrap()
            .fit_from_store(0.1)
            .pool(PoolMode::Auto)
            .workers(workers)
            .metrics(metrics.clone())
            .build()
            .unwrap(),
    );
    let mut cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() };
    tweak(&mut cfg);
    let server = Server::start(valuator.clone(), metrics, cfg).unwrap();
    let addr = server.addr().to_string();
    (server, valuator, addr)
}

/// `{"gradient": [...], "nt": N, "topk": 8}` with seeded values, plus an
/// optional `"deadline_ms"`.
fn gradient_body(nt: usize, k: usize, seed: u64, deadline_ms: Option<u64>) -> String {
    let mut rng = Pcg32::seeded(seed);
    let mut g = vec![0.0f32; nt * k];
    rng.fill_normal(&mut g, 1.0);
    let mut pairs = vec![
        (
            "gradient".to_string(),
            Json::Arr(g.iter().map(|&x| Json::Float(x as f64)).collect()),
        ),
        ("nt".to_string(), Json::Num(nt as u64)),
        ("topk".to_string(), Json::Num(8)),
    ];
    if let Some(d) = deadline_ms {
        pairs.push(("deadline_ms".to_string(), Json::Num(d)));
    }
    Json::Obj(pairs).render()
}

/// First sample value of an unlabelled family in an exposition body.
fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        let rest = l.strip_prefix(name)?;
        let rest = rest.strip_prefix(' ')?;
        rest.trim().parse().ok()
    })
}

#[test]
fn query_roundtrip_bit_identical_to_valuator() {
    let dir = sharded_store("roundtrip", 96, 8, 4, 40);
    let (_server, valuator, addr) = start_server(&dir, 2, |_| {});

    let res =
        loadgen::http_request(&addr, "POST", "/query", br#"{"row": 3, "topk": 7}"#).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    assert_eq!(v.get("backend").and_then(Json::as_str), Some(valuator.kind().name()));
    assert!(v.get("request_id").and_then(Json::as_u64).unwrap() >= 1);

    // Local oracle: the same facade, the same request shape.
    let g = valuator.gradient_row(3).unwrap();
    let want = valuator.query(QueryRequest::gradients(g, 1, 7)).unwrap();
    let r0 = &v.get("results").and_then(Json::as_arr).unwrap()[0];
    let ids: Vec<u64> = r0
        .get("ids")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_u64().unwrap())
        .collect();
    let score_bits: Vec<u64> = r0
        .get("scores")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap().to_bits())
        .collect();
    let want_ids: Vec<u64> = want[0].top.iter().map(|&(_, id)| id).collect();
    let want_bits: Vec<u64> = want[0].top.iter().map(|&(s, _)| s.to_bits()).collect();
    assert_eq!(ids, want_ids, "served ids diverge from Valuator::query");
    assert_eq!(score_bits, want_bits, "served scores are not bit-identical");

    // The report rides along: full stage breakdown, correct shard count.
    let rep = v.get("report").expect("response must carry the QueryReport");
    assert_eq!(rep.get("shards").and_then(Json::as_u64), Some(4));
    assert_eq!(rep.get("backend").and_then(Json::as_str), Some(valuator.kind().name()));
    assert!(rep.get("total_nanos").and_then(Json::as_u64).unwrap() > 0);
    assert!(rep.get("rows_scanned").and_then(Json::as_u64).unwrap() >= 96);
}

#[test]
fn malformed_bodies_get_structured_errors_on_a_surviving_connection() {
    let dir = sharded_store("malformed", 48, 8, 2, 41);
    let (_server, _valuator, addr) = start_server(&dir, 1, |_| {});

    // ONE keep-alive connection for the whole exchange: every 400 must
    // leave it serving.
    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    for (body, frag) in [
        (&b"{not json"[..], "invalid JSON"),
        (&b"[1, 2]"[..], "JSON object"),
        (&b"{}"[..], "\"row\" or \"gradient\""),
        (&br#"{"row": 999999}"#[..], "out of range"),
        (&br#"{"row": 1, "topk": 0}"#[..], "topk"),
        (&br#"{"row": 1, "norm": "weird"}"#[..], "normalization"),
    ] {
        http::write_request(&mut writer, "POST", "/query", body).unwrap();
        let res = http::read_response(&mut reader).unwrap();
        assert_eq!(res.status, 400, "body {body:?}: {}", res.body_str());
        let v = json::parse(&res.body_str())
            .unwrap_or_else(|e| panic!("400 body must be JSON, got {e}: {}", res.body_str()));
        let err = v.get("error").expect("400 body must carry an error object");
        assert_eq!(err.get("code").and_then(Json::as_str), Some("bad_request"));
        let msg = err.get("message").and_then(Json::as_str).unwrap();
        assert!(msg.contains(frag), "message {msg:?} missing {frag:?}");
    }

    // Unknown routes and wrong methods are structured too.
    http::write_request(&mut writer, "GET", "/nope", b"").unwrap();
    let res = http::read_response(&mut reader).unwrap();
    assert_eq!(res.status, 404);
    assert!(res.body_str().contains("not_found"));
    http::write_request(&mut writer, "GET", "/query", b"").unwrap();
    let res = http::read_response(&mut reader).unwrap();
    assert_eq!(res.status, 405);

    // ...and the same connection still answers a good query.
    http::write_request(&mut writer, "POST", "/query", br#"{"row": 0}"#).unwrap();
    let res = http::read_response(&mut reader).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    json::parse(&res.body_str()).unwrap().get("results").expect("scored response");
}

#[test]
fn metrics_healthz_and_trace_scrape() {
    let dir = sharded_store("scrape", 64, 8, 4, 42);
    let (_server, _valuator, addr) = start_server(&dir, 2, |_| {});

    for row in [0u64, 1, 2] {
        let body = format!("{{\"row\":{row}}}");
        let res = loadgen::http_request(&addr, "POST", "/query", body.as_bytes()).unwrap();
        assert_eq!(res.status, 200, "{}", res.body_str());
    }

    let res = loadgen::http_request(&addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(res.status, 200);
    assert!(
        res.header("content-type").is_some_and(|c| c.starts_with("text/plain")),
        "exposition content type: {:?}",
        res.header("content-type")
    );
    let text = res.body_str();
    for family in [
        "logra_requests_total",
        "logra_query_latency_seconds",
        "logra_pool_tasks_completed_total",
        "logra_pool_tasks_cancelled_total",
        "logra_store_rows",
        "logra_serve_requests_total",
        "logra_serve_queries_total",
        "logra_serve_rejected_total",
        "logra_serve_deadline_expired_total",
        "logra_serve_disconnects_total",
        "logra_serve_in_flight",
    ] {
        assert!(text.contains(family), "exposition missing {family}");
    }
    assert_eq!(metric_value(&text, "logra_serve_queries_total"), Some(3.0));
    assert_eq!(metric_value(&text, "logra_store_rows"), Some(64.0));

    let res = loadgen::http_request(&addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(res.status, 200);
    let h = json::parse(&res.body_str()).unwrap();
    assert_eq!(h.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(h.get("rows").and_then(Json::as_u64), Some(64));
    let pool = h.get("pool").expect("pool-backed server must report pool health");
    assert!(pool.get("tasks_completed").and_then(Json::as_u64).unwrap() > 0);

    let res = loadgen::http_request(&addr, "GET", "/debug/trace", b"").unwrap();
    assert_eq!(res.status, 200);
    let t = json::parse(&res.body_str()).unwrap();
    let events = t.get("traceEvents").and_then(Json::as_arr).expect("chrome trace shape");
    assert!(!events.is_empty(), "three queries must leave trace spans");
}

/// Heavy fabric + a single pool worker: enough queued scan work that a
/// tiny deadline reliably expires while its shard tasks are unstarted.
const HEAVY_N: usize = 4096;
const HEAVY_K: usize = 128;
const HEAVY_SHARDS: usize = 16;
const HEAVY_NT: usize = 32;

fn saturate(addr: &str, clients: usize) -> Vec<std::thread::JoinHandle<u16>> {
    (0..clients)
        .map(|c| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let body = gradient_body(HEAVY_NT, HEAVY_K, 1000 + c as u64, None);
                loadgen::http_request(&addr, "POST", "/query", body.as_bytes())
                    .map(|r| r.status)
                    .unwrap_or(0)
            })
        })
        .collect()
}

#[test]
fn deadline_expiry_returns_504_and_cancels_pool_tasks() {
    let dir = sharded_store("deadline", HEAVY_N, HEAVY_K, HEAVY_SHARDS, 43);
    let (_server, valuator, addr) = start_server(&dir, 1, |cfg| {
        cfg.max_in_flight = 64;
        cfg.poll_interval = Duration::from_millis(1);
    });

    // Fill the single worker's queue with heavy queries, then ask for one
    // with a 1 ms deadline: its tasks sit behind ~hundreds of heavy shard
    // scans, so the deadline expires at the first poll.
    let background = saturate(&addr, 12);
    // Long enough for the clients to be admitted, short enough that the
    // single worker still has a deep queue when the victim arrives.
    std::thread::sleep(Duration::from_millis(30));
    let body = gradient_body(HEAVY_NT, HEAVY_K, 2000, Some(1));
    let res = loadgen::http_request(&addr, "POST", "/query", body.as_bytes()).unwrap();
    assert_eq!(res.status, 504, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    assert_eq!(
        v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
        Some("deadline_expired")
    );

    // The pool must skip the cancelled query's unstarted tasks as the
    // worker drains past them.
    let pool = valuator.scan_pool().expect("sharded fabric is pool-backed");
    let t0 = Instant::now();
    while pool.snapshot().tasks_cancelled == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "tasks_cancelled never rose: {:?}",
            pool.snapshot()
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    for h in background {
        assert_eq!(h.join().unwrap(), 200, "background query failed");
    }
    let m = loadgen::http_request(&addr, "GET", "/metrics", b"").unwrap();
    let text = m.body_str();
    assert!(metric_value(&text, "logra_serve_deadline_expired_total").unwrap() >= 1.0);
    assert!(metric_value(&text, "logra_pool_tasks_cancelled_total").unwrap() >= 1.0);
}

#[test]
fn client_disconnect_cancels_in_flight_query() {
    let dir = sharded_store("disconnect", HEAVY_N, HEAVY_K, HEAVY_SHARDS, 44);
    let (_server, valuator, addr) = start_server(&dir, 1, |cfg| {
        cfg.max_in_flight = 64;
        cfg.poll_interval = Duration::from_millis(1);
    });

    let background = saturate(&addr, 8);
    std::thread::sleep(Duration::from_millis(30));

    // Send a heavy query, then vanish without reading the response.
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let body = gradient_body(HEAVY_NT, HEAVY_K, 3000, None);
        http::write_request(&mut writer, "POST", "/query", body.as_bytes()).unwrap();
        // Both halves drop here: the server's next poll peeks EOF.
    }

    // The disconnect is observable on /metrics, and the orphaned query's
    // unstarted shard tasks get skipped.
    let pool = valuator.scan_pool().expect("sharded fabric is pool-backed");
    let t0 = Instant::now();
    loop {
        let m = loadgen::http_request(&addr, "GET", "/metrics", b"").unwrap();
        let text = m.body_str();
        let disconnects =
            metric_value(&text, "logra_serve_disconnects_total").unwrap_or(0.0);
        if disconnects >= 1.0 && pool.snapshot().tasks_cancelled > 0 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "disconnect never cancelled: disconnects={disconnects} pool={:?}",
            pool.snapshot()
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    for h in background {
        assert_eq!(h.join().unwrap(), 200, "background query failed");
    }
}
