//! Integration tests for multi-stage valuation sessions — several
//! checkpoint stores behind one [`Session`], every stage's shard tasks on
//! ONE shared scan pool.
//!
//! Load-bearing properties:
//!
//! 1. **Per-stage fidelity**: a session's per-stage results are
//!    bit-identical (ids AND score bits) to a standalone [`Valuator`]
//!    opened over the same store with the same recipe.
//! 2. **Degenerate weights**: under [`Combine::WeightedSum`] with weights
//!    `{1.0, 0.0}` the combined ranking IS stage 0's, bitwise.
//! 3. **Fault isolation**: a corrupt shard in one stage quarantines in
//!    that stage only; the other stages serve unchanged.
//! 4. **Server subsets**: `logra serve --session`'s `POST /query` honors
//!    per-request `"stages"` subsets and reports per-stage + combined
//!    scores; unknown names get a structured 400.
//! 5. **Pool economics**: the shared pool's worker count does not grow
//!    with the stage count.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use logra::serve::{loadgen, ServeConfig, Server};
use logra::session::{
    stage_spec, Combine, Session, SessionConfig, SessionManifest, StageSpec, SESSION_VERSION,
};
use logra::store::{shard_store, GradStoreWriter, ShardManifest};
use logra::util::json::{self, Json};
use logra::util::rng::Pcg32;
use logra::valuation::{Backend, PoolMode, QueryRequest, ScanBackend, ScanPool, Valuator};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-session-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write an n x k store and shard it into `dst` (a stage directory inside
/// a session dir). Sharded so the stages run pool-backed scan tasks.
fn stage_store(dst: &Path, n: usize, k: usize, shards: usize, seed: u64) {
    let src = dst.with_extension("src");
    let _ = std::fs::remove_dir_all(&src);
    std::fs::create_dir_all(&src).unwrap();
    let mut rng = Pcg32::seeded(seed);
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut w = GradStoreWriter::create(&src, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    let _ = std::fs::remove_dir_all(dst);
    shard_store(&src, dst, shards).unwrap();
    std::fs::remove_dir_all(&src).unwrap();
}

/// A two-stage session dir: stage "pretrain" (n0 rows) + stage "finetune"
/// (n1 rows), same k, different contents.
fn two_stage_session(name: &str, n0: usize, n1: usize, k: usize, shards: usize) -> PathBuf {
    let dir = tmpdir(name);
    stage_store(&dir.join("pretrain"), n0, k, shards, 70);
    stage_store(&dir.join("finetune"), n1, k, shards, 71);
    SessionManifest {
        version: SESSION_VERSION,
        stages: vec![stage_spec("pretrain", "pretrain"), stage_spec("finetune", "finetune")],
    }
    .save(&dir)
    .unwrap();
    dir
}

/// The standalone oracle: one valuator over one stage store, built with
/// the exact recipe [`Session`] uses per stage (shared-pool engine, store
/// Fisher fit at damping 0.1, no normalization).
fn standalone(dir: &Path, workers: usize) -> Valuator {
    let pool = Arc::new(ScanPool::spawn(workers));
    Valuator::open(dir)
        .unwrap()
        .backend(Backend::Auto)
        .pool(PoolMode::Shared(pool))
        .workers(workers)
        .fit_from_store(0.1)
        .build()
        .unwrap()
}

fn bits(top: &[(f64, u64)]) -> Vec<(u64, u64)> {
    top.iter().map(|&(s, id)| (s.to_bits(), id)).collect()
}

#[test]
fn per_stage_results_bit_identical_to_standalone_valuators() {
    let dir = two_stage_session("bit-identity", 96, 64, 8, 4);
    let sess = Session::open(
        &dir,
        SessionConfig { combine: Combine::WeightedSum, workers: 2 },
    )
    .unwrap();

    let g = sess.gradient_row(3).unwrap();
    let report = sess.query(QueryRequest::gradients(g.clone(), 1, 7)).unwrap();
    assert_eq!(report.stages.len(), 2);
    assert!(report.combined.is_some(), "weighted-sum must produce a combined ranking");

    for (sr, sub) in report.stages.iter().zip(["pretrain", "finetune"]) {
        assert_eq!(sr.name, sub);
        assert!(sr.report.is_some(), "every stage carries its own metrics");
        let oracle = standalone(&dir.join(sub), 2);
        let want = oracle.query(QueryRequest::gradients(g.clone(), 1, 7)).unwrap();
        assert_eq!(
            bits(&sr.results[0].top),
            bits(&want[0].top),
            "stage {sub} diverges from a standalone valuator"
        );
    }
    sess.shutdown();
}

#[test]
fn weighted_sum_with_degenerate_weights_is_stage_zero_bitwise() {
    let dir = tmpdir("degenerate-weights");
    stage_store(&dir.join("a"), 80, 8, 4, 72);
    stage_store(&dir.join("b"), 80, 8, 4, 73);
    SessionManifest {
        version: SESSION_VERSION,
        stages: vec![
            stage_spec("a", "a"),
            StageSpec { weight: 0.0, ..stage_spec("b", "b") },
        ],
    }
    .save(&dir)
    .unwrap();
    let sess = Session::open(
        &dir,
        SessionConfig { combine: Combine::WeightedSum, workers: 2 },
    )
    .unwrap();

    let g = sess.gradient_row(0).unwrap();
    let report = sess.query(QueryRequest::gradients(g, 1, 5)).unwrap();
    let combined = report.combined.as_ref().unwrap();
    // Weight 0 excludes stage b entirely and 1.0 * s == s exactly in f64,
    // so the combined ranking IS stage a's — same ids, same score bits,
    // same order.
    assert_eq!(bits(&combined[0].top), bits(&report.stages[0].results[0].top));
    // ...while stage b still reports its own top-k.
    assert_eq!(report.stages[1].results[0].top.len(), 5);
    sess.shutdown();
}

#[test]
fn corrupt_shard_quarantines_only_its_stage() {
    let dir = two_stage_session("quarantine", 96, 96, 8, 4);

    // Bit rot in ONE stage: halve the payload of a finetune shard.
    let man = ShardManifest::load(&dir.join("finetune")).unwrap();
    let victim = man.shard_dirs[1].clone();
    let victim_rows = man.shard_rows[1];
    let grads = dir.join("finetune").join(&victim).join("grads.bin");
    let len = std::fs::metadata(&grads).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&grads).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);

    let sess = Session::open(
        &dir,
        SessionConfig { combine: Combine::WeightedSum, workers: 2 },
    )
    .unwrap();
    let pt = sess.stage("pretrain").unwrap().valuator();
    let ft = sess.stage("finetune").unwrap().valuator();
    assert!(pt.quarantined().is_empty(), "healthy stage must not quarantine");
    assert_eq!(ft.quarantined().len(), 1);
    assert_eq!(ft.quarantined()[0].name, victim);
    assert_eq!(ft.rows() as u64, 96 - victim_rows);
    assert_eq!(pt.rows(), 96);

    // The session still answers; the healthy stage is bit-identical to a
    // standalone valuator over the intact store.
    let g = sess.gradient_row(1).unwrap();
    let report = sess.query(QueryRequest::gradients(g.clone(), 1, 6)).unwrap();
    assert_eq!(report.stages[0].quarantined_shards, 0);
    assert_eq!(report.stages[1].quarantined_shards, 1);
    let oracle = standalone(&dir.join("pretrain"), 2);
    let want = oracle.query(QueryRequest::gradients(g, 1, 6)).unwrap();
    assert_eq!(bits(&report.stages[0].results[0].top), bits(&want[0].top));
    sess.shutdown();
}

#[test]
fn server_honors_stage_subsets_and_reports_per_stage_scores() {
    let dir = two_stage_session("serve-subset", 64, 64, 8, 4);
    let sess = Session::open(
        &dir,
        SessionConfig { combine: Combine::WeightedSum, workers: 2 },
    )
    .unwrap();
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() };
    let server = Server::start_session(sess, cfg, None).unwrap();
    let addr = server.addr().to_string();

    // Full fan-out: both stages, combined ranking as top-level results.
    let res =
        loadgen::http_request(&addr, "POST", "/query", br#"{"row": 2, "topk": 5}"#).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    assert_eq!(v.get("combine").and_then(Json::as_str), Some("weighted-sum"));
    assert_eq!(v.get("stage_errors").and_then(Json::as_u64), Some(0));
    let stages = v.get("stages").and_then(Json::as_arr).unwrap();
    assert_eq!(stages.len(), 2);
    for st in stages {
        assert!(st.get("results").is_some(), "ok stage must carry results");
        assert!(st.get("report").is_some(), "ok stage must carry its report");
        assert!(st.get("generation").and_then(Json::as_u64).is_some());
    }
    v.get("results").and_then(Json::as_arr).expect("combined results at top level");

    // Subset round-trip: only the named stage runs; the top-level results
    // are the combined ranking over that one stage — its own scores.
    let res = loadgen::http_request(
        &addr,
        "POST",
        "/query",
        br#"{"row": 2, "topk": 5, "stages": ["finetune"]}"#,
    )
    .unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    let stages = v.get("stages").and_then(Json::as_arr).unwrap();
    assert_eq!(stages.len(), 1);
    assert_eq!(stages[0].get("name").and_then(Json::as_str), Some("finetune"));
    let stage_scores: Vec<u64> = stages[0]
        .get("results")
        .and_then(Json::as_arr)
        .unwrap()[0]
        .get("scores")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap().to_bits())
        .collect();
    let combined_scores: Vec<u64> = v
        .get("results")
        .and_then(Json::as_arr)
        .unwrap()[0]
        .get("scores")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap().to_bits())
        .collect();
    assert_eq!(
        combined_scores, stage_scores,
        "single-stage weighted sum at weight 1.0 must be the stage's own scores"
    );

    // Unknown stage name: structured 400 naming the known stages.
    let res = loadgen::http_request(
        &addr,
        "POST",
        "/query",
        br#"{"row": 2, "stages": ["warmup"]}"#,
    )
    .unwrap();
    assert_eq!(res.status, 400, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    let msg = v
        .get("error")
        .and_then(|e| e.get("message"))
        .and_then(Json::as_str)
        .unwrap();
    assert!(msg.contains("unknown stage"), "{msg}");
    assert!(msg.contains("pretrain"), "{msg}");

    // Per-stage health: one entry per stage, plus the loadgen-compatible
    // top-level row count.
    let res = loadgen::http_request(&addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(res.status, 200);
    let h = json::parse(&res.body_str()).unwrap();
    assert_eq!(h.get("rows").and_then(Json::as_u64), Some(64));
    let hs = h.get("stages").and_then(Json::as_arr).unwrap();
    assert_eq!(hs.len(), 2);
    for st in hs {
        assert!(st.get("name").and_then(Json::as_str).is_some());
        assert!(st.get("generation").and_then(Json::as_u64).is_some());
        assert_eq!(st.get("quarantined_shards").and_then(Json::as_u64), Some(0));
    }

    // Per-stage metrics: the session families carry a stage label per
    // stage and the shared pool is reported once.
    let res = loadgen::http_request(&addr, "GET", "/metrics", b"").unwrap();
    let text = res.body_str();
    for needle in [
        "logra_session_stages 2",
        "logra_session_stage_requests_total{stage=\"pretrain\"}",
        "logra_session_stage_requests_total{stage=\"finetune\"}",
        "logra_session_stage_query_latency_seconds",
        "logra_pool_workers",
    ] {
        assert!(text.contains(needle), "metrics missing {needle:?}:\n{text}");
    }
}

#[test]
fn shared_pool_workers_do_not_grow_with_stage_count() {
    let dir = tmpdir("pool-economics");
    for name in ["s0", "s1", "s2"] {
        stage_store(&dir.join(name), 48, 8, 2, 74);
    }
    let one = SessionManifest {
        version: SESSION_VERSION,
        stages: vec![stage_spec("s0", "s0")],
    };
    one.save(&dir).unwrap();
    let sess1 = Session::open(
        &dir,
        SessionConfig { combine: Combine::PerStageOnly, workers: 2 },
    )
    .unwrap();
    let w1 = sess1.workers();
    sess1.shutdown();

    let three = SessionManifest {
        version: SESSION_VERSION,
        stages: vec![
            stage_spec("s0", "s0"),
            stage_spec("s1", "s1"),
            stage_spec("s2", "s2"),
        ],
    };
    three.save(&dir).unwrap();
    let sess3 = Session::open(
        &dir,
        SessionConfig { combine: Combine::PerStageOnly, workers: 2 },
    )
    .unwrap();
    assert_eq!(sess3.stages().len(), 3);
    assert_eq!(sess3.workers(), w1, "stages must share ONE pool, not grow it");
    assert_eq!(sess3.pool().workers(), 2);

    // PerStageOnly: queries answer per stage with no combined ranking.
    let g = sess3.gradient_row(0).unwrap();
    let report = sess3.query(QueryRequest::gradients(g, 1, 4)).unwrap();
    assert!(report.combined.is_none());
    assert_eq!(report.stages.len(), 3);
    sess3.shutdown();
}
