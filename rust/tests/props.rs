//! Property-based tests over the pure substrates (no artifacts needed):
//! linalg, stats, top-k, pipeline, config — run via the in-repo
//! mini-proptest framework (DESIGN.md §6).

use logra::linalg::{cholesky, dot, eigh, solve_spd, Matrix};
use logra::prop_assert;
use logra::util::proptest::check;
use logra::util::rng::Pcg32;
use logra::util::stats::{pearson, ranks, spearman};
use logra::util::topk::TopK;

fn random_spd(rng: &mut Pcg32, n: usize) -> Matrix {
    let b = Matrix::random_normal(rng, n + 2, n, 1.0);
    let mut g = b.transpose().matmul(&b);
    for i in 0..n {
        *g.at_mut(i, i) += 0.05;
    }
    g
}

#[test]
fn prop_eigh_reconstructs_and_orthogonal() {
    check("eigh-reconstruct", 25, |g| {
        let n = 1 + g.int_in(0, 40);
        let a = random_spd(&mut g.rng, n);
        let e = eigh(&a);
        // Orthogonality.
        let qtq = e.q.transpose().matmul(&e.q);
        let dev = qtq.max_abs_diff(&Matrix::identity(n));
        prop_assert!(dev < 1e-3, "Q^T Q deviates by {dev} at n={n}");
        // Reconstruction.
        let mut rec = Matrix::zeros(n, n);
        for i in 0..n {
            let lam = e.eigenvalues[i];
            for r in 0..n {
                for c in 0..n {
                    rec.data[r * n + c] += lam * e.q.at(r, i) * e.q.at(c, i);
                }
            }
        }
        let scale = a.fro_norm().max(1.0);
        prop_assert!(
            a.max_abs_diff(&rec) < 5e-4 * scale,
            "reconstruction off by {} at n={n}",
            a.max_abs_diff(&rec)
        );
        // Eigenvalues sorted ascending and non-negative (SPD).
        prop_assert!(
            e.eigenvalues.windows(2).all(|w| w[0] <= w[1] + 1e-6),
            "eigenvalues unsorted"
        );
        prop_assert!(e.eigenvalues[0] > -1e-3, "SPD matrix got negative eigenvalue");
        Ok(())
    });
}

#[test]
fn prop_cholesky_solve_is_inverse() {
    check("cholesky-solve", 25, |g| {
        let n = 1 + g.int_in(0, 30);
        let a = random_spd(&mut g.rng, n);
        let b: Vec<f32> = (0..n).map(|_| g.rng.normal_f32()).collect();
        let x = match solve_spd(&a, &b) {
            Some(x) => x,
            None => return Err("SPD solve failed".into()),
        };
        let ax = a.matvec(&x);
        let resid: f32 =
            ax.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum::<f32>().sqrt();
        let bn = dot(&b, &b).sqrt().max(1.0);
        prop_assert!(resid < 5e-3 * bn, "residual {resid} at n={n}");
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        prop_assert!(
            a.max_abs_diff(&rec) < 1e-2 * a.fro_norm().max(1.0),
            "cholesky reconstruction off"
        );
        Ok(())
    });
}

#[test]
fn prop_matmul_associativity_with_vector() {
    check("matmul-assoc", 25, |g| {
        let m = 1 + g.int_in(0, 12);
        let k = 1 + g.int_in(0, 12);
        let n = 1 + g.int_in(0, 12);
        let a = Matrix::random_normal(&mut g.rng, m, k, 1.0);
        let b = Matrix::random_normal(&mut g.rng, k, n, 1.0);
        let x: Vec<f32> = (0..n).map(|_| g.rng.normal_f32()).collect();
        // (A B) x == A (B x)
        let lhs = a.matmul(&b).matvec(&x);
        let rhs = a.matvec(&b.matvec(&x));
        for (p, q) in lhs.iter().zip(&rhs) {
            prop_assert!((p - q).abs() < 1e-2 * q.abs().max(1.0), "{p} vs {q}");
        }
        Ok(())
    });
}

#[test]
fn prop_spearman_invariant_to_monotone_maps() {
    check("spearman-monotone", 30, |g| {
        let n = 3 + g.int_in(0, 60);
        let x: Vec<f64> = (0..n).map(|_| g.rng.normal()).collect();
        let y: Vec<f64> = (0..n).map(|_| g.rng.normal()).collect();
        let base = spearman(&x, &y);
        let x2: Vec<f64> = x.iter().map(|v| v.exp()).collect(); // strictly monotone
        let y2: Vec<f64> = y.iter().map(|v| 3.0 * v + 7.0).collect();
        let mapped = spearman(&x2, &y2);
        prop_assert!(
            (base - mapped).abs() < 1e-9,
            "monotone map changed spearman {base} -> {mapped}"
        );
        Ok(())
    });
}

#[test]
fn prop_ranks_are_permutation_of_1_to_n_when_distinct() {
    check("ranks-perm", 30, |g| {
        let n = 1 + g.int_in(0, 100);
        let mut x: Vec<f64> = (0..n).map(|i| i as f64).collect();
        g.rng.shuffle(&mut x);
        let r = ranks(&x);
        let mut sorted = r.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, v) in sorted.iter().enumerate() {
            prop_assert!((v - (i + 1) as f64).abs() < 1e-12, "rank {v} at {i}");
        }
        // And pearson(x, ranks(x)) is exactly spearman(x, x) = 1.
        prop_assert!((pearson(&x, &r) - spearman(&x, &x)).abs() < 1.0, "sanity");
        Ok(())
    });
}

#[test]
fn prop_topk_threshold_monotone_nondecreasing() {
    check("topk-threshold", 30, |g| {
        let k = 1 + g.int_in(0, 10);
        let n = g.int_in(0, 300);
        let mut tk = TopK::new(k);
        let mut last = f64::NEG_INFINITY;
        for i in 0..n {
            tk.push(g.rng.normal(), i as u64);
            let th = tk.threshold();
            prop_assert!(th >= last, "threshold decreased: {last} -> {th}");
            last = th;
        }
        let out = tk.into_sorted();
        prop_assert!(out.len() == k.min(n), "wrong kept count");
        prop_assert!(
            out.windows(2).all(|w| w[0].0 >= w[1].0),
            "not sorted descending"
        );
        Ok(())
    });
}

#[test]
fn prop_config_roundtrip_values() {
    check("config-roundtrip", 30, |g| {
        let i = g.rng.next_u32() as i64 - (u32::MAX / 2) as i64;
        let f = g.f64_in(-1e6, 1e6);
        let text = format!("[s]\na = {i}\nb = {f:.6}\nc = \"x{i}\"\nd = [1, 2, {i}]\n");
        let doc = match logra::config::parse(&text) {
            Ok(d) => d,
            Err(e) => return Err(format!("parse failed: {e}")),
        };
        prop_assert!(doc.int_of("s", "a").unwrap() == i, "int roundtrip");
        prop_assert!(
            (doc.float_of("s", "b").unwrap() - f).abs() < 1e-3 * f.abs().max(1.0),
            "float roundtrip"
        );
        prop_assert!(doc.str_of("s", "c").unwrap() == format!("x{i}"), "str roundtrip");
        prop_assert!(
            doc.get("s", "d").unwrap().as_int_list().unwrap() == [1, 2, i],
            "list roundtrip"
        );
        Ok(())
    });
}

#[test]
fn prop_pipeline_preserves_order_any_capacity() {
    check("pipeline-order", 15, |g| {
        let cap = 1 + g.int_in(0, 8);
        let n = g.int_in(0, 200);
        let (tx, rx) = logra::util::pipeline::bounded(cap);
        let h = std::thread::spawn(move || {
            for i in 0..n {
                if tx.send(i).is_err() {
                    break;
                }
            }
        });
        let got: Vec<usize> = std::iter::from_fn(|| rx.recv()).collect();
        h.join().unwrap();
        prop_assert!(got == (0..n).collect::<Vec<_>>(), "order broken (cap={cap})");
        Ok(())
    });
}

#[test]
fn prop_orthonormalized_projection_preserves_norms_in_subspace() {
    check("proj-isometry", 20, |g| {
        let n = 4 + g.int_in(0, 28);
        let k = 1 + g.int_in(0, 3).min(n - 1);
        let mut p = Matrix::random_normal(&mut g.rng, k, n, 1.0);
        p.orthonormalize_rows();
        // For x in the row space, ||P x|| == ||x||.
        let coef: Vec<f32> = (0..k).map(|_| g.rng.normal_f32()).collect();
        let mut x = vec![0.0f32; n];
        for (i, &c) in coef.iter().enumerate() {
            for (xv, pv) in x.iter_mut().zip(p.row(i)) {
                *xv += c * pv;
            }
        }
        let px = p.matvec(&x);
        let nx = dot(&x, &x).sqrt();
        let npx = dot(&px, &px).sqrt();
        prop_assert!(
            (nx - npx).abs() < 1e-3 * nx.max(1.0),
            "not isometric on subspace: {nx} vs {npx}"
        );
        Ok(())
    });
}
