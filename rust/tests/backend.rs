//! `ScanBackend` trait + `Valuator` facade integration tests
//! (artifact-free: native scoring only).
//!
//! Load-bearing properties of the unified query seam:
//!
//! 1. **Trait-object equivalence**: all four backends behind
//!    `Box<dyn ScanBackend>` — sequential, parallel-f32, two-stage with a
//!    corpus-covering rescore pool, and IVF probing every cluster — are
//!    bit-identical to the sequential `QueryEngine` native reference, for
//!    both normalizations, with and without a shared scan pool. This
//!    extends the pool/twostage invariants to the new seam: the trait
//!    boundary cannot move a bit.
//! 2. **Facade auto-detection**: `Valuator::open` + `Backend::Auto`
//!    serves an f32 fabric and a quantized fabric with zero
//!    codec-specific caller code (the quantized manifest records its
//!    exact companion), and per-request `topk` / normalization overrides
//!    thread through `QueryRequest`.
//! 3. **Typed error paths**: construction-time validation
//!    (`InvalidConfig`), store pairing failures, token queries on
//!    runtime-free backends (`BadQuery`), pool-worker panics
//!    (`QueryPoisoned`), and `ServiceConfig` validation at `spawn` —
//!    all typed, none panicking deep in a worker.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use logra::hessian::BlockHessian;
use logra::store::{
    build_index, quantize_store, shard_store, GradStore, GradStoreWriter, IvfIndex,
    QuantShardedStore, ShardManifest, ShardedStore,
};
use logra::util::rng::Pcg32;
use logra::valuation::{
    Backend, BackendConfig, BackendKind, IvfEngine, Normalization, ParallelQueryEngine,
    PoolMode, QueryEngine, QueryRequest, ScanBackend, ScanPool, SequentialEngine,
    TwoStageEngine, ValuationError, Valuator,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-backend-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a v1 store with shuffled (non-sequential) ids so id-based
/// tie-breaking is exercised honestly.
fn write_store(dir: &Path, n: usize, k: usize, rng: &mut Pcg32) -> (Vec<u64>, Vec<f32>) {
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let mut ids: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1000).collect();
    rng.shuffle(&mut ids);
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    (ids, rows)
}

fn make_precond(rows: &[f32], n: usize, k: usize) -> logra::hessian::Preconditioner {
    let mut h = BlockHessian::single_block(k);
    h.accumulate(rows, n);
    h.preconditioner(0.1).unwrap()
}

#[test]
fn all_backends_behind_the_trait_are_bit_identical_to_sequential() {
    let k = 14;
    let n = 330;
    let n_shards = 5;
    let nt = 3;
    let topk = 8;
    let src = tmpdir("equiv-src");
    let mut rng = Pcg32::seeded(2024);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("equiv-sharded");
    shard_store(&src, &sharded, n_shards).unwrap();
    let quant_dir = tmpdir("equiv-quant");
    quantize_store(&sharded, &quant_dir).unwrap();
    let clusters = 6;
    build_index(&quant_dir, clusters, 42).unwrap();

    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let index = Arc::new(IvfIndex::open(&quant_dir, &quant).unwrap());
    let single = GradStore::open(&src).unwrap();
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq_ref = QueryEngine::new_native(&single, &precond, 64);
    // Corpus-covering rescore pool: the regime where the two-stage backend
    // must reproduce the exact engine bit-identically.
    let factor = n.div_ceil(topk) + 1;
    let mut test = vec![0.0f32; nt * k];
    rng.fill_normal(&mut test, 1.0);

    // Pooled and unpooled execution substrates for the fan-out backends.
    let pool = Arc::new(ScanPool::spawn(2));
    for pooled in [false, true] {
        let pool_opt = pooled.then(|| pool.clone());
        let backends: Vec<(&str, Box<dyn ScanBackend>)> = vec![
            (
                "sequential",
                Box::new(SequentialEngine::new(
                    exact.clone(),
                    precond.clone(),
                    BackendConfig { chunk_len: 32, ..Default::default() },
                )),
            ),
            (
                "parallel-f32",
                Box::new(ParallelQueryEngine::new(
                    exact.clone(),
                    precond.clone(),
                    BackendConfig {
                        workers: 2,
                        chunk_len: 32,
                        pool: pool_opt.clone(),
                        ..Default::default()
                    },
                )),
            ),
            (
                "two-stage",
                Box::new(
                    TwoStageEngine::new(
                        quant.clone(),
                        exact.clone(),
                        precond.clone(),
                        BackendConfig {
                            workers: 2,
                            chunk_len: 32,
                            rescore_factor: factor,
                            pool: pool_opt.clone(),
                            ..Default::default()
                        },
                    )
                    .unwrap(),
                ),
            ),
            (
                // Full probe (nprobe = clusters): the IVF funnel must
                // reproduce the two-stage engine — and through it the
                // sequential reference — bit-identically.
                "ivf",
                Box::new(
                    IvfEngine::new(
                        quant.clone(),
                        index.clone(),
                        exact.clone(),
                        precond.clone(),
                        BackendConfig {
                            workers: 2,
                            chunk_len: 32,
                            rescore_factor: factor,
                            nprobe: clusters,
                            pool: pool_opt.clone(),
                            ..Default::default()
                        },
                    )
                    .unwrap(),
                ),
            ),
        ];
        for norm in [Normalization::None, Normalization::RelatIf] {
            let want = seq_ref.query(&test, nt, topk, norm).unwrap();
            for (name, backend) in &backends {
                assert_eq!(backend.rows(), n, "{name}: rows");
                assert_eq!(backend.k(), k, "{name}: k");
                let got = backend
                    .query(QueryRequest::gradients(test.clone(), nt, topk).with_norm(norm))
                    .unwrap();
                assert_eq!(got.len(), want.len(), "{name}: result count");
                for (t, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        a.top, b.top,
                        "{name} (pooled {pooled}, norm {norm:?}) diverged from the \
                         sequential reference on test row {t}"
                    );
                }
            }
        }
        // Introspection: kinds and exactness are what they claim.
        assert_eq!(backends[0].1.kind(), BackendKind::Sequential);
        assert_eq!(backends[1].1.kind(), BackendKind::Parallel);
        assert_eq!(backends[2].1.kind(), BackendKind::TwoStage);
        assert_eq!(backends[3].1.kind(), BackendKind::Ivf);
        assert!(backends[0].1.exact() && backends[1].1.exact());
        assert!(!backends[2].1.exact() && !backends[3].1.exact());
    }
    pool.shutdown();
}

#[test]
fn valuator_auto_serves_f32_and_quantized_fabrics_identically() {
    let k = 10;
    let n = 240;
    let nt = 2;
    let topk = 6;
    let src = tmpdir("auto-src");
    let mut rng = Pcg32::seeded(7);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("auto-sharded");
    shard_store(&src, &sharded, 4).unwrap();
    let quant_dir = tmpdir("auto-quant");
    quantize_store(&sharded, &quant_dir).unwrap();
    // The quantized manifest recorded its exact companion.
    assert!(ShardManifest::load(&quant_dir).unwrap().rescore_dir.is_some());

    let single = GradStore::open(&src).unwrap();
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq_ref = QueryEngine::new_native(&single, &precond, 32);
    let mut test = vec![0.0f32; nt * k];
    rng.fill_normal(&mut test, 1.0);
    let factor = n.div_ceil(topk) + 1;

    // ONE caller shape for three fabrics: unsharded f32 (sequential),
    // sharded f32 (parallel), quantized (two-stage against the recorded
    // companion) — zero codec-specific code here.
    for (dir, want_kind, backend) in [
        (&src, BackendKind::Sequential, Backend::Auto),
        (&sharded, BackendKind::Parallel, Backend::Auto),
        (&quant_dir, BackendKind::TwoStage, Backend::Quantized { rescore_factor: factor }),
    ] {
        let valuator = Valuator::open(dir)
            .unwrap()
            .backend(backend)
            .preconditioner(precond.clone())
            .build()
            .unwrap();
        assert_eq!(valuator.kind(), want_kind, "{}", dir.display());
        assert_eq!(valuator.rows(), n);
        for norm in [Normalization::None, Normalization::RelatIf] {
            let want = seq_ref.query(&test, nt, topk, norm).unwrap();
            let got = valuator
                .query(QueryRequest::gradients(test.clone(), nt, topk).with_norm(norm))
                .unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.top, b.top, "{} (norm {norm:?})", dir.display());
            }
        }
        // Per-request topk override: a smaller request truncates.
        let small = valuator.query(QueryRequest::gradients(test.clone(), nt, 2)).unwrap();
        assert_eq!(small[0].top.len(), 2);
        // Query-by-gradient convenience: a stored row retrieves itself
        // under RelatIF (it has maximal normalized self-affinity).
        let g0 = valuator.gradient_row(0).unwrap();
        let id0 = single.id(0);
        let hit = valuator
            .query(QueryRequest::gradients(g0, 1, 3).with_norm(Normalization::RelatIf))
            .unwrap();
        assert!(
            hit[0].top.iter().any(|&(_, id)| id == id0),
            "row 0 (id {id0}) missing from its own top-3: {:?}",
            hit[0].top
        );
        valuator.shutdown();
    }

    // Backend::Exact over the quantized fabric serves the f32 companion.
    let exact_over_quant = Valuator::open(&quant_dir)
        .unwrap()
        .backend(Backend::Exact)
        .preconditioner(precond.clone())
        .build()
        .unwrap();
    assert_eq!(exact_over_quant.kind(), BackendKind::Parallel);
    let want = seq_ref.query(&test, nt, topk, Normalization::None).unwrap();
    let got = exact_over_quant
        .query(QueryRequest::gradients(test.clone(), nt, topk))
        .unwrap();
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.top, b.top, "exact-over-quantized fabric");
    }
}

#[test]
fn query_batch_admits_everything_then_completes_in_order() {
    let k = 8;
    let n = 160;
    let src = tmpdir("batch-src");
    let mut rng = Pcg32::seeded(11);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("batch-sharded");
    shard_store(&src, &sharded, 4).unwrap();
    let single = GradStore::open(&src).unwrap();
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq_ref = QueryEngine::new_native(&single, &precond, 32);

    let valuator = Valuator::open(&sharded)
        .unwrap()
        .preconditioner(precond.clone())
        .pool(PoolMode::Auto)
        .workers(2)
        .build()
        .unwrap();
    assert!(valuator.scan_pool().is_some(), "Auto pool on a sharded fabric");

    let mut reqs = Vec::new();
    let mut wants = Vec::new();
    for q in 0..6 {
        let mut test = vec![0.0f32; k];
        rng.fill_normal(&mut test, 1.0);
        let norm =
            if q % 2 == 0 { Normalization::None } else { Normalization::RelatIf };
        wants.push(seq_ref.query(&test, 1, 5, norm).unwrap());
        reqs.push(QueryRequest::gradients(test, 1, 5).with_norm(norm));
    }
    let results = valuator.query_batch(reqs).unwrap();
    assert_eq!(results.len(), wants.len());
    for (q, (got, want)) in results.iter().zip(&wants).enumerate() {
        assert_eq!(got[0].top, want[0].top, "batched query {q}");
    }
    valuator.shutdown();

    // A PoolMode::Shared pool belongs to the caller: a sibling valuator's
    // shutdown must leave it serving.
    let shared = Arc::new(ScanPool::spawn(1));
    let sibling = Valuator::open(&sharded)
        .unwrap()
        .preconditioner(precond.clone())
        .pool(PoolMode::Shared(shared.clone()))
        .build()
        .unwrap();
    sibling.shutdown();
    assert!(
        shared.submit(0, |_| Vec::new()).is_ok(),
        "shared pool must survive a sibling valuator's shutdown"
    );
    shared.shutdown();
}

#[test]
fn typed_error_paths() {
    let k = 6;
    let n = 40;
    let src = tmpdir("errors-src");
    let mut rng = Pcg32::seeded(5);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let precond = Arc::new(make_precond(&rows, n, k));

    // Missing directory -> StoreOpen.
    let missing = tmpdir("errors-missing").join("nope");
    assert!(matches!(
        Valuator::open(&missing).err(),
        Some(ValuationError::StoreOpen { .. })
    ));

    // No preconditioner -> InvalidConfig at build, not a panic at query.
    let err = Valuator::open(&src).unwrap().build().unwrap_err();
    assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");

    // Quantized backend on an f32 fabric -> InvalidConfig.
    let err = Valuator::open(&src)
        .unwrap()
        .backend(Backend::Quantized { rescore_factor: 4 })
        .preconditioner(precond.clone())
        .build()
        .unwrap_err();
    assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");

    // rescore_factor = 0 -> InvalidConfig (construction, not worker).
    let quant_dir = tmpdir("errors-quant");
    quantize_store(&src, &quant_dir).unwrap();
    let err = Valuator::open(&quant_dir)
        .unwrap()
        .backend(Backend::Quantized { rescore_factor: 0 })
        .preconditioner(precond.clone())
        .build()
        .unwrap_err();
    assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");

    // Preconditioner width mismatch -> InvalidConfig.
    let wrong_rows = vec![0.5f32; 8 * (k + 1)];
    let wrong = Arc::new(make_precond(&wrong_rows, 8, k + 1));
    let err = Valuator::open(&src)
        .unwrap()
        .preconditioner(wrong)
        .build()
        .unwrap_err();
    assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");

    // Token queries on a runtime-free backend -> BadQuery.
    let valuator = Valuator::open(&src)
        .unwrap()
        .preconditioner(precond.clone())
        .build()
        .unwrap();
    let err = valuator.query(QueryRequest::tokens(vec![1, 2, 3], 5)).unwrap_err();
    assert!(matches!(err, ValuationError::BadQuery(_)), "{err:?}");

    // Shape mismatch -> BadQuery.
    let err = valuator
        .query(QueryRequest::gradients(vec![0.0; k + 1], 1, 5))
        .unwrap_err();
    assert!(matches!(err, ValuationError::BadQuery(_)), "{err:?}");

    // Submitting to a shut-down pool -> Shutdown.
    let pool = Arc::new(ScanPool::spawn(1));
    pool.shutdown();
    assert!(matches!(
        pool.submit(1, |_| Vec::new()).err(),
        Some(ValuationError::Shutdown)
    ));

    // A panicking shard task -> QueryPoisoned on the completion handle
    // (not a generic channel error, not a shutdown).
    let pool = Arc::new(ScanPool::spawn(2));
    let sharded = tmpdir("errors-sharded");
    shard_store(&src, &sharded, 4).unwrap();
    let engine = ParallelQueryEngine::new(
        Arc::new(ShardedStore::open(&sharded).unwrap()),
        precond.clone(),
        BackendConfig { chunk_len: 16, pool: Some(pool.clone()), ..Default::default() },
    );
    let poisoned = pool
        .submit(3, |si| {
            if si == 1 {
                panic!("backend-suite fault");
            }
            Vec::new()
        })
        .unwrap();
    match poisoned.wait().unwrap_err() {
        ValuationError::QueryPoisoned { message, .. } => {
            assert!(message.contains("backend-suite fault"), "message lost: {message}")
        }
        other => panic!("expected QueryPoisoned, got {other:?}"),
    }
    // The engine sharing that pool is unaffected.
    let mut test = vec![0.0f32; k];
    rng.fill_normal(&mut test, 1.0);
    let ok = engine.query(QueryRequest::gradients(test, 1, 3)).unwrap();
    assert_eq!(ok[0].top.len(), 3);
    pool.shutdown();
}

#[test]
fn service_config_validation_is_typed_and_artifact_free() {
    // Configurations that can never serve must be rejected by
    // `ValuationService::spawn` BEFORE it touches the artifact directory
    // (none exists here) — as ValuationError values downcastable from the
    // anyhow chain.
    let mk = |backend: Backend, max_in_flight: usize| logra::coordinator::ServiceConfig {
        artifact_dir: PathBuf::from("/nonexistent/artifacts"),
        store_dir: PathBuf::from("/nonexistent/store"),
        params: Vec::new(),
        proj_flat: Vec::new(),
        hessian: BlockHessian::single_block(4),
        damping: 0.1,
        norm: Normalization::None,
        max_wait: std::time::Duration::from_millis(1),
        scan_workers: 1,
        backend,
        max_in_flight,
    };
    for cfg in [
        mk(Backend::Quantized { rescore_factor: 0 }, 2),
        mk(Backend::Auto, 0),
        mk(Backend::Ann { nprobe: 0, rescore_factor: 4 }, 2),
        mk(Backend::Ann { nprobe: 4, rescore_factor: 0 }, 2),
    ] {
        let err = match logra::coordinator::ValuationService::spawn(cfg) {
            Err(e) => e,
            Ok(_) => panic!("invalid config accepted"),
        };
        let typed = err
            .downcast_ref::<ValuationError>()
            .unwrap_or_else(|| panic!("not a ValuationError: {err:#}"));
        assert!(matches!(typed, ValuationError::InvalidConfig(_)), "{typed:?}");
    }
}

#[test]
fn fit_from_store_serves_without_an_artifact() {
    // The `logra query` shape: no logging-phase hessian, the projected
    // Fisher is refit from the stored rows at build time.
    let k = 8;
    let n = 90;
    let src = tmpdir("fit-src");
    let mut rng = Pcg32::seeded(21);
    let (ids, _) = write_store(&src, n, k, &mut rng);
    let valuator = Valuator::open(&src)
        .unwrap()
        .fit_from_store(0.1)
        .normalization(Normalization::RelatIf)
        .build()
        .unwrap();
    let g = valuator.gradient_row(3).unwrap();
    let res = valuator.query(QueryRequest::gradients(g, 1, 5)).unwrap();
    assert_eq!(res[0].top.len(), 5);
    assert!(
        res[0].top.iter().any(|&(_, id)| id == ids[3]),
        "stored row should retrieve itself: {:?}",
        res[0].top
    );
    // Out-of-range query row is a clean None, not a panic.
    assert!(valuator.gradient_row(n).is_none());
}
