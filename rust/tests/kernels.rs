//! Scan-kernel subsystem tests (artifact-free).
//!
//! Load-bearing properties:
//! 1. The dispatched f32 kernel tracks the naive single-accumulator
//!    reference within FP-reassociation tolerance over random shapes,
//!    including ragged tails (k not a multiple of the 8-wide unroll) and
//!    shapes smaller than a register tile.
//! 2. Every f32 output cell is BITWISE the standalone kernel dot of its
//!    two rows — independent of tile position, output shape, and chunk
//!    split. This is what keeps sequential/parallel/two-stage engines
//!    bit-identical to each other however the scan is carved up.
//! 3. The int8 kernel is EXACTLY (bit-for-bit) the `dot_q8` reference on
//!    every arm: block sums are exact i32, the scale combine order is
//!    fixed.
//! 4. Steady-state scans through a warm `ScanPool` stop growing their
//!    per-worker scratch — the zero-alloc-per-chunk contract.
//! 5. Auto-derived chunk lengths (`chunk_len = 0`) serve bit-identical
//!    results to any explicit chunking.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use logra::hessian::BlockHessian;
use logra::linalg::kernels::{
    self, dot_f32, dot_f32_scalar, matmul_t_into, matmul_t_scalar_into, scan_q8_into,
    scan_q8_scalar_into,
};
use logra::linalg::matrix::matmul_t_slices;
use logra::prop_assert;
use logra::store::quant::{blocks_of, dot_q8, quantize_rows};
use logra::store::{shard_store, GradStore, GradStoreWriter, ShardedStore};
use logra::util::proptest::check;
use logra::util::rng::Pcg32;
use logra::valuation::{
    BackendConfig, Normalization, ParallelQueryEngine, QueryEngine, QueryRequest, ScanBackend,
    ScanPool,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-kernels-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_store(dir: &Path, n: usize, k: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    rows
}

#[test]
fn prop_f32_kernel_tracks_naive_reference() {
    check("kernel-f32-vs-naive", 12, |g| {
        // Shapes deliberately straddle the tile (4x2) and unroll (8)
        // boundaries: m,n down to 1, k exercising ragged tails.
        let m = 1 + g.int_in(0, 9);
        let n = 1 + g.int_in(0, 40);
        let k = 1 + g.int_in(0, 200);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; n * k];
        g.rng.fill_normal(&mut a, 1.0);
        g.rng.fill_normal(&mut b, 1.0);
        let want = matmul_t_slices(&a, m, &b, n, k);
        let mut got = vec![0.0f32; m * n];
        matmul_t_into(&a, m, &b, n, k, &mut got);
        let mut got_scalar = vec![0.0f32; m * n];
        matmul_t_scalar_into(&a, m, &b, n, k, &mut got_scalar);
        for idx in 0..m * n {
            // Reassociation moves the result by O(k) ulps, not more.
            let tol = 1e-4 * (1.0 + want[idx].abs() + (k as f32).sqrt());
            prop_assert!(
                (got[idx] - want[idx]).abs() <= tol,
                "dispatched cell {idx} of ({m},{n},{k}): {} vs naive {}",
                got[idx],
                want[idx]
            );
            prop_assert!(
                (got_scalar[idx] - want[idx]).abs() <= tol,
                "scalar cell {idx} of ({m},{n},{k}): {} vs naive {}",
                got_scalar[idx],
                want[idx]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_f32_cells_are_position_independent() {
    check("kernel-f32-cell-purity", 12, |g| {
        let m = 1 + g.int_in(0, 7);
        let n = 1 + g.int_in(0, 23);
        let k = 1 + g.int_in(0, 130);
        let mut a = vec![0.0f32; m * k];
        let mut b = vec![0.0f32; n * k];
        g.rng.fill_normal(&mut a, 1.0);
        g.rng.fill_normal(&mut b, 1.0);
        let mut got = vec![0.0f32; m * n];
        matmul_t_into(&a, m, &b, n, k, &mut got);
        for i in 0..m {
            for j in 0..n {
                let d = dot_f32(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                prop_assert!(
                    got[i * n + j].to_bits() == d.to_bits(),
                    "cell ({i},{j}) of ({m},{n},{k}) != standalone dot"
                );
            }
        }
        // Chunk-split invariance: scoring the same rows in two arbitrary
        // column chunks reproduces the one-shot scores bitwise.
        if n >= 2 {
            let split = 1 + g.rng.below_usize(n - 1);
            let mut left = vec![0.0f32; m * split];
            let mut right = vec![0.0f32; m * (n - split)];
            matmul_t_into(&a, m, &b[..split * k], split, k, &mut left);
            matmul_t_into(&a, m, &b[split * k..], n - split, k, &mut right);
            for i in 0..m {
                for j in 0..n {
                    let v = if j < split {
                        left[i * split + j]
                    } else {
                        right[i * (n - split) + (j - split)]
                    };
                    prop_assert!(
                        v.to_bits() == got[i * n + j].to_bits(),
                        "chunk split at {split} moved cell ({i},{j}) of ({m},{n},{k})"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_q8_kernel_bit_identical_to_dot_q8_reference() {
    check("kernel-q8-exactness", 12, |g| {
        let nt = 1 + g.int_in(0, 7);
        let len = 1 + g.int_in(0, 30);
        // k straddles the 64-wide block: partial blocks, exact multiples,
        // and sub-block rows all occur.
        let k = 1 + g.int_in(0, 300);
        let blocks = blocks_of(k);
        let mut a = vec![0.0f32; nt * k];
        let mut b = vec![0.0f32; len * k];
        g.rng.fill_normal(&mut a, 2.0);
        g.rng.fill_normal(&mut b, 2.0);
        let (ac, asc) = quantize_rows(&a, nt, k);
        let (bc, bsc) = quantize_rows(&b, len, k);
        let mut got = vec![0.0f32; nt * len];
        scan_q8_into(&ac, &asc, nt, &bc, &bsc, len, k, &mut got);
        let mut got_scalar = vec![0.0f32; nt * len];
        scan_q8_scalar_into(&ac, &asc, nt, &bc, &bsc, len, k, &mut got_scalar);
        for t in 0..nt {
            for j in 0..len {
                let want = dot_q8(
                    &ac[t * k..(t + 1) * k],
                    &asc[t * blocks..(t + 1) * blocks],
                    &bc[j * k..(j + 1) * k],
                    &bsc[j * blocks..(j + 1) * blocks],
                );
                prop_assert!(
                    got[t * len + j].to_bits() == want.to_bits(),
                    "dispatched q8 ({t},{j}) of ({nt},{len},{k}) != dot_q8"
                );
                prop_assert!(
                    got_scalar[t * len + j].to_bits() == want.to_bits(),
                    "scalar q8 ({t},{j}) of ({nt},{len},{k}) != dot_q8"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn scalar_and_dispatched_dots_agree_within_tolerance() {
    // The arms may round differently (FMA fuses the multiply), but they
    // must describe the same mathematical dot.
    let mut rng = Pcg32::seeded(29);
    for &k in &[1usize, 7, 8, 9, 63, 64, 65, 192, 777] {
        let mut a = vec![0.0f32; k];
        let mut b = vec![0.0f32; k];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let d = dot_f32(&a, &b);
        let s = dot_f32_scalar(&a, &b);
        let tol = 1e-4 * (1.0 + s.abs() + (k as f32).sqrt());
        assert!((d - s).abs() <= tol, "k={k}: dispatched {d} vs scalar {s}");
    }
}

#[test]
fn warm_pool_scratch_stops_growing() {
    // The zero-alloc contract at the serving level: once the pool is
    // warm, further queries must not grow any worker's scratch.
    let k = 24;
    let n = 400;
    let src = tmpdir("pool-scratch-src");
    let mut rng = Pcg32::seeded(31);
    let rows = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("pool-scratch-sharded");
    shard_store(&src, &sharded, 4).unwrap();
    let store = Arc::new(ShardedStore::open(&sharded).unwrap());
    let mut hess = BlockHessian::single_block(k);
    hess.accumulate(&rows, n);
    let precond = Arc::new(hess.preconditioner(0.1).unwrap());
    let workers = 2;
    let pool = Arc::new(ScanPool::spawn(workers));
    let engine = ParallelQueryEngine::new(
        store,
        precond,
        // 400 rows / 4 shards / 32 = multi-chunk shards
        BackendConfig { chunk_len: 32, pool: Some(pool.clone()), ..Default::default() },
    );
    let mut test = vec![0.0f32; 2 * k];
    rng.fill_normal(&mut test, 1.0);

    // Warmup: enough queries that every worker has seen the peak lease.
    for _ in 0..8 {
        engine.query(QueryRequest::gradients(test.clone(), 2, 5)).unwrap();
    }
    let warm: u64 = pool.snapshot().scratch_grows.iter().sum();
    assert!(
        warm <= 2 * workers as u64,
        "warmup grew scratch {warm} times across {workers} workers"
    );
    for _ in 0..20 {
        engine.query(QueryRequest::gradients(test.clone(), 2, 5)).unwrap();
    }
    let after: u64 = pool.snapshot().scratch_grows.iter().sum();
    assert_eq!(after, warm, "steady-state queries grew worker scratch");
    pool.shutdown();
}

#[test]
fn auto_chunk_len_serves_bit_identical_results() {
    // chunk_len = 0 (the new default) derives an L2-sized chunk; results
    // must be bitwise what any explicit chunking produces.
    let k = 18;
    let n = 500;
    let src = tmpdir("auto-chunk-src");
    let mut rng = Pcg32::seeded(37);
    let rows = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("auto-chunk-sharded");
    shard_store(&src, &sharded, 3).unwrap();
    let store = Arc::new(ShardedStore::open(&sharded).unwrap());
    let single = GradStore::open(&src).unwrap();
    let mut hess = BlockHessian::single_block(k);
    hess.accumulate(&rows, n);
    let precond = Arc::new(hess.preconditioner(0.1).unwrap());
    let mut test = vec![0.0f32; 3 * k];
    rng.fill_normal(&mut test, 1.0);

    for norm in [Normalization::None, Normalization::RelatIf] {
        let seq_explicit = QueryEngine::new_native(&single, &precond, 37);
        let want = seq_explicit.query(&test, 3, 8, norm).unwrap();
        let seq_auto = QueryEngine::new_native(&single, &precond, 0);
        let got = seq_auto.query(&test, 3, 8, norm).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.top, b.top, "sequential auto-chunk diverged (norm {norm:?})");
        }
        let par_auto = ParallelQueryEngine::new(
            store.clone(),
            precond.clone(),
            BackendConfig { workers: 2, ..Default::default() },
        );
        let got = par_auto
            .query(QueryRequest::gradients(test.clone(), 3, 8).with_norm(norm))
            .unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.top, b.top, "parallel auto-chunk diverged (norm {norm:?})");
        }
    }
}

#[test]
fn kernel_arm_reports_a_name() {
    let arm = kernels::kernel_arm();
    assert!(matches!(arm.name(), "avx2+fma" | "scalar"));
}
