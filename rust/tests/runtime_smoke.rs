//! Integration: load real AOT artifacts and execute them via PJRT.
//! Requires `make artifacts` (skips gracefully otherwise).

use std::path::Path;

use logra::runtime::{literal, Runtime};
use logra::util::rng::Pcg32;

fn open(name: &str) -> Option<Runtime> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let dir = root.join("artifacts").join(name);
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/{name} not built");
        return None;
    }
    Some(Runtime::open(&dir).expect("open runtime"))
}

#[test]
fn lm_tiny_init_and_logra_log() {
    let Some(rt) = open("lm_tiny") else { return };
    let man = rt.manifest.clone();
    assert!(man.is_lm());

    // init(seed) -> params
    let out = rt.run("init", &[literal::u32_scalar(0)]).unwrap();
    assert_eq!(out.len(), 1);
    let params = literal::to_f32_vec(&out[0]).unwrap();
    assert_eq!(params.len(), man.n_params);
    assert!(params.iter().all(|v| v.is_finite()));
    // Deterministic per seed.
    let again = rt.run("init", &[literal::u32_scalar(0)]).unwrap();
    assert_eq!(literal::to_f32_vec(&again[0]).unwrap(), params);

    // logra_log(params, P, tokens) -> (G [B,K], loss [B])
    let mut rng = Pcg32::seeded(1);
    let mut proj = vec![0.0f32; man.proj_len];
    rng.fill_normal(&mut proj, 0.3);
    let b = man.log_batch;
    let t = man.seq_len;
    let tokens: Vec<i32> =
        (0..b * t).map(|_| rng.below(man.vocab as u32) as i32).collect();
    let out = rt
        .run(
            "logra_log",
            &[
                literal::f32_lit(&[man.n_params], &params).unwrap(),
                literal::f32_lit(&[man.proj_len], &proj).unwrap(),
                literal::i32_lit(&[b, t], &tokens).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
    let g = literal::to_f32_vec(&out[0]).unwrap();
    let loss = literal::to_f32_vec(&out[1]).unwrap();
    assert_eq!(g.len(), b * man.k_total);
    assert_eq!(loss.len(), b);
    assert!(loss.iter().all(|&l| l.is_finite() && l > 0.0));
    assert!(g.iter().any(|&x| x != 0.0));

    // Scale property: 3x projection scales G by 3 (per-layer bilinearity in
    // P_i,P_o means x9 overall for both sides scaled; scale only P here).
    let proj3: Vec<f32> = proj.iter().map(|x| x * 3.0).collect();
    let out3 = rt
        .run(
            "logra_log",
            &[
                literal::f32_lit(&[man.n_params], &params).unwrap(),
                literal::f32_lit(&[man.proj_len], &proj3).unwrap(),
                literal::i32_lit(&[b, t], &tokens).unwrap(),
            ],
        )
        .unwrap();
    let g3 = literal::to_f32_vec(&out3[0]).unwrap();
    for (a, b) in g.iter().zip(&g3) {
        assert!((b - 9.0 * a).abs() <= 1e-3 * a.abs().max(1.0), "{a} {b}");
    }
}

#[test]
fn lm_tiny_train_step_learns() {
    let Some(rt) = open("lm_tiny") else { return };
    let man = rt.manifest.clone();
    let params0 =
        literal::to_f32_vec(&rt.run("init", &[literal::u32_scalar(1)]).unwrap()[0])
            .unwrap();
    let n = man.n_params;
    let mut params = params0;
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut step = 0i32;
    let bsz = man.train_batch;
    let t = man.seq_len;
    // One fixed batch: loss must drop when overfitting it.
    let mut rng = Pcg32::seeded(2);
    let tokens: Vec<i32> =
        (0..bsz * t).map(|_| rng.below(man.vocab as u32) as i32).collect();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for it in 0..15 {
        let out = rt
            .run(
                "train_step",
                &[
                    literal::f32_lit(&[n], &params).unwrap(),
                    literal::f32_lit(&[n], &m).unwrap(),
                    literal::f32_lit(&[n], &v).unwrap(),
                    literal::i32_scalar(step),
                    literal::i32_lit(&[bsz, t], &tokens).unwrap(),
                ],
            )
            .unwrap();
        params = literal::to_f32_vec(&out[0]).unwrap();
        m = literal::to_f32_vec(&out[1]).unwrap();
        v = literal::to_f32_vec(&out[2]).unwrap();
        step = literal::to_i32_scalar(&out[3]).unwrap();
        let loss = literal::to_f32_scalar(&out[4]).unwrap();
        if it == 0 {
            first = loss;
        }
        last = loss;
    }
    assert_eq!(step, 15);
    assert!(last < first, "loss did not drop: {first} -> {last}");
}

#[test]
fn score_artifact_matches_host_matmul() {
    let Some(rt) = open("lm_tiny") else { return };
    let man = rt.manifest.clone();
    let (qb, tc, k) = (man.test_batch, man.train_chunk, man.k_total);
    let mut rng = Pcg32::seeded(3);
    let mut gt = vec![0.0f32; qb * k];
    let mut gn = vec![0.0f32; tc * k];
    rng.fill_normal(&mut gt, 1.0);
    rng.fill_normal(&mut gn, 1.0);
    let out = rt
        .run(
            "score",
            &[
                literal::f32_lit(&[qb, k], &gt).unwrap(),
                literal::f32_lit(&[tc, k], &gn).unwrap(),
            ],
        )
        .unwrap();
    let s = literal::to_f32_vec(&out[0]).unwrap();
    assert_eq!(s.len(), qb * tc);
    use logra::linalg::Matrix;
    let a = Matrix::from_vec(qb, k, gt);
    let b = Matrix::from_vec(tc, k, gn);
    let want = a.matmul_t(&b);
    for (x, y) in s.iter().zip(&want.data) {
        assert!((x - y).abs() < 1e-2 * y.abs().max(1.0), "{x} vs {y}");
    }
}

#[test]
fn mlp_artifacts_run() {
    let Some(rt) = open("mlp_fmnist") else { return };
    let man = rt.manifest.clone();
    assert!(!man.is_lm());
    let params =
        literal::to_f32_vec(&rt.run("init", &[literal::u32_scalar(0)]).unwrap()[0])
            .unwrap();
    assert_eq!(params.len(), man.n_params);
    let b = man.log_batch;
    let d = man.input_dim;
    let mut rng = Pcg32::seeded(4);
    let mut x = vec![0.0f32; b * d];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..b).map(|_| rng.below(man.classes as u32) as i32).collect();
    let out = rt
        .run(
            "eval_loss",
            &[
                literal::f32_lit(&[man.n_params], &params).unwrap(),
                literal::f32_lit(&[b, d], &x).unwrap(),
                literal::i32_lit(&[b], &y).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2); // (loss [B], logits [B, C])
    let loss = literal::to_f32_vec(&out[0]).unwrap();
    let logits = literal::to_f32_vec(&out[1]).unwrap();
    assert_eq!(loss.len(), b);
    assert_eq!(logits.len(), b * man.classes);
    // Untrained loss should be near ln(classes).
    let want = (man.classes as f32).ln();
    let mean: f32 = loss.iter().sum::<f32>() / b as f32;
    assert!((mean - want).abs() < 1.0, "mean loss {mean}, ln(C)={want}");
}
