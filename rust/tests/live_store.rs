//! Live-growth integration tests: generation-numbered manifests, the
//! fault-injection harness, and generation-snapshotted serving.
//!
//! The contract under test, end to end:
//!
//! 1. **Publish is atomic**: an append that crashes mid-finalize or tears
//!    the manifest rename leaves the previous generation fully servable —
//!    bit-identical scores before and after the failed publish — and a
//!    later retry succeeds over the debris.
//! 2. **Degradation is graceful**: a shard that fails validation makes
//!    the strict open name the shard and its row counts, while
//!    [`Valuator::open_degraded`] quarantines it and keeps serving.
//! 3. **Serving is snapshot-pinned**: `logra serve` with a reload
//!    interval follows the manifest generation; every response carries
//!    the generation it was answered under, and appends racing a query
//!    stream never produce an error or a generation that was never
//!    published.
//! 4. **IVF follows growth**: a shard added by `store quantize
//!    --incremental` serves through the per-shard full-scan fallback,
//!    visible on `/metrics`.
//!
//! Fault-driven tests hold [`fault::exclusive`] and arm only
//! path-filtered fault specs (the fault set is process-global and cargo
//! runs tests concurrently).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use logra::coordinator::Metrics;
use logra::serve::{loadgen, ReloadConfig, ServeConfig, Server};
use logra::store::{
    append_shard, build_index, current_generation, fault, quantize_store,
    quantize_store_incremental, shard_store, AppendReport, GradStoreWriter, ShardManifest,
    ShardedStore,
};
use logra::util::json::{self, Json};
use logra::util::rng::Pcg32;
use logra::valuation::{Backend, PoolMode, QueryRequest, ScanBackend, ScanPool, Valuator};

fn sharded_store(name: &str, n: usize, k: usize, shards: usize, seed: u64) -> PathBuf {
    let base = std::env::temp_dir().join("logra-live-it").join(name);
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let src = base.join("flat");
    let mut rng = Pcg32::seeded(seed);
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut w = GradStoreWriter::create(&src, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    let dir = base.join("sharded");
    shard_store(&src, &dir, shards).unwrap();
    dir
}

/// Append `n` synthetic rows as one new shard, ids continuing from the
/// current total.
fn grow(dir: &Path, n: usize, k: usize, seed: u64) -> AppendReport {
    let mut rng = Pcg32::seeded(seed);
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let start = ShardManifest::load(dir).unwrap().total_rows();
    let ids: Vec<u64> = (start..start + n as u64).collect();
    append_shard(dir, &ids, &rows).unwrap()
}

/// Top-k (score bits, id) of querying row 0 through a fresh Valuator —
/// the bit-exact oracle for "the previous generation still serves".
fn topk_bits(dir: &Path) -> Vec<(u64, u64)> {
    let v = Valuator::open(dir).unwrap().fit_from_store(0.1).build().unwrap();
    let g = v.gradient_row(0).unwrap();
    let res = v.query(QueryRequest::gradients(g, 1, 5)).unwrap();
    res[0].top.iter().map(|&(s, id)| (s.to_bits(), id)).collect()
}

/// Boot a reload-following server on a free port over a shared pool.
fn start_reload_server(dir: &Path, interval_ms: u64) -> (Server, String) {
    let metrics = Arc::new(Metrics::default());
    let pool = Arc::new(ScanPool::spawn(2));
    let valuator = Arc::new(
        Valuator::open_degraded(dir)
            .unwrap()
            .backend(Backend::Auto)
            .workers(2)
            .fit_from_store(0.1)
            .pool(PoolMode::Shared(pool.clone()))
            .metrics(metrics.clone())
            .build()
            .unwrap(),
    );
    let cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() };
    let reload = ReloadConfig::standard(
        dir.to_path_buf(),
        Duration::from_millis(interval_ms),
        Backend::Auto,
        0.1,
        2,
        pool,
        metrics.clone(),
    );
    let server = Server::start_with_reload(valuator, metrics, cfg, Some(reload)).unwrap();
    let addr = server.addr().to_string();
    (server, addr)
}

/// First sample value of an unlabelled family in an exposition body.
fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        let rest = l.strip_prefix(name)?;
        let rest = rest.strip_prefix(' ')?;
        rest.trim().parse().ok()
    })
}

fn scrape(addr: &str) -> String {
    let res = loadgen::http_request(addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(res.status, 200);
    res.body_str()
}

fn healthz(addr: &str) -> Json {
    let res = loadgen::http_request(addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    json::parse(&res.body_str()).unwrap()
}

/// Poll `/metrics` until `name` reaches `want` (reloads are asynchronous).
fn await_metric(addr: &str, name: &str, want: f64) {
    let t0 = Instant::now();
    loop {
        let text = scrape(addr);
        if metric_value(&text, name).is_some_and(|v| v >= want) {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "{name} never reached {want}: {:?}",
            metric_value(&text, name)
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn append_advances_generation_and_valuator_sees_it() {
    let dir = sharded_store("gen-roundtrip", 48, 8, 3, 50);
    assert_eq!(current_generation(&dir).unwrap(), 1);
    let v = Valuator::open(&dir).unwrap().fit_from_store(0.1).build().unwrap();
    assert_eq!(v.generation(), 1);
    assert_eq!(v.rows(), 48);
    assert!(v.quarantined().is_empty());

    let rep = grow(&dir, 6, 8, 51);
    assert_eq!(rep.generation, 2);
    assert_eq!(rep.rows, 6);
    assert_eq!(current_generation(&dir).unwrap(), 2);

    let v = Valuator::open(&dir).unwrap().fit_from_store(0.1).build().unwrap();
    assert_eq!(v.generation(), 2);
    assert_eq!(v.rows(), 54, "appended rows must be servable");
}

#[test]
fn torn_manifest_rename_preserves_previous_generation_bit_identical() {
    let dir = sharded_store("live-tear", 48, 8, 3, 52);
    let before = topk_bits(&dir);

    let _x = fault::exclusive();
    fault::arm("manifest_tear=live-tear");
    let err = {
        let mut rng = Pcg32::seeded(53);
        let mut rows = vec![0.0f32; 4 * 8];
        rng.fill_normal(&mut rows, 1.0);
        append_shard(&dir, &[48, 49, 50, 51], &rows).unwrap_err()
    };
    fault::disarm();
    drop(_x);
    assert!(format!("{err:#}").contains("fault injected"), "got: {err:#}");

    // The publish never happened: same generation, same row count, and
    // the exact same score bits as before the failed append.
    assert_eq!(current_generation(&dir).unwrap(), 1);
    assert_eq!(ShardedStore::open(&dir).unwrap().rows(), 48);
    assert_eq!(topk_bits(&dir), before, "failed publish must not perturb scores");

    // Recovery: the same append over the leftover temp file and shard
    // debris publishes cleanly.
    let rep = grow(&dir, 4, 8, 53);
    assert_eq!(rep.generation, 2);
    assert_eq!(ShardedStore::open(&dir).unwrap().rows(), 52);
}

#[test]
fn mid_finalize_crash_leaves_old_generation_servable() {
    let dir = sharded_store("live-crash", 48, 8, 3, 54);
    let before = topk_bits(&dir);

    let _x = fault::exclusive();
    fault::arm("finalize_truncate=live-crash");
    let err = {
        let mut rng = Pcg32::seeded(55);
        let mut rows = vec![0.0f32; 4 * 8];
        rng.fill_normal(&mut rows, 1.0);
        append_shard(&dir, &[48, 49, 50, 51], &rows).unwrap_err()
    };
    fault::disarm();
    drop(_x);
    assert!(format!("{err:#}").contains("fault injected"), "got: {err:#}");

    // The torn shard is invisible: the manifest never mentioned it.
    assert_eq!(current_generation(&dir).unwrap(), 1);
    assert_eq!(ShardedStore::open(&dir).unwrap().rows(), 48);
    assert_eq!(topk_bits(&dir), before);

    // The debris directory is cleared and rewritten by the retry.
    let rep = grow(&dir, 4, 8, 55);
    assert_eq!(rep.shard_dir, "shard-0003");
    assert_eq!(rep.generation, 2);
    assert_eq!(ShardedStore::open(&dir).unwrap().rows(), 52);
}

#[test]
fn corrupt_shard_fails_strict_open_with_context_and_quarantines_degraded() {
    let dir = sharded_store("quarantine", 48, 8, 4, 56);
    let man = ShardManifest::load(&dir).unwrap();
    let victim = man.shard_dirs[1].clone();
    let victim_rows = man.shard_rows[1];

    // Bit rot: halve the payload of one finalized shard.
    let grads = dir.join(&victim).join("grads.bin");
    let len = std::fs::metadata(&grads).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&grads).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);

    // Strict open names the shard and the row counts involved.
    let err = ShardedStore::open(&dir).unwrap_err().to_string();
    assert!(err.contains(&victim), "error {err:?} must name {victim}");
    assert!(
        err.contains(&format!("{victim_rows} rows")),
        "error {err:?} must carry the expected row count"
    );

    // The degraded open quarantines it and serves the survivors.
    let v = Valuator::open_degraded(&dir)
        .unwrap()
        .fit_from_store(0.1)
        .build()
        .unwrap();
    assert_eq!(v.quarantined().len(), 1);
    assert_eq!(v.quarantined()[0].name, victim);
    assert_eq!(v.generation(), 1);
    assert_eq!(v.rows() as u64, 48 - victim_rows);
    let g = v.gradient_row(0).unwrap();
    let res = v.query(QueryRequest::gradients(g, 1, 5)).unwrap();
    assert_eq!(res[0].top.len(), 5, "survivors must keep answering");
}

#[test]
fn serve_reload_swaps_generation_under_load() {
    let dir = sharded_store("serve-reload", 64, 8, 4, 57);
    let (_server, addr) = start_reload_server(&dir, 25);

    let h = healthz(&addr);
    assert_eq!(h.get("generation").and_then(Json::as_u64), Some(1));
    assert_eq!(h.get("rows").and_then(Json::as_u64), Some(64));

    // A response names the generation it was answered under.
    let res = loadgen::http_request(&addr, "POST", "/query", br#"{"row": 0}"#).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    assert_eq!(v.get("generation").and_then(Json::as_u64), Some(1));

    // Publish generation 2; the reloader swaps it in without a restart.
    let rep = grow(&dir, 8, 8, 58);
    assert_eq!(rep.generation, 2);
    await_metric(&addr, "logra_store_generation", 2.0);
    await_metric(&addr, "logra_store_reloads_total", 1.0);

    let h = healthz(&addr);
    assert_eq!(h.get("generation").and_then(Json::as_u64), Some(2));
    assert_eq!(h.get("rows").and_then(Json::as_u64), Some(72));
    assert_eq!(h.get("quarantined_shards").and_then(Json::as_u64), Some(0));

    // The appended rows are queryable on the new snapshot.
    let res = loadgen::http_request(&addr, "POST", "/query", br#"{"row": 70}"#).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    assert_eq!(v.get("generation").and_then(Json::as_u64), Some(2));
}

#[test]
fn reload_quarantines_bad_shard_instead_of_dying() {
    let dir = sharded_store("serve-quarantine", 48, 8, 3, 59);
    let (_server, addr) = start_reload_server(&dir, 25);
    assert_eq!(healthz(&addr).get("generation").and_then(Json::as_u64), Some(1));

    // Publish a generation whose new shard is garbage (references a
    // directory that does not exist). The strict open would die; the
    // reload path must quarantine it and keep serving everything else.
    let mut man = ShardManifest::load(&dir).unwrap();
    man.shard_dirs.push("shard-0099".into());
    man.shard_rows.push(7);
    man.generation += 1;
    man.save(&dir).unwrap();

    await_metric(&addr, "logra_store_generation", 2.0);
    await_metric(&addr, "logra_store_quarantined_shards", 1.0);

    let h = healthz(&addr);
    assert_eq!(h.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(h.get("generation").and_then(Json::as_u64), Some(2));
    assert_eq!(h.get("quarantined_shards").and_then(Json::as_u64), Some(1));
    assert_eq!(h.get("rows").and_then(Json::as_u64), Some(48));

    let res = loadgen::http_request(&addr, "POST", "/query", br#"{"row": 0}"#).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
}

#[test]
fn concurrent_appends_never_blend_generations() {
    let dir = sharded_store("serve-blend", 64, 8, 4, 60);
    let (_server, addr) = start_reload_server(&dir, 10);

    // Two query threads hammer row 0 while the main thread publishes
    // three more generations. Every response must be a 200 whose
    // generation is one that was actually published (1..=4) — a blend or
    // an unpublished generation is the bug this PR exists to prevent.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let clients: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut gens = Vec::new();
                while !stop.load(std::sync::atomic::Ordering::Acquire) {
                    let res =
                        loadgen::http_request(&addr, "POST", "/query", br#"{"row": 0}"#)
                            .expect("query I/O failed");
                    assert_eq!(res.status, 200, "{}", res.body_str());
                    let v = json::parse(&res.body_str()).unwrap();
                    gens.push(v.get("generation").and_then(Json::as_u64).unwrap());
                }
                gens
            })
        })
        .collect();

    for (i, seed) in [(2u64, 61u64), (3, 62), (4, 63)] {
        let rep = grow(&dir, 8, 8, seed);
        assert_eq!(rep.generation, i);
        std::thread::sleep(Duration::from_millis(40));
    }
    await_metric(&addr, "logra_store_generation", 4.0);
    // Let the clients take a few laps against the final snapshot before
    // stopping, so the assertion below sees post-reload generations.
    std::thread::sleep(Duration::from_millis(150));
    stop.store(true, std::sync::atomic::Ordering::Release);

    let mut seen = Vec::new();
    for c in clients {
        let gens = c.join().unwrap();
        assert!(!gens.is_empty(), "client issued no queries");
        for g in gens {
            assert!(
                (1..=4).contains(&g),
                "response generation {g} was never published"
            );
            seen.push(g);
        }
    }
    assert!(
        seen.iter().any(|&g| g > 1),
        "reload never became visible to the query stream: {seen:?}"
    );
}

#[test]
fn incremental_quantize_skips_up_to_date_shards() {
    let dir = sharded_store("inc-quant", 60, 8, 3, 64);
    let base = dir.parent().unwrap().to_path_buf();
    let q8 = base.join("q8");
    let man = quantize_store(&dir, &q8).unwrap();
    assert_eq!(man.generation, 1);

    // Nothing changed: no conversion, no new generation published.
    let (man, rep) = quantize_store_incremental(&dir, &q8).unwrap();
    assert_eq!((rep.converted, rep.skipped), (0, 3));
    assert_eq!(man.generation, 1);
    assert_eq!(ShardManifest::load(&q8).unwrap().generation, 1);

    // Grow the source: exactly the new shard is converted.
    grow(&dir, 10, 8, 65);
    let (man, rep) = quantize_store_incremental(&dir, &q8).unwrap();
    assert_eq!((rep.converted, rep.skipped), (1, 3));
    assert_eq!(man.generation, 2);
    assert_eq!(man.total_rows(), 70);
}

#[test]
fn ivf_fallback_shard_appears_under_reload() {
    let dir = sharded_store("ivf-grow", 60, 8, 3, 66);
    let base = dir.parent().unwrap().to_path_buf();
    let q8 = base.join("q8");
    quantize_store(&dir, &q8).unwrap();
    build_index(&q8, 4, 7).unwrap();
    assert_eq!(current_generation(&q8).unwrap(), 2);

    let (_server, addr) = start_reload_server(&q8, 25);
    let text = scrape(&addr);
    assert_eq!(metric_value(&text, "logra_store_ivf_fallback_shards"), Some(0.0));

    // Grow the f32 source, mirror it incrementally: the new int8 shard
    // has no IVF sidecars, so the reloaded index serves it via the
    // per-shard full-scan fallback — visible, not fatal.
    grow(&dir, 10, 8, 67);
    let (man, rep) = quantize_store_incremental(&dir, &q8).unwrap();
    assert_eq!(rep.converted, 1);
    assert_eq!(man.generation, 3);

    await_metric(&addr, "logra_store_generation", 3.0);
    await_metric(&addr, "logra_store_ivf_fallback_shards", 1.0);
    let h = healthz(&addr);
    assert_eq!(h.get("ivf_fallback_shards").and_then(Json::as_u64), Some(1));
    assert_eq!(h.get("rows").and_then(Json::as_u64), Some(70));

    // Queries keep answering across the whole grown fabric.
    let res = loadgen::http_request(&addr, "POST", "/query", br#"{"row": 65}"#).unwrap();
    assert_eq!(res.status, 200, "{}", res.body_str());
    let v = json::parse(&res.body_str()).unwrap();
    assert_eq!(v.get("generation").and_then(Json::as_u64), Some(3));
}
