//! Sharded store + parallel scan-and-merge subsystem tests (artifact-free:
//! native scoring only, so these always run).
//!
//! The load-bearing property: for ANY shard decomposition of a store and
//! ANY worker count, the parallel engine's top-k (score, data_id) results
//! are identical to the sequential `QueryEngine` native scan over the
//! unsharded store, and every `chunk()` view is byte-identical.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use logra::hessian::BlockHessian;
use logra::prop_assert;
use logra::store::{
    merge_store, shard_store, GradStore, GradStoreWriter, ShardedStore, ShardedWriter,
};
use logra::util::proptest::check;
use logra::util::rng::Pcg32;
use logra::valuation::{
    BackendConfig, Normalization, ParallelQueryEngine, QueryEngine, QueryRequest, ScanBackend,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-shards-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a v1 store with n rows of seeded gaussian data; ids are shuffled
/// (NOT 0..n) so id-based tie-breaking is exercised honestly.
fn write_store(dir: &Path, n: usize, k: usize, rng: &mut Pcg32) -> (Vec<u64>, Vec<f32>) {
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let mut ids: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1000).collect();
    rng.shuffle(&mut ids);
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    (ids, rows)
}

fn make_precond(rows: &[f32], n: usize, k: usize) -> logra::hessian::Preconditioner {
    let mut h = BlockHessian::single_block(k);
    h.accumulate(rows, n);
    h.preconditioner(0.1).unwrap()
}

#[test]
fn prop_shard_decomposition_chunks_and_topk_identical() {
    check("shard-parity", 8, |g| {
        let k = 2 + g.int_in(0, 10);
        let n = 8 + g.int_in(0, 120);
        let n_shards = 1 + g.int_in(0, 5).min(n - 1);
        let workers = 1 + g.int_in(0, 3);
        let nt = 1 + g.int_in(0, 3);
        let topk = 1 + g.int_in(0, 9);

        let uniq = g.rng.next_u32();
        let src = tmpdir(&format!("parity-src-{uniq}"));
        let (ids, rows) = write_store(&src, n, k, &mut g.rng);
        let sharded = tmpdir(&format!("parity-dst-{uniq}"));
        shard_store(&src, &sharded, n_shards).unwrap();

        // Byte-identical chunk views under any in-shard decomposition.
        let fabric = ShardedStore::open(&sharded).unwrap();
        prop_assert!(fabric.rows() == n, "rows {} != {n}", fabric.rows());
        prop_assert!(fabric.k() == k, "k mismatch");
        let mut at = 0usize;
        while at < n {
            let max_len = fabric.contiguous_len(at);
            let len = 1 + g.rng.below_usize(max_len);
            prop_assert!(
                fabric.chunk(at, len) == &rows[at * k..(at + len) * k],
                "chunk mismatch at {at}+{len}"
            );
            at += len;
        }
        for i in 0..n {
            prop_assert!(fabric.id(i) == ids[i], "id mismatch at {i}");
        }

        // Identical top-k vs the sequential engine, both normalizations.
        let single = GradStore::open(&src).unwrap();
        let precond = Arc::new(make_precond(&rows, n, k));
        let chunk_len = 1 + g.rng.below_usize(n);
        let seq = QueryEngine::new_native(&single, &precond, chunk_len);
        let fabric = Arc::new(fabric);
        let mut test = vec![0.0f32; nt * k];
        g.rng.fill_normal(&mut test, 1.0);
        for norm in [Normalization::None, Normalization::RelatIf] {
            let want = seq.query(&test, nt, topk, norm).unwrap();
            let par = ParallelQueryEngine::new(
                fabric.clone(),
                precond.clone(),
                BackendConfig {
                    workers,
                    chunk_len: 1 + g.rng.below_usize(n),
                    ..Default::default()
                },
            );
            let got = par
                .query(QueryRequest::gradients(test.clone(), nt, topk).with_norm(norm))
                .unwrap();
            prop_assert!(got.len() == want.len(), "result count");
            for (t, (a, b)) in got.iter().zip(&want).enumerate() {
                prop_assert!(
                    a.top == b.top,
                    "top-k diverged (norm {norm:?}, test row {t}, shards {n_shards}, \
                     workers {workers}):\n  par {:?}\n  seq {:?}",
                    a.top,
                    b.top
                );
            }
        }
        Ok(())
    });
}

#[test]
fn duplicate_rows_tie_break_identically() {
    // Exact score ties (duplicated gradient rows) must resolve the same
    // way in both engines — the total-order TopK guarantee.
    let k = 4;
    let n = 32;
    let dir = tmpdir("ties-src");
    let mut rng = Pcg32::seeded(11);
    let mut one_row = vec![0.0f32; k];
    rng.fill_normal(&mut one_row, 1.0);
    let mut rows = Vec::with_capacity(n * k);
    for _ in 0..n {
        rows.extend_from_slice(&one_row); // every row identical
    }
    let ids: Vec<u64> = (0..n as u64).map(|i| 500 - i * 3).collect();
    let mut w = GradStoreWriter::create(&dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();

    let sharded = tmpdir("ties-dst");
    shard_store(&dir, &sharded, 5).unwrap();
    let single = GradStore::open(&dir).unwrap();
    let fabric = Arc::new(ShardedStore::open(&sharded).unwrap());
    let precond = Arc::new(make_precond(&rows, n, k));
    let mut test = vec![0.0f32; k];
    rng.fill_normal(&mut test, 1.0);

    let seq = QueryEngine::new_native(&single, &precond, 7);
    let want = seq.query(&test, 1, 6, Normalization::None).unwrap();
    let par = ParallelQueryEngine::new(
        fabric,
        precond.clone(),
        BackendConfig { workers: 3, chunk_len: 4, ..Default::default() },
    );
    let got = par.query(QueryRequest::gradients(test.clone(), 1, 6)).unwrap();
    assert_eq!(got[0].top, want[0].top);
    // All scores tie; kept ids must be the 6 smallest.
    let mut kept: Vec<u64> = got[0].top.iter().map(|&(_, id)| id).collect();
    let mut smallest = ids.clone();
    smallest.sort_unstable();
    smallest.truncate(6);
    kept.sort_unstable();
    assert_eq!(kept, smallest);
}

#[test]
fn parallel_self_influences_match_sequential() {
    let k = 6;
    let n = 40;
    let src = tmpdir("selfinf-src");
    let mut rng = Pcg32::seeded(21);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("selfinf-dst");
    shard_store(&src, &sharded, 3).unwrap();
    let single = GradStore::open(&src).unwrap();
    let fabric = Arc::new(ShardedStore::open(&sharded).unwrap());
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq = QueryEngine::new_native(&single, &precond, 8);
    let par = ParallelQueryEngine::new(
        fabric,
        precond.clone(),
        BackendConfig { workers: 2, chunk_len: 8, ..Default::default() },
    );
    assert_eq!(&*seq.train_self_influences(), &par.train_self_influences()[..]);
}

#[test]
fn crash_unfinalized_shard_serves_durable_rows() {
    // One shard "crashes" before finalize; the fabric still opens, serves
    // every durable row, and parallel queries agree with a sequential scan
    // of the surviving data.
    let k = 3;
    let dir = tmpdir("crash-fabric");
    let w = ShardedWriter::create(&dir, k, 3).unwrap();
    let mut writers = w.into_shard_writers();
    let mut rng = Pcg32::seeded(31);
    let mut survivors_rows: Vec<f32> = Vec::new();
    let mut survivors_ids: Vec<u64> = Vec::new();
    for (si, sw) in writers.iter_mut().enumerate() {
        let mut rows = vec![0.0f32; 5 * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (si as u64 * 100..si as u64 * 100 + 5).collect();
        sw.append(&ids, &rows).unwrap();
        if si != 1 {
            survivors_rows.extend_from_slice(&rows);
            survivors_ids.extend_from_slice(&ids);
        }
    }
    let w2 = writers.pop().unwrap();
    let w1 = writers.pop().unwrap();
    let w0 = writers.pop().unwrap();
    w0.finalize().unwrap();
    drop(w1); // crash: shard 1 never finalized
    w2.finalize().unwrap();

    let fabric = ShardedStore::open(&dir).unwrap();
    assert_eq!(fabric.rows(), 10);
    assert_eq!(fabric.shard(1).rows(), 0);
    for g in 0..10 {
        assert_eq!(fabric.id(g), survivors_ids[g]);
        assert_eq!(fabric.row(g), &survivors_rows[g * k..(g + 1) * k]);
    }

    // Queries over the degraded fabric == sequential scan of survivors.
    let merged = tmpdir("crash-merged");
    merge_store(&dir, &merged).unwrap();
    let single = GradStore::open(&merged).unwrap();
    let precond = Arc::new(make_precond(&survivors_rows, 10, k));
    let mut test = vec![0.0f32; k];
    rng.fill_normal(&mut test, 1.0);
    let seq = QueryEngine::new_native(&single, &precond, 4);
    let par = ParallelQueryEngine::new(
        Arc::new(fabric),
        precond.clone(),
        BackendConfig { workers: 2, chunk_len: 4, ..Default::default() },
    );
    assert_eq!(
        par.query(QueryRequest::gradients(test.clone(), 1, 5)).unwrap()[0].top,
        seq.query(&test, 1, 5, Normalization::None).unwrap()[0].top
    );
}

#[test]
fn legacy_v1_store_queries_unchanged() {
    // A v1 directory opens as a 1-shard fabric and the parallel engine
    // reproduces the sequential engine exactly on it.
    let k = 5;
    let n = 24;
    let dir = tmpdir("legacy-query");
    let mut rng = Pcg32::seeded(41);
    let (_, rows) = write_store(&dir, n, k, &mut rng);
    let single = GradStore::open(&dir).unwrap();
    let fabric = ShardedStore::open(&dir).unwrap();
    assert_eq!(fabric.n_shards(), 1);
    assert!(fabric.as_single().is_some());
    let precond = Arc::new(make_precond(&rows, n, k));
    let mut test = vec![0.0f32; 2 * k];
    rng.fill_normal(&mut test, 1.0);
    let seq = QueryEngine::new_native(&single, &precond, 6);
    let par = ParallelQueryEngine::new(
        Arc::new(fabric),
        precond.clone(),
        BackendConfig { workers: 4, chunk_len: 6, ..Default::default() },
    );
    for norm in [Normalization::None, Normalization::RelatIf] {
        let a = seq.query(&test, 2, 4, norm).unwrap();
        let b = par
            .query(QueryRequest::gradients(test.clone(), 2, 4).with_norm(norm))
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.top, y.top);
        }
    }
}
