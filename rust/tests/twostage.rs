//! Quantized-store + two-stage query engine tests (artifact-free).
//!
//! Load-bearing properties:
//! 1. With a rescore pool large enough to cover the whole corpus, the
//!    two-stage engine reproduces the sequential `QueryEngine` native-scan
//!    top-k BIT-IDENTICALLY — same (score, id) pairs — for any shard
//!    decomposition, worker count, and normalization.
//! 2. With the default small pool (`rescore_factor = 4`), recall@10
//!    against the exact scan stays high (the int8 codec preserves
//!    influence rankings, the PAPERS.md sketching observation).
//! 3. The int8 codec's reconstruction error is bounded by half a
//!    quantization step per value, and the quantized copy is ~4x smaller.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use logra::hessian::BlockHessian;
use logra::prop_assert;
use logra::store::quant::blocks_of;
use logra::store::{
    quantize_store, GradStore, GradStoreWriter, QuantShardedStore, ShardedStore, StoreCodec,
    QUANT_BLOCK,
};
use logra::util::proptest::check;
use logra::util::rng::Pcg32;
use logra::valuation::{
    BackendConfig, Normalization, QueryEngine, QueryRequest, ScanBackend, TwoStageEngine,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-twostage-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a v1 store with shuffled (non-sequential) ids so id-based
/// tie-breaking is exercised honestly.
fn write_store(dir: &Path, n: usize, k: usize, rng: &mut Pcg32) -> (Vec<u64>, Vec<f32>) {
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let mut ids: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1000).collect();
    rng.shuffle(&mut ids);
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    (ids, rows)
}

fn make_precond(rows: &[f32], n: usize, k: usize) -> logra::hessian::Preconditioner {
    let mut h = BlockHessian::single_block(k);
    h.accumulate(rows, n);
    h.preconditioner(0.1).unwrap()
}

#[test]
fn prop_full_pool_reproduces_exact_engine_bit_identically() {
    check("twostage-full-pool-parity", 8, |g| {
        let k = 2 + g.int_in(0, 10);
        let n = 8 + g.int_in(0, 100);
        let n_shards = 1 + g.int_in(0, 4).min(n - 1);
        let workers = 1 + g.int_in(0, 3);
        let nt = 1 + g.int_in(0, 3);
        let topk = 1 + g.int_in(0, 9);

        let uniq = g.rng.next_u32();
        let src = tmpdir(&format!("parity-src-{uniq}"));
        let (_, rows) = write_store(&src, n, k, &mut g.rng);
        let sharded = tmpdir(&format!("parity-sharded-{uniq}"));
        logra::store::shard_store(&src, &sharded, n_shards).unwrap();
        let quant_dir = tmpdir(&format!("parity-quant-{uniq}"));
        quantize_store(&sharded, &quant_dir).unwrap();

        let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
        let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
        let single = GradStore::open(&src).unwrap();
        let precond = Arc::new(make_precond(&rows, n, k));
        let seq = QueryEngine::new_native(&single, &precond, 1 + g.rng.below_usize(n));
        // rescore_factor large enough that the pool covers every row.
        let factor = n.div_ceil(topk) + 1;
        let mut test = vec![0.0f32; nt * k];
        g.rng.fill_normal(&mut test, 1.0);

        for norm in [Normalization::None, Normalization::RelatIf] {
            let want = seq.query(&test, nt, topk, norm).unwrap();
            let engine = TwoStageEngine::new(
                quant.clone(),
                exact.clone(),
                precond.clone(),
                BackendConfig {
                    workers,
                    chunk_len: 1 + g.rng.below_usize(n),
                    rescore_factor: factor,
                    ..Default::default()
                },
            )
            .unwrap();
            prop_assert!(
                engine.pool_size(topk) == n,
                "pool {} != corpus {n}",
                engine.pool_size(topk)
            );
            let got = engine
                .query(QueryRequest::gradients(test.clone(), nt, topk).with_norm(norm))
                .unwrap();
            prop_assert!(got.len() == want.len(), "result count");
            for (t, (a, b)) in got.iter().zip(&want).enumerate() {
                prop_assert!(
                    a.top == b.top,
                    "top-k diverged (norm {norm:?}, test row {t}, shards {n_shards}, \
                     workers {workers}, topk {topk}):\n  two-stage {:?}\n  exact {:?}",
                    a.top,
                    b.top
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_roundtrip_error_bounded() {
    check("quant-roundtrip-bound", 10, |g| {
        let k = 1 + g.int_in(0, 200);
        let n = 1 + g.int_in(0, 40);
        let uniq = g.rng.next_u32();
        let src = tmpdir(&format!("rt-src-{uniq}"));
        let mut rows = vec![0.0f32; n * k];
        g.rng.fill_normal(&mut rows, 2.0);
        let ids: Vec<u64> = (0..n as u64).collect();
        let mut w = GradStoreWriter::create(&src, k).unwrap();
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();
        let dst = tmpdir(&format!("rt-dst-{uniq}"));
        quantize_store(&src, &dst).unwrap();
        let q = QuantShardedStore::open(&dst).unwrap();
        prop_assert!(q.rows() == n, "rows {} != {n}", q.rows());

        let blocks = blocks_of(k);
        for r in 0..n {
            let orig = &rows[r * k..(r + 1) * k];
            let deq = q.shard(0).dequant_row(r);
            let scales = q.shard(0).scales_chunk(r, 1);
            for (i, (&v, &d)) in orig.iter().zip(&deq).enumerate() {
                let b = (i / QUANT_BLOCK).min(blocks - 1);
                // Symmetric round-to-nearest: ≤ half a step per value.
                let bound = scales[b] * 0.5 + 1e-6;
                prop_assert!(
                    (v - d).abs() <= bound,
                    "row {r} value {i}: |{v} - {d}| > {bound}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn small_pool_recall_stays_high() {
    // Default serving shape: rescore_factor 4, topk 10, a corpus big
    // enough that the pool (40) is a small fraction of it. The int8 coarse
    // scan must put nearly all of the true top-10 into the pool.
    let k = 96;
    let n = 1000;
    let nt = 8;
    let topk = 10;
    let src = tmpdir("recall-src");
    let mut rng = Pcg32::seeded(77);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("recall-sharded");
    logra::store::shard_store(&src, &sharded, 4).unwrap();
    let quant_dir = tmpdir("recall-quant");
    quantize_store(&sharded, &quant_dir).unwrap();

    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let single = GradStore::open(&src).unwrap();
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq = QueryEngine::new_native(&single, &precond, 128);
    let engine = TwoStageEngine::new(
        quant,
        exact,
        precond.clone(),
        BackendConfig { workers: 2, chunk_len: 128, rescore_factor: 4, ..Default::default() },
    )
    .unwrap();

    let mut test = vec![0.0f32; nt * k];
    rng.fill_normal(&mut test, 1.0);
    let want = seq.query(&test, nt, topk, Normalization::None).unwrap();
    let got = engine.query(QueryRequest::gradients(test.clone(), nt, topk)).unwrap();
    let mut hits = 0usize;
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.top.len(), topk);
        let truth: Vec<u64> = b.top.iter().map(|&(_, id)| id).collect();
        hits += a.top.iter().filter(|&&(_, id)| truth.contains(&id)).count();
    }
    let recall = hits as f64 / (nt * topk) as f64;
    assert!(recall >= 0.95, "recall@{topk} = {recall:.3} < 0.95");
}

#[test]
fn quantized_copy_is_4x_smaller_and_codec_tagged() {
    let k = 192; // paper-shaped row width
    let n = 512;
    let src = tmpdir("size-src");
    let mut rng = Pcg32::seeded(5);
    write_store(&src, n, k, &mut rng);
    let dst = tmpdir("size-dst");
    let man = quantize_store(&src, &dst).unwrap();
    assert_eq!(man.codec, StoreCodec::Int8);

    let f32_bytes = logra::store::stat_store(&src).unwrap().storage_bytes;
    let q8_stat = logra::store::stat_store(&dst).unwrap();
    assert_eq!(q8_stat.codec, StoreCodec::Int8);
    assert_eq!(q8_stat.rows, n);
    let ratio = f32_bytes as f64 / q8_stat.storage_bytes as f64;
    assert!(ratio > 3.0, "compression ratio only {ratio:.2}x");
    assert!(q8_stat.render().contains("codec         int8"));
}

#[test]
fn stale_quantized_copy_rejected() {
    // The engine refuses a quantized copy that no longer mirrors the
    // exact store (row count drift = stale conversion).
    let k = 8;
    let src_a = tmpdir("stale-a");
    let src_b = tmpdir("stale-b");
    let mut rng = Pcg32::seeded(3);
    let (_, rows_a) = write_store(&src_a, 20, k, &mut rng);
    write_store(&src_b, 30, k, &mut rng);
    let quant_b = tmpdir("stale-quant-b");
    quantize_store(&src_b, &quant_b).unwrap();

    let exact_a = Arc::new(ShardedStore::open(&src_a).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_b).unwrap());
    let precond = Arc::new(make_precond(&rows_a, 20, k));
    assert!(TwoStageEngine::new(quant, exact_a, precond, BackendConfig::default()).is_err());
}
