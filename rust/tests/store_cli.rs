//! Smoke test for the `store stat` CLI surface: the library function the
//! subcommand prints, over both v1 and sharded layouts.

use std::path::PathBuf;

use logra::store::{shard_store, stat_store, GradStoreWriter};
use logra::util::rng::Pcg32;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-store-cli-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn stat_on_v1_and_sharded_stores() {
    let src = tmpdir("stat-src");
    let k = 12;
    let n = 50;
    let mut rng = Pcg32::seeded(3);
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut w = GradStoreWriter::create(&src, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();

    // v1 directory: reported as a 1-shard fabric.
    let st = stat_store(&src).unwrap();
    assert_eq!(st.shards, 1);
    assert_eq!(st.rows, n);
    assert_eq!(st.k, k);
    // Storage column = grads.bin (header + rows*k*4) + ids.bin (rows*8).
    assert_eq!(st.storage_bytes, (32 + n * k * 4 + n * 8) as u64);

    // Sharded copy: same rows/k/storage math, shard breakdown visible.
    let dst = tmpdir("stat-dst");
    shard_store(&src, &dst, 3).unwrap();
    let st = stat_store(&dst).unwrap();
    assert_eq!(st.shards, 3);
    assert_eq!(st.rows, n);
    assert_eq!(st.k, k);
    assert_eq!(st.shard_rows, vec![17, 17, 16]);
    assert_eq!(st.storage_bytes, (3 * 32 + n * k * 4 + n * 8) as u64);

    let text = st.render();
    assert!(text.contains("codec         f32"), "render:\n{text}");
    assert!(text.contains("shards        3"), "render:\n{text}");
    assert!(text.contains("rows          50"), "render:\n{text}");
    assert!(text.contains("k             12"), "render:\n{text}");
    assert!(text.contains("storage_bytes"), "render:\n{text}");
    assert!(text.contains("shard-0002"), "render:\n{text}");

    // Quantized copy: same rows/k, int8 codec, ~4x smaller storage.
    let qdir = tmpdir("stat-quant");
    let man = logra::store::quantize_store(&dst, &qdir).unwrap();
    assert_eq!(man.n_shards(), 3);
    let qst = stat_store(&qdir).unwrap();
    assert_eq!(qst.codec, logra::store::StoreCodec::Int8);
    assert_eq!(qst.rows, n);
    assert_eq!(qst.k, k);
    assert_eq!(qst.shard_rows, vec![17, 17, 16]);
    assert!(qst.storage_bytes < st.storage_bytes);
    assert!(qst.render().contains("codec         int8"));

    // Missing directory is a clean error, not a panic.
    assert!(stat_store(&tmpdir("stat-missing").join("nope")).is_err());
}
