//! ScanPool integration tests (artifact-free: native scoring only).
//!
//! Load-bearing properties of the persistent pool as a serving substrate:
//!
//! 1. **Concurrent admission is deterministic**: M queries submitted from
//!    M threads — a mix of f32 parallel scans and two-stage quantized
//!    scans — interleave their shard tasks on one shared pool, and every
//!    result is bit-identical to the sequential `QueryEngine` native scan
//!    for that query.
//! 2. **Shutdown drains in-flight work**: queries admitted before
//!    `shutdown` still complete with correct results; admission afterwards
//!    is refused.
//! 3. **Panic isolation**: a poisoned scan task fails only its own query
//!    with an error — the pool neither hangs nor stops serving others.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use logra::hessian::BlockHessian;
use logra::store::{
    quantize_store, shard_store, GradStore, GradStoreWriter, QuantShardedStore, ShardedStore,
};
use logra::util::rng::Pcg32;
use logra::util::topk::TopK;
use logra::valuation::{
    BackendConfig, Normalization, ParallelQueryEngine, QueryEngine, QueryRequest, ScanBackend,
    ScanPool, TwoStageEngine, ValuationError,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-pool-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a v1 store with shuffled (non-sequential) ids so id-based
/// tie-breaking is exercised honestly.
fn write_store(dir: &Path, n: usize, k: usize, rng: &mut Pcg32) -> (Vec<u64>, Vec<f32>) {
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let mut ids: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1000).collect();
    rng.shuffle(&mut ids);
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    (ids, rows)
}

fn make_precond(rows: &[f32], n: usize, k: usize) -> logra::hessian::Preconditioner {
    let mut h = BlockHessian::single_block(k);
    h.accumulate(rows, n);
    h.preconditioner(0.1).unwrap()
}

#[test]
fn concurrent_mixed_queries_bit_identical_to_sequential() {
    let k = 12;
    let n = 360;
    let n_shards = 8;
    let nt = 2;
    let topk = 7;
    let src = tmpdir("conc-src");
    let mut rng = Pcg32::seeded(90);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("conc-sharded");
    shard_store(&src, &sharded, n_shards).unwrap();
    let quant_dir = tmpdir("conc-quant");
    quantize_store(&sharded, &quant_dir).unwrap();

    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let single = GradStore::open(&src).unwrap();
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq = QueryEngine::new_native(&single, &precond, 64);
    // Fewer workers than clients: shard tasks of different queries MUST
    // interleave on the same workers.
    let pool = Arc::new(ScanPool::spawn(3));

    // Per-thread query plans with the sequential oracle computed up front.
    let m = 6usize;
    let reps = 3usize;
    let mut plans: Vec<(Vec<f32>, Normalization, Vec<logra::valuation::QueryResult>)> =
        Vec::new();
    for t in 0..m {
        let mut trng = Pcg32::seeded(500 + t as u64);
        let mut test = vec![0.0f32; nt * k];
        trng.fill_normal(&mut test, 1.0);
        let norm = if t % 2 == 0 { Normalization::None } else { Normalization::RelatIf };
        let want = seq.query(&test, nt, topk, norm).unwrap();
        plans.push((test, norm, want));
    }
    // rescore_factor large enough that the two-stage pool covers every
    // row — the regime where two-stage results are bit-identical too.
    let factor = n.div_ceil(topk) + 1;

    std::thread::scope(|s| {
        for (t, (test, norm, want)) in plans.iter().enumerate() {
            let pool = pool.clone();
            let exact = exact.clone();
            let quant = quant.clone();
            let precond = precond.clone();
            s.spawn(move || {
                for _ in 0..reps {
                    let req = QueryRequest::gradients(test.clone(), nt, topk).with_norm(*norm);
                    let results = if t % 3 == 0 {
                        TwoStageEngine::new(
                            quant.clone(),
                            exact.clone(),
                            precond.clone(),
                            BackendConfig {
                                chunk_len: 32,
                                rescore_factor: factor,
                                pool: Some(pool.clone()),
                                ..Default::default()
                            },
                        )
                        .unwrap()
                        .query(req)
                        .unwrap()
                    } else {
                        ParallelQueryEngine::new(
                            exact.clone(),
                            precond.clone(),
                            BackendConfig {
                                chunk_len: 32,
                                pool: Some(pool.clone()),
                                ..Default::default()
                            },
                        )
                        .query(req)
                        .unwrap()
                    };
                    assert_eq!(results.len(), want.len(), "thread {t}");
                    for (row, (a, b)) in results.iter().zip(want).enumerate() {
                        assert_eq!(
                            a.top, b.top,
                            "thread {t} test row {row} diverged from sequential scan"
                        );
                    }
                }
            });
        }
    });

    let snap = pool.snapshot();
    assert_eq!(snap.workers, 3);
    assert_eq!(snap.in_flight, 0);
    assert_eq!(snap.tasks_failed, 0);
    // Every query fanned out over every shard.
    assert_eq!(snap.tasks_completed, (m * reps * n_shards) as u64);
    assert!(snap.total_busy_seconds() > 0.0);
    pool.shutdown();
}

#[test]
fn pooled_engines_match_unpooled_engines_with_small_rescore_pool() {
    // Even when the candidate pool does NOT cover the corpus (the lossy
    // serving regime), pooled execution must agree exactly with per-query
    // spawn execution: the candidate pool is a pure function of the
    // candidate multiset, not of scheduling.
    let k = 10;
    let n = 300;
    let src = tmpdir("small-pool-src");
    let mut rng = Pcg32::seeded(41);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("small-pool-sharded");
    shard_store(&src, &sharded, 5).unwrap();
    let quant_dir = tmpdir("small-pool-quant");
    quantize_store(&sharded, &quant_dir).unwrap();

    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let precond = Arc::new(make_precond(&rows, n, k));
    let pool = Arc::new(ScanPool::spawn(2));
    let mut test = vec![0.0f32; 3 * k];
    rng.fill_normal(&mut test, 1.0);

    for norm in [Normalization::None, Normalization::RelatIf] {
        let spawned = TwoStageEngine::new(
            quant.clone(),
            exact.clone(),
            precond.clone(),
            BackendConfig { workers: 2, chunk_len: 64, rescore_factor: 2, ..Default::default() },
        )
        .unwrap()
        .query(QueryRequest::gradients(test.clone(), 3, 9).with_norm(norm))
        .unwrap();
        let pooled = TwoStageEngine::new(
            quant.clone(),
            exact.clone(),
            precond.clone(),
            BackendConfig {
                chunk_len: 64,
                rescore_factor: 2,
                pool: Some(pool.clone()),
                ..Default::default()
            },
        )
        .unwrap()
        .query(QueryRequest::gradients(test.clone(), 3, 9).with_norm(norm))
        .unwrap();
        for (a, b) in pooled.iter().zip(&spawned) {
            assert_eq!(a.top, b.top, "norm {norm:?}");
        }
    }
    pool.shutdown();
}

#[test]
fn shutdown_drains_in_flight_queries() {
    let pool = Arc::new(ScanPool::spawn(2));
    let n_jobs = 5usize;
    let shards = 6usize;
    let pendings: Vec<_> = (0..n_jobs)
        .map(|j| {
            pool.submit(shards, move |si| {
                // Slow enough that shutdown arrives mid-flight.
                std::thread::sleep(Duration::from_millis(4));
                let mut t = TopK::new(1);
                t.push((j * 100 + si) as f64, si as u64);
                vec![t]
            })
            .unwrap()
        })
        .collect();
    // Shut down while tasks are still queued/running: must drain, not
    // abandon.
    pool.shutdown();
    for (j, pending) in pendings.into_iter().enumerate() {
        let out = pending.wait().unwrap_or_else(|e| panic!("job {j} lost: {e}"));
        assert_eq!(out.len(), shards);
        for (si, heaps) in out.into_iter().enumerate() {
            let sorted = heaps.into_iter().next().unwrap().into_sorted();
            assert_eq!(sorted, vec![((j * 100 + si) as f64, si as u64)]);
        }
    }
    // Admission after shutdown is refused, not hung.
    assert!(pool.submit(1, |_| Vec::new()).is_err());
    let snap = pool.snapshot();
    assert_eq!(snap.tasks_completed, (n_jobs * shards) as u64);
    assert_eq!(snap.in_flight, 0);
}

#[test]
fn poisoned_scan_fails_only_its_query_and_pool_keeps_serving() {
    let k = 8;
    let n = 120;
    let src = tmpdir("poison-src");
    let mut rng = Pcg32::seeded(61);
    let (_, rows) = write_store(&src, n, k, &mut rng);
    let sharded = tmpdir("poison-sharded");
    shard_store(&src, &sharded, 4).unwrap();
    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let single = GradStore::open(&src).unwrap();
    let precond = Arc::new(make_precond(&rows, n, k));
    let seq = QueryEngine::new_native(&single, &precond, 32);
    let pool = Arc::new(ScanPool::spawn(2));

    let engine = ParallelQueryEngine::new(
        exact,
        precond.clone(),
        BackendConfig { chunk_len: 32, pool: Some(pool.clone()), ..Default::default() },
    );
    let mut test = vec![0.0f32; k];
    rng.fill_normal(&mut test, 1.0);

    // Healthy query before the poison.
    let want = seq.query(&test, 1, 5, Normalization::None).unwrap();
    let got = engine.query(QueryRequest::gradients(test.clone(), 1, 5)).unwrap();
    assert_eq!(got[0].top, want[0].top);

    // A raw poisoned job: one shard task panics. Only ITS query errors —
    // and the completion handle reports it as the typed QueryPoisoned
    // variant, distinguishable from a shutdown.
    let poisoned = pool
        .submit(4, |si| {
            if si == 1 {
                panic!("injected scan fault");
            }
            let mut t = TopK::new(1);
            t.push(si as f64, si as u64);
            vec![t]
        })
        .unwrap();
    let err = poisoned.wait().unwrap_err();
    assert!(
        matches!(err, ValuationError::QueryPoisoned { .. }),
        "expected QueryPoisoned, got {err:?}"
    );
    let msg = err.to_string();
    assert!(msg.contains("panicked"), "unexpected error: {msg}");
    assert!(msg.contains("injected scan fault"), "message lost: {msg}");

    // The pool survives and keeps producing bit-identical results.
    let got = engine.query(QueryRequest::gradients(test.clone(), 1, 5)).unwrap();
    assert_eq!(got[0].top, want[0].top);
    let snap = pool.snapshot();
    assert_eq!(snap.tasks_failed, 1);
    assert_eq!(snap.in_flight, 0);
    pool.shutdown();
}
