//! End-to-end integration over real artifacts: logging pipeline ->
//! gradient store -> Fisher blocks -> query engine; baselines; service.
//! Requires `make artifacts` (tests skip gracefully otherwise).

use std::path::{Path, PathBuf};

use logra::baselines::{
    EkfacValuator, GradDotValuator, LograInit, LograValuator, RepSimValuator,
    TrakValuator, Valuator,
};
use logra::coordinator::{projected_grads, run_logging, LoggingOptions};
use logra::data::corpus::{generate as gen_corpus, CorpusSpec};
use logra::data::images::{generate as gen_images, generate_eval, ImageSpec};
use logra::hessian::random_projections;
use logra::model::dataset::Dataset;
use logra::model::trainer::Trainer;
use logra::runtime::Runtime;
use logra::util::rng::Pcg32;
use logra::valuation::{Normalization, QueryEngine};

fn open(name: &str) -> Option<Runtime> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let dir = root.join("artifacts").join(name);
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/{name} not built");
        return None;
    }
    Some(Runtime::open(&dir).expect("open runtime"))
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-pipeline-tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn lm_logging_and_self_retrieval() {
    let Some(rt) = open("lm_tiny") else { return };
    let man = rt.manifest.clone();
    let corpus = gen_corpus(CorpusSpec::new(man.vocab, man.seq_len, 48, 11));
    let ds = Dataset::Lm(&corpus);

    // Briefly train so gradients differentiate documents.
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(0).unwrap();
    let all: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Pcg32::seeded(1);
    trainer.train(&mut st, &ds, &all, 2, &mut rng).unwrap();

    let proj = random_projections(&man, &mut rng);
    let dir = tmpdir("lm-selfret");
    let (store, hess, report) =
        run_logging(&rt, &ds, &st.params, &proj, &dir, &LoggingOptions::default())
            .unwrap();
    assert_eq!(store.rows(), 48);
    assert_eq!(store.k(), man.k_total);
    assert!(report.tokens_per_sec > 0.0);
    let hess = hess.unwrap();
    assert_eq!(hess.count, 48);

    let precond = hess.preconditioner(0.1).unwrap();
    let engine = QueryEngine::new(&rt, &store, &precond);

    // Query WITH training documents: each doc should retrieve itself at
    // (or extremely near) the top — the self-influence sanity check.
    let qidx: Vec<usize> = vec![0, 7, 23];
    let (g, losses) = projected_grads(&rt, &ds, &qidx, &st.params, &proj).unwrap();
    assert_eq!(losses.len(), 3);
    let res = engine.query(&g, 3, 5, Normalization::None).unwrap();
    for (i, &qi) in qidx.iter().enumerate() {
        let ids: Vec<u64> = res[i].top.iter().map(|&(_, id)| id).collect();
        assert!(
            ids.contains(&(qi as u64)),
            "query {qi} not in its own top-5: {ids:?}"
        );
    }

    // Dense values agree with pair_influence.
    let vals = engine.values_matrix(&g, 3, Normalization::None).unwrap();
    for (i, _) in qidx.iter().enumerate() {
        let k = man.k_total;
        let row = &g[i * k..(i + 1) * k];
        for j in [0usize, 13, 47] {
            let direct = engine.pair_influence(row, j);
            assert!(
                (vals.at(i, j) - direct).abs() < 1e-3 * direct.abs().max(1.0),
                "values_matrix vs pair_influence mismatch"
            );
        }
    }

    // RelatIF shrinks high-self-influence rows but keeps finiteness.
    let res_rel = engine.query(&g, 3, 5, Normalization::RelatIf).unwrap();
    for r in &res_rel {
        assert!(r.top.iter().all(|(s, _)| s.is_finite()));
    }
}

#[test]
fn hlo_score_path_matches_native() {
    let Some(rt) = open("lm_tiny") else { return };
    let man = rt.manifest.clone();
    let corpus = gen_corpus(CorpusSpec::new(man.vocab, man.seq_len, man.train_chunk, 13));
    let ds = Dataset::Lm(&corpus);
    let trainer = Trainer::new(&rt);
    let st = trainer.init(2).unwrap();
    let mut rng = Pcg32::seeded(3);
    let proj = random_projections(&man, &mut rng);
    let dir = tmpdir("hlo-vs-native");
    let (store, hess, _) =
        run_logging(&rt, &ds, &st.params, &proj, &dir, &LoggingOptions::default())
            .unwrap();
    let precond = hess.unwrap().preconditioner(0.1).unwrap();

    let qidx: Vec<usize> = (0..man.test_batch).collect();
    let (g, _) = projected_grads(&rt, &ds, &qidx, &st.params, &proj).unwrap();

    let mut hlo_engine = QueryEngine::new(&rt, &store, &precond);
    hlo_engine.use_hlo = true;
    let a = hlo_engine
        .values_matrix(&g, qidx.len(), Normalization::None)
        .unwrap();
    let mut native = QueryEngine::new(&rt, &store, &precond);
    native.use_hlo = false;
    let b = native.values_matrix(&g, qidx.len(), Normalization::None).unwrap();
    assert!(rt.call_count("score") > 0, "HLO path not exercised");
    assert!(a.max_abs_diff(&b) < 1e-2 * b.fro_norm().max(1.0) / (b.data.len() as f32).sqrt());
}

#[test]
fn mlp_baselines_produce_sane_values() {
    let Some(rt) = open("mlp_fmnist") else { return };
    let man = rt.manifest.clone();
    let spec = ImageSpec::fmnist_like(man.input_dim, man.classes, 96, 5);
    let train_set = gen_images(spec);
    let test_set = generate_eval(spec, 16);
    let train = Dataset::Mlp(&train_set);
    let test = Dataset::Mlp(&test_set);
    let trainer = Trainer::new(&rt);
    let mut st = trainer.init(1).unwrap();
    let all: Vec<usize> = (0..train.len()).collect();
    let mut rng = Pcg32::seeded(2);
    trainer.train(&mut st, &train, &all, 3, &mut rng).unwrap();
    let params = st.params.clone();

    let test_idx: Vec<usize> = vec![0, 3, 9];
    let dir = tmpdir("mlp-baselines");

    let mut methods: Vec<Box<dyn Valuator>> = vec![
        Box::new(
            LograValuator::build(
                &rt,
                &train,
                &test,
                &params,
                LograInit::Random,
                dir.join("s1"),
                0.1,
                7,
            )
            .unwrap(),
        ),
        Box::new(
            LograValuator::build(
                &rt,
                &train,
                &test,
                &params,
                LograInit::Pca,
                dir.join("s2"),
                0.1,
                7,
            )
            .unwrap(),
        ),
        Box::new(GradDotValuator { rt: &rt, train: &train, test: &test, params: &params }),
        Box::new(TrakValuator::new(&rt, &train, &test, &params, 32, 0.1, 7)),
        Box::new(EkfacValuator::new(&rt, &train, &test, &params)),
        Box::new(RepSimValuator::new(&rt, &train, &test, &params)),
    ];
    let mut value_mats = Vec::new();
    for m in methods.iter_mut() {
        let v = m.values(&test_idx).unwrap();
        assert_eq!((v.rows, v.cols), (3, 96), "{}", m.name());
        assert!(
            v.data.iter().all(|x| x.is_finite()),
            "{} produced non-finite values",
            m.name()
        );
        assert!(v.data.iter().any(|&x| x != 0.0), "{} all-zero", m.name());
        value_mats.push((m.name(), v));
    }

    // Gradient-based methods should broadly agree with each other more
    // than chance (exact agreement is not expected: LoGra preconditions
    // with the projected Fisher, grad-dot does not, and the projections
    // differ). Check mean rank correlations are positive.
    let mean_spearman = |a: &logra::linalg::Matrix, b: &logra::linalg::Matrix| -> f64 {
        let mut acc = 0.0;
        for t in 0..a.rows {
            let x: Vec<f64> = a.row(t).iter().map(|&v| v as f64).collect();
            let y: Vec<f64> = b.row(t).iter().map(|&v| v as f64).collect();
            acc += logra::util::stats::spearman(&x, &y);
        }
        acc / a.rows as f64
    };
    let logra_rand = &value_mats[0].1;
    let logra_pca = &value_mats[1].1;
    let gd = &value_mats[2].1;
    let ekfac = &value_mats[4].1;
    assert!(
        mean_spearman(logra_rand, logra_pca) > 0.1,
        "logra inits disagree: {}",
        mean_spearman(logra_rand, logra_pca)
    );
    assert!(mean_spearman(logra_rand, gd) > 0.0, "logra vs grad-dot negative");
    assert!(
        mean_spearman(logra_rand, ekfac) > 0.0,
        "logra vs ekfac negative: {}",
        mean_spearman(logra_rand, ekfac)
    );
}

#[test]
fn valuation_service_batches_requests() {
    let Some(rt) = open("lm_tiny") else { return };
    let man = rt.manifest.clone();
    let corpus = gen_corpus(CorpusSpec::new(man.vocab, man.seq_len, 32, 17));
    let ds = Dataset::Lm(&corpus);
    let trainer = Trainer::new(&rt);
    let st = trainer.init(4).unwrap();
    let mut rng = Pcg32::seeded(5);
    let proj = random_projections(&man, &mut rng);
    let dir = tmpdir("service");
    let (store, hess, _) =
        run_logging(&rt, &ds, &st.params, &proj, &dir, &LoggingOptions::default())
            .unwrap();
    let hess = hess.unwrap();
    drop(store);
    drop(rt);

    let svc = logra::coordinator::ValuationService::spawn(logra::coordinator::ServiceConfig {
        artifact_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/lm_tiny"),
        store_dir: dir.clone(),
        params: st.params.clone(),
        proj_flat: proj.clone(),
        hessian: hess,
        damping: 0.1,
        norm: Normalization::None,
        max_wait: std::time::Duration::from_millis(5),
        scan_workers: 1,
        backend: logra::valuation::Backend::Auto,
        max_in_flight: 2,
    })
    .unwrap();

    // Fire queries (training docs themselves) from several threads.
    let mut handles = Vec::new();
    let svc = std::sync::Arc::new(svc);
    for q in 0..6usize {
        let svc2 = svc.clone();
        let tokens = corpus.docs[q].tokens.clone();
        handles.push(std::thread::spawn(move || {
            let res = svc2.query(tokens, 3).unwrap();
            (q, res)
        }));
    }
    for h in handles {
        let (q, res) = h.join().unwrap();
        assert_eq!(res.top.len(), 3);
        let ids: Vec<u64> = res.top.iter().map(|&(_, id)| id).collect();
        assert!(ids.contains(&(q as u64)), "query {q} missing itself: {ids:?}");
    }
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.requests, 6);
    assert!(snap.batches <= 6);
    assert!(snap.rows_scanned > 0);
    // Wrong-length query rejected.
    assert!(svc.query(vec![1, 2, 3], 1).is_err());
}
