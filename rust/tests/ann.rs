//! IVF backend integration tests (artifact-free: native scoring only).
//!
//! Load-bearing properties of the stage-0 index:
//!
//! 1. **Full-probe bit-identity**: with `nprobe >=` every shard's cluster
//!    count the IVF engine reproduces the two-stage engine bit-for-bit —
//!    even with a SMALL rescore pool, where both engines are approximate
//!    in exactly the same way. The per-request `nprobe` override hits the
//!    same anchor from a config whose default probe is narrow.
//! 2. **Crash consistency**: a truncated `lists.bin` degrades its one
//!    shard to a full coarse scan (fallback), never to wrong results —
//!    the damaged-index engine still matches two-stage bit-identically.
//! 3. **Recall under pruning**: on a clustered corpus, probing 2 of 8
//!    clusters keeps recall@10 >= 0.95 while the probed-rows counter
//!    stays strictly below the corpus row count — the sublinearity is
//!    observable, not assumed.
//! 4. **Per-request routing**: one `Valuator` over an indexed fabric
//!    serves `exact` / `quantized` / `ann` per request; unservable
//!    choices are typed `InvalidConfig` errors, not panics.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use logra::coordinator::Metrics;
use logra::hessian::BlockHessian;
use logra::obs::render_exposition;
use logra::store::{
    build_index, quantize_store, shard_store, GradStoreWriter, IvfIndex, QuantShardedStore,
    ShardedStore, IVF_LISTS_FILE,
};
use logra::util::rng::Pcg32;
use logra::valuation::{
    Backend, BackendChoice, BackendConfig, BackendKind, IvfEngine, Normalization,
    ParallelQueryEngine, QueryRequest, ScanBackend, TwoStageEngine, ValuationError, Valuator,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-ann-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_store(dir: &Path, rows: &[f32], n: usize, k: usize) {
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, rows).unwrap();
    w.finalize().unwrap();
}

/// Near-isotropic preconditioner fit from standard-normal rows, so the
/// preconditioned query keeps its direction (the recall test's cluster
/// geometry must survive preconditioning).
fn isotropic_precond(k: usize) -> Arc<logra::hessian::Preconditioner> {
    let mut rng = Pcg32::seeded(0x150);
    let m = 256;
    let mut rows = vec![0.0f32; m * k];
    rng.fill_normal(&mut rows, 1.0);
    let mut h = BlockHessian::single_block(k);
    h.accumulate(&rows, m);
    Arc::new(h.preconditioner(0.1).unwrap())
}

/// f32 source -> sharded -> quantized + IVF index. Returns
/// (sharded_dir, quant_dir).
fn indexed_fixture(
    name: &str,
    rows: &[f32],
    n: usize,
    k: usize,
    shards: usize,
    clusters: usize,
) -> (PathBuf, PathBuf) {
    let src = tmpdir(&format!("{name}-src"));
    write_store(&src, rows, n, k);
    let sharded = tmpdir(&format!("{name}-sharded"));
    shard_store(&src, &sharded, shards).unwrap();
    let quant = tmpdir(&format!("{name}-q8"));
    quantize_store(&sharded, &quant).unwrap();
    build_index(&quant, clusters, 42).unwrap();
    (sharded, quant)
}

fn gaussian_rows(n: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    rows
}

/// `centers` well-separated cluster centers, `per_center` rows each:
/// row = center + small noise. Returns (rows, fresh same-cluster queries).
fn clustered_rows(
    centers: usize,
    per_center: usize,
    k: usize,
    queries_per_center: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let mut rng = Pcg32::seeded(0xC1);
    let mut cvecs = vec![0.0f32; centers * k];
    rng.fill_normal(&mut cvecs, 4.0);
    let n = centers * per_center;
    let mut rows = vec![0.0f32; n * k];
    let mut noise = vec![0.0f32; k];
    for c in 0..centers {
        for r in 0..per_center {
            rng.fill_normal(&mut noise, 0.2);
            let at = (c * per_center + r) * k;
            for j in 0..k {
                rows[at + j] = cvecs[c * k + j] + noise[j];
            }
        }
    }
    let mut queries = Vec::new();
    for c in 0..centers {
        for _ in 0..queries_per_center {
            rng.fill_normal(&mut noise, 0.2);
            queries.push((0..k).map(|j| cvecs[c * k + j] + noise[j]).collect());
        }
    }
    (rows, queries)
}

#[test]
fn full_probe_is_bit_identical_to_two_stage() {
    let (k, n, shards, clusters) = (14, 330, 5, 6);
    let nt = 3;
    let topk = 8;
    let rows = gaussian_rows(n, k, 2025);
    let (sharded, quant_dir) = indexed_fixture("bitident", &rows, n, k, shards, clusters);
    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let index = Arc::new(IvfIndex::open(&quant_dir, &quant).unwrap());
    assert_eq!(index.fallback_shards(), 0);
    let precond = isotropic_precond(k);

    // A SMALL rescore pool: both engines are approximate, and they must
    // be approximate identically — the funnel above the rescore is the
    // only thing the index changes.
    let cfg = |nprobe: usize| BackendConfig {
        workers: 2,
        chunk_len: 32,
        rescore_factor: 4,
        nprobe,
        ..Default::default()
    };
    let two = TwoStageEngine::new(quant.clone(), exact.clone(), precond.clone(), cfg(1))
        .unwrap();
    let ivf = IvfEngine::new(
        quant.clone(),
        index.clone(),
        exact.clone(),
        precond.clone(),
        cfg(clusters),
    )
    .unwrap();

    let mut rng = Pcg32::seeded(9);
    let mut test = vec![0.0f32; nt * k];
    rng.fill_normal(&mut test, 1.0);
    for norm in [Normalization::None, Normalization::RelatIf] {
        let want = two
            .query(QueryRequest::gradients(test.clone(), nt, topk).with_norm(norm))
            .unwrap();
        let got = ivf
            .query(QueryRequest::gradients(test.clone(), nt, topk).with_norm(norm))
            .unwrap();
        for (t, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.top, b.top, "full probe diverged (norm {norm:?}, test {t})");
        }
    }

    // Per-request nprobe override reaches the same anchor from a config
    // whose default probe is narrow.
    let narrow = IvfEngine::new(quant, index, exact, precond, cfg(1)).unwrap();
    let want = two.query(QueryRequest::gradients(test.clone(), nt, topk)).unwrap();
    let got = narrow
        .query(
            QueryRequest::gradients(test.clone(), nt, topk)
                .with_backend(BackendChoice::Ann { nprobe: Some(clusters) }),
        )
        .unwrap();
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.top, b.top, "per-request full probe diverged");
    }

    // nprobe = 0 on the wire is a typed error, not a silent full scan.
    let err = narrow
        .query(
            QueryRequest::gradients(test, nt, topk)
                .with_backend(BackendChoice::Ann { nprobe: Some(0) }),
        )
        .unwrap_err();
    assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");
}

#[test]
fn truncated_lists_degrade_to_full_scan_not_wrong_results() {
    let (k, n, shards, clusters) = (10, 240, 4, 5);
    let rows = gaussian_rows(n, k, 77);
    let (sharded, quant_dir) = indexed_fixture("crash", &rows, n, k, shards, clusters);
    // Crash simulation: one shard's lists.bin is cut mid-payload.
    let lpath = quant_dir.join("shard-0002").join(IVF_LISTS_FILE);
    let bytes = std::fs::read(&lpath).unwrap();
    std::fs::write(&lpath, &bytes[..bytes.len() / 2]).unwrap();

    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let index = Arc::new(IvfIndex::open(&quant_dir, &quant).unwrap());
    assert_eq!(index.fallback_shards(), 1, "exactly the damaged shard falls back");
    let precond = isotropic_precond(k);
    let cfg = BackendConfig {
        chunk_len: 32,
        rescore_factor: 4,
        nprobe: clusters,
        ..Default::default()
    };
    let two =
        TwoStageEngine::new(quant.clone(), exact.clone(), precond.clone(), cfg.clone())
            .unwrap();
    let ivf = IvfEngine::new(quant, index, exact, precond, cfg).unwrap();
    assert_eq!(ivf.fallback_shards(), 1);

    // The healthy shards probe, the damaged shard scans in full; the
    // result is still bit-identical to the un-indexed engine.
    let mut rng = Pcg32::seeded(3);
    let mut test = vec![0.0f32; 2 * k];
    rng.fill_normal(&mut test, 1.0);
    let want = two.query(QueryRequest::gradients(test.clone(), 2, 7)).unwrap();
    let got = ivf.query(QueryRequest::gradients(test, 2, 7)).unwrap();
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.top, b.top, "damaged index changed results");
    }
}

#[test]
fn pruned_probe_keeps_recall_and_scans_fewer_rows() {
    let (centers, per_center, k) = (8, 100, 32);
    let n = centers * per_center;
    let topk = 10;
    let (rows, queries) = clustered_rows(centers, per_center, k, 2);
    let (sharded, quant_dir) = indexed_fixture("recall", &rows, n, k, 2, centers);
    let exact = Arc::new(ShardedStore::open(&sharded).unwrap());
    let quant = Arc::new(QuantShardedStore::open(&quant_dir).unwrap());
    let index = Arc::new(IvfIndex::open(&quant_dir, &quant).unwrap());
    assert_eq!(index.fallback_shards(), 0);
    let precond = isotropic_precond(k);

    let reference = ParallelQueryEngine::new(
        exact.clone(),
        precond.clone(),
        BackendConfig { chunk_len: 64, ..Default::default() },
    );
    let metrics = Arc::new(Metrics::default());
    let ivf = IvfEngine::new(
        quant,
        index,
        exact,
        precond,
        BackendConfig {
            chunk_len: 64,
            rescore_factor: 4,
            nprobe: 2,
            metrics: Some(metrics.clone()),
            ..Default::default()
        },
    )
    .unwrap();

    let mut hits = 0usize;
    let mut total = 0usize;
    for q in &queries {
        let want = reference.query(QueryRequest::gradients(q.clone(), 1, topk)).unwrap();
        let got = ivf.query(QueryRequest::gradients(q.clone(), 1, topk)).unwrap();
        let want_ids: Vec<u64> = want[0].top.iter().map(|&(_, id)| id).collect();
        for &(_, id) in &got[0].top {
            if want_ids.contains(&id) {
                hits += 1;
            }
        }
        total += topk;
    }
    let recall = hits as f64 / total as f64;
    assert!(recall >= 0.95, "recall@{topk} = {recall:.3} below 0.95");

    // Sublinearity is observable: the probe named strictly fewer rows
    // than the corpus holds, per query, on average.
    let probed = metrics.rows_probed.load(std::sync::atomic::Ordering::Relaxed);
    let full = (n * queries.len()) as u64;
    assert!(probed > 0, "probe counter never moved");
    assert!(probed < full, "probed {probed} rows >= full-scan {full}");
    let expo = render_exposition(&metrics, None, &[]);
    assert!(expo.contains("logra_rows_probed_total"), "missing probe family:\n{expo}");
}

#[test]
fn valuator_routes_backends_per_request() {
    let (k, n, shards, clusters) = (12, 200, 3, 4);
    let rows = gaussian_rows(n, k, 5150);
    let (sharded, quant_dir) = indexed_fixture("route", &rows, n, k, shards, clusters);

    // Indexed int8 fabric: Auto resolves to IVF, and one valuator serves
    // all four wire names.
    let v = Valuator::open(&quant_dir).unwrap().fit_from_store(0.1).build().unwrap();
    assert_eq!(v.kind(), BackendKind::Ivf);
    assert_eq!(v.resolved_kind(None).unwrap(), BackendKind::Ivf);
    assert_eq!(v.resolved_kind(Some(BackendChoice::Auto)).unwrap(), BackendKind::Ivf);
    assert_eq!(
        v.resolved_kind(Some(BackendChoice::Exact)).unwrap(),
        BackendKind::Parallel
    );
    assert_eq!(
        v.resolved_kind(Some(BackendChoice::Quantized)).unwrap(),
        BackendKind::TwoStage
    );
    assert_eq!(
        v.resolved_kind(Some(BackendChoice::Ann { nprobe: None })).unwrap(),
        BackendKind::Ivf
    );

    // A full-probe ann request and a quantized request are bit-identical
    // THROUGH THE FACADE (same rescore pool, same fabric).
    let g = v.gradient_row(0).unwrap();
    let quantized = v
        .query(
            QueryRequest::gradients(g.clone(), 1, 6).with_backend(BackendChoice::Quantized),
        )
        .unwrap();
    let ann_full = v
        .query(
            QueryRequest::gradients(g.clone(), 1, 6)
                .with_backend(BackendChoice::Ann { nprobe: Some(clusters) }),
        )
        .unwrap();
    assert_eq!(quantized[0].top, ann_full[0].top, "facade routing moved a bit");
    // The exact route serves f32 results with the requested depth.
    let exact = v
        .query(QueryRequest::gradients(g, 1, 6).with_backend(BackendChoice::Exact))
        .unwrap();
    assert_eq!(exact[0].top.len(), 6);
    v.shutdown();

    // f32 fabric: quantized/ann requests are typed errors.
    let v32 = Valuator::open(&sharded).unwrap().fit_from_store(0.1).build().unwrap();
    let g = v32.gradient_row(0).unwrap();
    for choice in [BackendChoice::Quantized, BackendChoice::Ann { nprobe: None }] {
        let err = v32
            .query(QueryRequest::gradients(g.clone(), 1, 3).with_backend(choice))
            .unwrap_err();
        assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");
    }
    v32.shutdown();

    // Quantized fabric WITHOUT an index: ann is unservable — per request
    // and at build.
    let bare_quant = tmpdir("route-bare-q8");
    quantize_store(&sharded, &bare_quant).unwrap();
    let vq = Valuator::open(&bare_quant).unwrap().fit_from_store(0.1).build().unwrap();
    assert_eq!(vq.kind(), BackendKind::TwoStage, "no index -> two-stage auto");
    let g = vq.gradient_row(0).unwrap();
    let err = vq
        .query(
            QueryRequest::gradients(g, 1, 3)
                .with_backend(BackendChoice::Ann { nprobe: None }),
        )
        .unwrap_err();
    assert!(matches!(err, ValuationError::InvalidConfig(_)), "{err:?}");
    vq.shutdown();
    let built = Valuator::open(&bare_quant)
        .unwrap()
        .backend(Backend::Ann { nprobe: 2, rescore_factor: 4 })
        .fit_from_store(0.1)
        .build();
    assert!(
        matches!(built, Err(ValuationError::InvalidConfig(_))),
        "ann on an unindexed fabric must be rejected at build"
    );
}
