//! Observability integration tests: histogram quantile accuracy against
//! the exact reference percentiles, trace-ring wraparound, Chrome
//! trace-event JSON schema, per-backend [`QueryReport`]s, and the
//! concurrent pooled timeline.
//!
//! Load-bearing properties:
//!
//! 1. **Histogram quantiles are honest**: the log-bucketed histogram's
//!    p50/p95/p99 agree with the exact `util::stats::percentile` of the
//!    same samples to within one bucket width (≤ 12.5% relative), and
//!    `percentile_bounds` always brackets the exact value.
//! 2. **Trace export is well-formed**: `chrome_trace_json` output parses
//!    under the crate's own JSON subset parser and every event carries
//!    the full Chrome trace-event shape.
//! 3. **Every backend reports**: sequential, parallel, and two-stage all
//!    return a `QueryReport` whose stages exactly partition the total,
//!    and all three populate the queue-wait histogram uniformly.
//! 4. **Concurrent pooled timelines are consistent**: 8 queries racing
//!    on one pool each leave n_shards "scan" spans inside their own
//!    "query" span window, and their reported worker lanes are real pool
//!    worker lanes.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use logra::coordinator::Metrics;
use logra::hessian::BlockHessian;
use logra::obs::{
    bucket_bounds, bucket_index, chrome_trace_json, Histogram, QueryReport, SpanEvent, TraceRing,
};
use logra::store::{
    quantize_store, shard_store, GradStoreWriter, QuantShardedStore, ShardedStore,
};
use logra::util::json::{self, Json};
use logra::util::rng::Pcg32;
use logra::util::stats;
use logra::valuation::{
    BackendConfig, ParallelQueryEngine, QueryRequest, ScanBackend, ScanPool, SequentialEngine,
    TwoStageEngine,
};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("logra-obs-it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_store(dir: &Path, n: usize, k: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut rows = vec![0.0f32; n * k];
    rng.fill_normal(&mut rows, 1.0);
    let ids: Vec<u64> = (0..n as u64).collect();
    let mut w = GradStoreWriter::create(dir, k).unwrap();
    w.append(&ids, &rows).unwrap();
    w.finalize().unwrap();
    rows
}

fn make_precond(rows: &[f32], n: usize, k: usize) -> logra::hessian::Preconditioner {
    let mut h = BlockHessian::single_block(k);
    h.accumulate(rows, n);
    h.preconditioner(0.1).unwrap()
}

/// Build an n-row, n_shards-shard f32 + int8 store fabric.
fn fixture(
    name: &str,
    n: usize,
    k: usize,
    n_shards: usize,
    rng: &mut Pcg32,
) -> (Arc<ShardedStore>, Arc<QuantShardedStore>, Arc<logra::hessian::Preconditioner>) {
    let src = tmpdir(&format!("{name}-src"));
    let rows = write_store(&src, n, k, rng);
    let sharded = tmpdir(&format!("{name}-sharded"));
    shard_store(&src, &sharded, n_shards).unwrap();
    let quant_dir = tmpdir(&format!("{name}-quant"));
    quantize_store(&sharded, &quant_dir).unwrap();
    (
        Arc::new(ShardedStore::open(&sharded).unwrap()),
        Arc::new(QuantShardedStore::open(&quant_dir).unwrap()),
        Arc::new(make_precond(&rows, n, k)),
    )
}

// ---------------------------------------------------------------- histogram

#[test]
fn histogram_percentiles_track_exact_reference() {
    // 1001 samples so p in {50, 95, 99} has an integral rank
    // (p/100 * 1000) — the exact percentile IS an order statistic, and
    // the histogram's round-rank bucket must contain it.
    let mut rng = Pcg32::seeded(11);
    let h = Histogram::new();
    let mut samples: Vec<f64> = Vec::with_capacity(1001);
    for _ in 0..1001 {
        // Log-spread nanosecond values across 26 octaves, the shape of
        // real mixed-latency data.
        let e = rng.below(26) + 4;
        let v = (1u64 << e) + rng.next_u64() % (1u64 << e);
        h.record(v);
        samples.push(v as f64);
    }
    let snap = h.snapshot();
    assert_eq!(snap.count, 1001);

    for p in [50.0, 95.0, 99.0] {
        let exact = stats::percentile(&samples, p);
        let approx = snap.percentile(p);
        let (lo, hi) = snap.percentile_bounds(p);
        assert!(
            lo <= exact && exact < hi,
            "p{p}: exact {exact} outside bounds [{lo}, {hi})"
        );
        assert!(
            lo <= approx && approx <= hi,
            "p{p}: approx {approx} outside bounds [{lo}, {hi})"
        );
        // Integral rank => floor and ceil buckets coincide, so the
        // midpoint estimate sits within one bucket width of the exact
        // order statistic...
        let (blo, bhi) = bucket_bounds(bucket_index(exact as u64));
        let width = (bhi - blo) as f64;
        assert!(
            (approx - exact).abs() <= width,
            "p{p}: |{approx} - {exact}| > bucket width {width}"
        );
        // ...which is the <= 12.5% HDR relative-error guarantee.
        assert!(
            (approx - exact).abs() / exact <= 0.125 + 1e-9,
            "p{p}: relative error too large ({approx} vs {exact})"
        );
    }

    // Fractional ranks only widen the bracket to two (adjacent-rank)
    // buckets; the exact interpolated value must still be inside.
    for p in [12.3, 61.8, 97.3] {
        let exact = stats::percentile(&samples, p);
        let (lo, hi) = snap.percentile_bounds(p);
        assert!(
            lo <= exact && exact <= hi,
            "p{p}: exact {exact} outside bounds [{lo}, {hi}]"
        );
    }
}

// -------------------------------------------------------------------- trace

#[test]
fn trace_ring_wraps_without_losing_order() {
    let ring = TraceRing::with_capacity(16);
    for i in 0..100u64 {
        ring.record(SpanEvent {
            name: if i % 2 == 0 { "scan" } else { "merge" },
            query: i / 10,
            shard: Some((i % 4) as u32),
            lane: 0,
            start_nanos: i * 1_000,
            dur_nanos: 750,
            seq: 0,
        });
    }
    assert_eq!(ring.recorded(), 100);
    let events = ring.events();
    assert_eq!(events.len(), 16, "ring retains exactly its capacity");
    // The survivors are the 16 MOST RECENT events, in seq order.
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (84..100).collect::<Vec<u64>>());
    assert_eq!(events[0].start_nanos, 84_000);
}

/// Validate one parsed Chrome trace event object.
fn check_trace_event(ev: &Json) {
    const TAXONOMY: [&str; 6] =
        ["admission", "queue_wait", "scan", "merge", "rescore", "query"];
    let name = ev.get("name").and_then(Json::as_str).expect("event missing name");
    assert!(TAXONOMY.contains(&name), "unknown span name {name:?}");
    assert_eq!(ev.get("cat").and_then(Json::as_str), Some("logra"));
    assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
    assert_eq!(ev.get("pid").and_then(Json::as_u64), Some(1));
    ev.get("tid").and_then(Json::as_u64).expect("event missing integer tid");
    ev.get("ts").and_then(Json::as_u64).expect("event missing integer ts");
    let dur = ev.get("dur").and_then(Json::as_u64).expect("event missing integer dur");
    assert!(dur >= 1, "durations round up to 1us");
    let args = ev.get("args").expect("event missing args");
    args.get("query").and_then(Json::as_u64).expect("args missing query id");
    if name == "scan" {
        args.get("shard").and_then(Json::as_u64).expect("scan span missing shard");
    }
}

#[test]
fn chrome_trace_json_is_schema_valid_under_subset_parser() {
    let ring = TraceRing::with_capacity(64);
    for i in 0..10u64 {
        ring.record(SpanEvent {
            name: "scan",
            query: 3,
            shard: Some(i as u32),
            lane: i as u32 % 2,
            start_nanos: 5_000 + i * 2_000,
            dur_nanos: if i == 0 { 120 } else { 1_900 }, // sub-us dur too
            seq: 0,
        });
    }
    ring.record(SpanEvent {
        name: "query",
        query: 3,
        shard: None,
        lane: 9,
        start_nanos: 0,
        dur_nanos: 40_000,
        seq: 0,
    });
    let text = chrome_trace_json(&ring.events());
    let parsed = json::parse(&text).expect("chrome trace JSON must parse");
    let events = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("top-level traceEvents array");
    assert_eq!(events.len(), 11);
    for ev in events {
        check_trace_event(ev);
    }
}

// ------------------------------------------------------------ query reports

fn assert_report_partitions(rep: &QueryReport) {
    let sum = rep.admission_nanos
        + rep.queue_wait_nanos
        + rep.scan_nanos
        + rep.merge_nanos
        + rep.rescore_nanos;
    assert_eq!(
        sum, rep.total_nanos,
        "stages must partition the total exactly ({rep:?})"
    );
    assert!(!rep.workers.is_empty(), "scan tasks must register lanes");
    let text = rep.render();
    assert!(text.contains("total"), "render must include the total line");
}

#[test]
fn every_backend_returns_a_report_and_records_queue_wait() {
    let k = 12;
    let n = 240;
    let n_shards = 4;
    let mut rng = Pcg32::seeded(21);
    let (exact, quant, precond) = fixture("backends", n, k, n_shards, &mut rng);
    let nt = 2;
    let topk = 5;
    let mut test = vec![0.0f32; nt * k];
    rng.fill_normal(&mut test, 1.0);
    let req = || QueryRequest::gradients(test.clone(), nt, topk);

    // Sequential.
    {
        let metrics = Arc::new(Metrics::default());
        let engine = SequentialEngine::new(
            exact.clone(),
            precond.clone(),
            BackendConfig { chunk_len: 32, metrics: Some(metrics.clone()), ..Default::default() },
        );
        let (results, rep) = engine.query_with_report(req()).unwrap();
        assert_eq!(results.len(), nt);
        let rep = rep.expect("metrics attached => report present");
        assert_eq!(rep.backend, "sequential");
        assert_eq!(rep.shards, n_shards as u32);
        assert_eq!(rep.rows_scanned, n as u64);
        assert_eq!(rep.candidates_rescored, 0);
        assert_report_partitions(&rep);
        assert_eq!(metrics.obs.queue_wait.snapshot().count, 1);
        assert_eq!(metrics.obs.query_latency.snapshot().count, 1);
        assert_eq!(metrics.obs.shard_scan.snapshot().count, n_shards as u64);
    }

    // Parallel (scoped-thread fan-out, no pool).
    {
        let metrics = Arc::new(Metrics::default());
        let engine = ParallelQueryEngine::new(
            exact.clone(),
            precond.clone(),
            BackendConfig {
                workers: 2,
                chunk_len: 32,
                metrics: Some(metrics.clone()),
                ..Default::default()
            },
        );
        let (results, rep) = engine.query_with_report(req()).unwrap();
        assert_eq!(results.len(), nt);
        let rep = rep.expect("metrics attached => report present");
        assert_eq!(rep.backend, "parallel-f32");
        assert_eq!(rep.shards, n_shards as u32);
        assert_eq!(rep.candidates_rescored, 0);
        assert_report_partitions(&rep);
        assert_eq!(metrics.obs.queue_wait.snapshot().count, 1);
        assert_eq!(metrics.obs.shard_scan.snapshot().count, n_shards as u64);
    }

    // Two-stage (int8 coarse scan + exact rescore).
    {
        let metrics = Arc::new(Metrics::default());
        let engine = TwoStageEngine::new(
            quant.clone(),
            exact.clone(),
            precond.clone(),
            BackendConfig {
                workers: 2,
                chunk_len: 32,
                rescore_factor: 3,
                metrics: Some(metrics.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let (results, rep) = engine.query_with_report(req()).unwrap();
        assert_eq!(results.len(), nt);
        let rep = rep.expect("metrics attached => report present");
        assert_eq!(rep.backend, "two-stage");
        assert_eq!(rep.shards, n_shards as u32);
        assert!(rep.candidates_rescored > 0, "two-stage must rescore candidates");
        assert_report_partitions(&rep);
        assert_eq!(metrics.obs.queue_wait.snapshot().count, 1);
        assert_eq!(metrics.obs.shard_scan.snapshot().count, n_shards as u64);
    }

    // No metrics => no report, and no overhead switches flipped.
    {
        let engine = SequentialEngine::new(
            exact.clone(),
            precond.clone(),
            BackendConfig { chunk_len: 32, ..Default::default() },
        );
        let (results, rep) = engine.query_with_report(req()).unwrap();
        assert_eq!(results.len(), nt);
        assert!(rep.is_none(), "no metrics => no report");
    }
}

// ----------------------------------------------------- concurrent pool trace

#[test]
fn concurrent_pooled_queries_leave_consistent_timelines() {
    let k = 12;
    let n = 360;
    let n_shards = 6;
    let n_queries = 8usize;
    let mut rng = Pcg32::seeded(31);
    let (exact, _quant, precond) = fixture("pool-trace", n, k, n_shards, &mut rng);
    let metrics = Arc::new(Metrics::default());
    let pool = Arc::new(ScanPool::spawn(3));
    let engine = Arc::new(ParallelQueryEngine::new(
        exact,
        precond,
        BackendConfig {
            chunk_len: 32,
            pool: Some(pool.clone()),
            metrics: Some(metrics.clone()),
            ..Default::default()
        },
    ));

    let reports: Mutex<Vec<QueryReport>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for q in 0..n_queries {
            let engine = engine.clone();
            let reports = &reports;
            let mut qrng = Pcg32::seeded(700 + q as u64);
            s.spawn(move || {
                let mut test = vec![0.0f32; k];
                qrng.fill_normal(&mut test, 1.0);
                let (results, rep) = engine
                    .query_with_report(QueryRequest::gradients(test, 1, 5))
                    .unwrap();
                assert_eq!(results.len(), 1);
                reports.lock().unwrap().push(rep.expect("report"));
            });
        }
    });
    let reports = reports.into_inner().unwrap();
    assert_eq!(reports.len(), n_queries);

    // Distinct query ids; every query fed the latency histograms.
    let ids: BTreeSet<u64> = reports.iter().map(|r| r.query_id).collect();
    assert_eq!(ids.len(), n_queries);
    assert_eq!(metrics.obs.query_latency.snapshot().count, n_queries as u64);
    assert_eq!(metrics.obs.queue_wait.snapshot().count, n_queries as u64);
    assert_eq!(
        metrics.obs.shard_scan.snapshot().count,
        (n_queries * n_shards) as u64
    );

    let snap = pool.snapshot();
    assert_eq!(snap.tasks_completed, (n_queries * n_shards) as u64);
    let pool_lanes: BTreeSet<u32> =
        snap.worker_lanes.iter().copied().filter(|&l| l != u32::MAX).collect();
    assert!(
        !pool_lanes.is_empty() && pool_lanes.len() <= 3,
        "workers register lanes on startup, before any task runs: {pool_lanes:?}"
    );

    let events = metrics.obs.trace.events();
    // Mixed time bases (obs epoch vs per-query Instant) can skew span
    // endpoints by the nanoseconds between two adjacent clock reads;
    // 1ms of slack keeps the containment check honest but unflaky.
    let slack = 1_000_000u64;
    for rep in &reports {
        let scans: Vec<&SpanEvent> = events
            .iter()
            .filter(|e| e.name == "scan" && e.query == rep.query_id)
            .collect();
        assert_eq!(scans.len(), n_shards, "one scan span per shard for query {}", rep.query_id);
        let shards: BTreeSet<u32> = scans.iter().map(|e| e.shard.unwrap()).collect();
        assert_eq!(shards, (0..n_shards as u32).collect::<BTreeSet<u32>>());

        let query_span = events
            .iter()
            .find(|e| e.name == "query" && e.query == rep.query_id)
            .expect("query span recorded");
        let q_end = query_span.start_nanos + query_span.dur_nanos;
        for scan in &scans {
            assert!(
                scan.start_nanos + slack >= query_span.start_nanos,
                "scan span starts before its query was admitted"
            );
            assert!(
                scan.start_nanos + scan.dur_nanos <= q_end + slack,
                "scan span outlives its query span"
            );
        }

        // Reported worker lanes are REAL pool worker lanes (the scan ran
        // on the pool, not on ad-hoc threads).
        for lane in &rep.workers {
            assert!(
                pool_lanes.contains(lane),
                "report lane {lane} not a pool worker lane {pool_lanes:?}"
            );
        }
    }

    // The full concurrent trace round-trips through the Chrome exporter
    // and our own JSON subset parser.
    let text = chrome_trace_json(&events);
    let parsed = json::parse(&text).expect("trace JSON parses");
    let arr = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert_eq!(arr.len(), events.len());
    for ev in arr {
        check_trace_event(ev);
    }
    pool.shutdown();
}
