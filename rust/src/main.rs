//! `logra` — CLI launcher for the data-valuation system.
//!
//! Subcommands map 1:1 onto the paper's experiments (DESIGN.md §3):
//!   info         inspect an artifact manifest
//!   fig4         counterfactual accuracy (brittleness + LDS)
//!   table1       LoGra vs EKFAC efficiency
//!   qualitative  Fig-5-style top-valued-document inspection
//!   store        gradient-store maintenance (stat | shard | merge | quantize | index)
//!   query        value a stored gradient row against any store fabric
//!   session      multi-stage sessions: one query across many checkpoints
//!   trace        run concurrent queries, export a Chrome trace + percentiles
//!   serve        HTTP valuation server (/query /metrics /healthz /debug/trace);
//!                --session serves a whole multi-stage session
//!   loadgen      closed-loop load bench against a running serve instance

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use logra::cli::{self, BackendArgs, FlagSpec};
use logra::coordinator::Metrics;
use logra::eval::fig4::{render_markdown, run_fig4, Fig4Scale};
use logra::eval::qualitative::{render as render_qual, run_qualitative};
use logra::eval::table1::{run_table1, TABLE1_HEADER};
use logra::eval::{BrittlenessConfig, LdsConfig};
use logra::obs::{chrome_trace_json, render_exposition};
use logra::serve::{loadgen, ReloadConfig, ServeConfig, Server};
use logra::session::{stage_spec, Combine, Session, SessionConfig, SessionManifest, SESSION_VERSION};
use logra::store::{
    append_shard, build_index, build_index_incremental, merge_store, quantize_store,
    quantize_store_incremental, shard_store, stat_store, ShardManifest,
};
use logra::valuation::{
    BackendChoice, Normalization, PoolMode, QueryRequest, ScanBackend, Valuator,
};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("info", "print an artifact manifest summary"),
    ("fig4", "run brittleness + LDS counterfactual evals"),
    ("table1", "run the LoGra vs EKFAC efficiency comparison"),
    ("qualitative", "train, log, and inspect top-valued documents"),
    ("store", "store maintenance: store stat|shard|merge|quantize|index|append <dir>"),
    ("query", "query <store_dir>: top-k most influential rows for --row"),
    ("session", "session init|stat|query <dir>: one query across many checkpoints"),
    ("trace", "trace <store_dir>: concurrent queries -> Chrome trace JSON"),
    ("serve", "serve <store_dir> | serve --session <dir>: HTTP valuation server"),
    ("loadgen", "loadgen: closed-loop query load against a running serve"),
];

const FLAGS: &[FlagSpec] = &[
    FlagSpec { name: "config", help: "config name (e.g. lm_tiny)", takes_value: true, default: Some("lm_tiny") },
    FlagSpec { name: "n-train", help: "training examples", takes_value: true, default: None },
    FlagSpec { name: "n-test", help: "test examples", takes_value: true, default: None },
    FlagSpec { name: "subsets", help: "LDS subsets", takes_value: true, default: None },
    FlagSpec { name: "epochs", help: "(re)train epochs", takes_value: true, default: None },
    FlagSpec { name: "methods", help: "comma list of methods", takes_value: true, default: None },
    FlagSpec { name: "part", help: "fig4 part: both|brittleness|lds", takes_value: true, default: Some("both") },
    FlagSpec { name: "removals", help: "brittleness ks, comma list", takes_value: true, default: None },
    FlagSpec { name: "topk", help: "retrieval depth", takes_value: true, default: Some("5") },
    FlagSpec { name: "out", help: "output dir for store shard/merge/quantize", takes_value: true, default: None },
    FlagSpec { name: "shards", help: "shard count for store shard", takes_value: true, default: Some("4") },
    FlagSpec { name: "clusters", help: "store index: IVF clusters per shard", takes_value: true, default: Some("16") },
    FlagSpec { name: "seed", help: "store index/append: k-means / synthesis seed", takes_value: true, default: Some("42") },
    FlagSpec { name: "rows", help: "store append: synthetic rows to append", takes_value: true, default: Some("128") },
    FlagSpec { name: "incremental", help: "store quantize/index: skip shards already converted/indexed", takes_value: false, default: None },
    FlagSpec { name: "row", help: "query: stored row used as the query gradient", takes_value: true, default: Some("0") },
    FlagSpec { name: "norm", help: "query: normalization none|relatif", takes_value: true, default: Some("relatif") },
    FlagSpec { name: "backend", help: "query/trace/serve: auto|exact|quantized|ann", takes_value: true, default: Some("auto") },
    FlagSpec { name: "nprobe", help: "query/trace/serve: IVF clusters probed per shard", takes_value: true, default: Some("4") },
    FlagSpec { name: "rescore-factor", help: "query/trace/serve: stage-1 pool multiplier", takes_value: true, default: Some("4") },
    FlagSpec { name: "rescore-store", help: "query: exact f32 companion for a quantized store", takes_value: true, default: None },
    FlagSpec { name: "workers", help: "query/trace/serve: scan workers (0 = auto)", takes_value: true, default: Some("0") },
    FlagSpec { name: "damping", help: "query: Fisher damping factor", takes_value: true, default: Some("0.1") },
    FlagSpec { name: "repeat", help: "query: run the query N times (latency percentiles)", takes_value: true, default: Some("1") },
    FlagSpec { name: "queries", help: "trace: queries to run", takes_value: true, default: Some("8") },
    FlagSpec { name: "concurrency", help: "trace: concurrent client threads", takes_value: true, default: Some("8") },
    FlagSpec { name: "metrics", help: "store stat: print Prometheus exposition", takes_value: false, default: None },
    FlagSpec { name: "addr", help: "serve/loadgen: bind/target address", takes_value: true, default: Some("127.0.0.1:7878") },
    FlagSpec { name: "max-in-flight", help: "serve: queries admitted at once (excess -> 429)", takes_value: true, default: Some("8") },
    FlagSpec { name: "deadline-ms", help: "serve: default per-query deadline (0 = none)", takes_value: true, default: Some("0") },
    FlagSpec { name: "poll-ms", help: "serve: deadline/disconnect poll interval", takes_value: true, default: Some("15") },
    FlagSpec { name: "reload-ms", help: "serve: manifest generation probe interval (0 = static)", takes_value: true, default: Some("0") },
    FlagSpec { name: "offline", help: "serve: synthesize a sharded store (no artifacts)", takes_value: false, default: None },
    FlagSpec { name: "session", help: "serve: multi-stage session directory to serve", takes_value: true, default: None },
    FlagSpec { name: "combine", help: "session/serve: weighted-sum|borda|per-stage", takes_value: true, default: Some("weighted-sum") },
    FlagSpec { name: "stages", help: "session init: stage count | session query: comma-list subset", takes_value: true, default: None },
    FlagSpec { name: "clients", help: "loadgen: concurrent closed-loop clients", takes_value: true, default: Some("8") },
    FlagSpec { name: "requests", help: "loadgen: requests per client", takes_value: true, default: Some("32") },
    FlagSpec { name: "max-retries", help: "loadgen: backoff retries per request on 429/503", takes_value: true, default: Some("3") },
    FlagSpec { name: "bench-out", help: "loadgen: merge serve_c*_{qps,p50_ms,p99_ms} into this JSON", takes_value: true, default: None },
];

/// Repo root: the directory holding `artifacts/` (cwd, else build-time).
fn repo_root() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_default();
    if cwd.join("artifacts").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let value_flags: Vec<&str> =
        FLAGS.iter().filter(|f| f.takes_value).map(|f| f.name).collect();
    let args = cli::parse(&argv, &value_flags)?;
    if args.subcommand.is_empty() || args.has_switch("help") {
        print!("{}", cli::usage("logra", SUBCOMMANDS, FLAGS));
        return Ok(());
    }
    let root = repo_root();
    let config = args.flag_or("config", "lm_tiny");

    match args.subcommand.as_str() {
        "info" => {
            let man = logra::runtime::Manifest::load(&root.join("artifacts").join(&config))?;
            println!(
                "{} ({}) — n_params={}, K={} ({} modules x {}x{}), K_full={}",
                man.name,
                man.kind,
                man.n_params,
                man.k_total,
                man.modules.len(),
                man.k_out,
                man.k_in,
                man.k_full
            );
            println!("entries: {}", man.entries.join(", "));
            for m in &man.modules {
                println!("  module {:<12} {}x{} -> block {}", m.name, m.n_out, m.n_in, m.g_len);
            }
            Ok(())
        }
        "fig4" => {
            let mut scale = Fig4Scale::default();
            scale.n_train = args.usize_or("n-train", scale.n_train)?;
            scale.n_test = args.usize_or("n-test", scale.n_test)?;
            if let Some(ms) = args.flag("methods") {
                scale.methods = ms.split(',').map(str::to_string).collect();
            }
            let epochs = args.usize_or("epochs", 4)?;
            scale.base_epochs = epochs;
            scale.brittle = BrittlenessConfig { epochs, ..Default::default() };
            if let Some(ks) = args.flag("removals") {
                scale.brittle.removal_counts = ks
                    .split(',')
                    .map(|s| s.parse::<usize>())
                    .collect::<std::result::Result<_, _>>()?;
            }
            scale.lds = LdsConfig {
                n_subsets: args.usize_or("subsets", 16)?,
                epochs,
                ..Default::default()
            };
            match args.flag_or("part", "both").as_str() {
                "brittleness" => scale.run_lds = false,
                "lds" => scale.run_brittleness = false,
                _ => {}
            }
            let configs: Vec<String> = if config == "all" {
                vec!["mlp_fmnist".into(), "mlp_cifar".into(), "lm_wikitext".into()]
            } else {
                vec![config]
            };
            for c in configs {
                let out = run_fig4(&root, &c, &scale)?;
                println!("\n{}", render_markdown(&out));
            }
            Ok(())
        }
        "table1" => {
            let n_train = args.usize_or("n-train", 512)?;
            let n_test = args.usize_or("n-test", 8)?;
            let rows = run_table1(&root, &config, n_train, n_test, 8)?;
            println!("{TABLE1_HEADER}");
            for r in &rows {
                println!("{}", r.render());
            }
            Ok(())
        }
        "qualitative" => {
            let n_train = args.usize_or("n-train", 512)?;
            let topk = args.usize_or("topk", 5)?;
            let epochs = args.usize_or("epochs", 6)?;
            let out = run_qualitative(&root, &config, n_train, 8, topk, epochs)?;
            println!("{}", render_qual(&out));
            Ok(())
        }
        "store" => {
            let action = args
                .positional
                .first()
                .map(String::as_str)
                .ok_or_else(|| {
                    anyhow!(
                        "usage: store stat|shard|merge|quantize|index|append <dir> \
                         [--out DIR] [--shards N] [--clusters C] [--seed S] \
                         [--incremental] [--rows N]"
                    )
                })?;
            let dir = args
                .positional
                .get(1)
                .map(PathBuf::from)
                .ok_or_else(|| anyhow!("store {action}: missing store directory"))?;
            match action {
                "stat" => {
                    let stat = stat_store(&dir)?;
                    print!("{}", stat.render());
                    // The scan backend `Valuator::open(dir)` + Backend::Auto
                    // would serve this fabric with.
                    if let Ok(builder) = Valuator::open(&dir) {
                        println!("auto backend  {}", builder.auto_kind().name());
                    }
                    if args.has_switch("metrics") {
                        // Exposition over a fresh Metrics: the counter and
                        // histogram families a serving process would export,
                        // plus store-shape gauges — what
                        // scripts/check_metrics.py validates in CI.
                        let m = Metrics::default();
                        print!(
                            "{}",
                            render_exposition(
                                &m,
                                None,
                                &[
                                    ("logra_store_rows", "Rows in the store fabric.", stat.rows as f64),
                                    ("logra_store_shards", "Shards in the store fabric.", stat.shards as f64),
                                    ("logra_store_k", "Projected gradient dimension.", stat.k as f64),
                                    ("logra_store_bytes", "Store payload bytes on disk.", stat.storage_bytes as f64),
                                ],
                            )
                        );
                    }
                    Ok(())
                }
                "shard" => {
                    let out = args
                        .flag("out")
                        .map(PathBuf::from)
                        .ok_or_else(|| anyhow!("store shard: --out <dir> required"))?;
                    let n = args.usize_or("shards", 4)?;
                    let man = shard_store(&dir, &out, n)?;
                    println!(
                        "sharded {} -> {} ({} shards, {} rows)",
                        dir.display(),
                        out.display(),
                        man.n_shards(),
                        man.total_rows()
                    );
                    Ok(())
                }
                "merge" => {
                    let out = args
                        .flag("out")
                        .map(PathBuf::from)
                        .ok_or_else(|| anyhow!("store merge: --out <dir> required"))?;
                    let rows = merge_store(&dir, &out)?;
                    println!("merged {} -> {} ({rows} rows)", dir.display(), out.display());
                    Ok(())
                }
                "quantize" => {
                    let out = args
                        .flag("out")
                        .map(PathBuf::from)
                        .ok_or_else(|| anyhow!("store quantize: --out <dir> required"))?;
                    let man = if args.has_switch("incremental") {
                        let (man, rep) = quantize_store_incremental(&dir, &out)?;
                        println!(
                            "incremental quantize: {} shards converted, {} up to date \
                             (generation {})",
                            rep.converted, rep.skipped, man.generation
                        );
                        man
                    } else {
                        quantize_store(&dir, &out)?
                    };
                    let before = stat_store(&dir)?.storage_bytes;
                    let after = stat_store(&out)?.storage_bytes;
                    println!(
                        "quantized {} -> {} ({} shards, {} rows, int8 codec, {} -> {} bytes, {:.2}x smaller)",
                        dir.display(),
                        out.display(),
                        man.n_shards(),
                        man.total_rows(),
                        before,
                        after,
                        before as f64 / after.max(1) as f64
                    );
                    Ok(())
                }
                "index" => {
                    let clusters = args.usize_or("clusters", 16)?;
                    let seed = args.usize_or("seed", 42)? as u64;
                    if args.has_switch("incremental") {
                        // Index only the shards with a missing sidecar —
                        // the recovery path `store append` points at when
                        // it staled the advertised index.
                        let rep = build_index_incremental(&dir, clusters, seed)?;
                        println!(
                            "incremental index: {} shards indexed, {} up to date ({})",
                            rep.indexed,
                            rep.skipped,
                            dir.display()
                        );
                        return Ok(());
                    }
                    let rep = build_index(&dir, clusters, seed)?;
                    println!(
                        "indexed {} ({} shards, seed {seed})",
                        dir.display(),
                        rep.shards
                    );
                    for si in 0..rep.shards {
                        println!(
                            "  shard {si}: {} clusters over {} rows",
                            rep.clusters[si], rep.rows[si]
                        );
                    }
                    Ok(())
                }
                // Live growth: append one synthetic shard and publish the
                // next manifest generation — the writer side of
                // `serve --reload-ms` (and the CI append-while-serving
                // smoke test).
                "append" => {
                    let n = args.usize_or("rows", 128)?.max(1);
                    let seed = args.usize_or("seed", 42)? as u64;
                    let man = ShardManifest::load(&dir)?;
                    let next_id = man.total_rows();
                    let ids: Vec<u64> = (next_id..next_id + n as u64).collect();
                    let mut rows = vec![0.0f32; n * man.k];
                    logra::util::rng::Pcg32::new(seed, man.generation)
                        .fill_normal(&mut rows, 1.0);
                    let rep = append_shard(&dir, &ids, &rows)?;
                    println!(
                        "appended {} ({} rows, ids {}..{}) -> generation {}",
                        rep.shard_dir,
                        rep.rows,
                        next_id,
                        next_id + rep.rows - 1,
                        rep.generation
                    );
                    if man.index.is_some() {
                        eprintln!(
                            "warning: store advertises an IVF index but the appended shard \
                             has no sidecar — ANN queries fall back to exact scans on it; \
                             run `logra store index {} --incremental` to reindex",
                            dir.display()
                        );
                    }
                    Ok(())
                }
                other => Err(anyhow!(
                    "unknown store action {other:?}; try stat|shard|merge|quantize|index|append"
                )),
            }
        }
        // Store-only valuation: no artifact needed. The projected Fisher
        // is refit from the stored rows themselves (they ARE projected
        // gradients), one stored row serves as the query gradient, and the
        // per-request --norm override threads through QueryRequest.
        "query" => {
            let dir = args.positional.first().map(PathBuf::from).ok_or_else(|| {
                anyhow!(
                    "usage: query <store_dir> [--row N] [--topk K] [--norm none|relatif] \
                     [--backend auto|exact|quantized|ann] [--nprobe N] \
                     [--rescore-factor N] [--workers N] [--damping X]"
                )
            })?;
            let row = args.usize_or("row", 0)?;
            let topk = args.usize_or("topk", 5)?;
            let ba = BackendArgs::from_args(&args)?;
            let damping = args.f64_or("damping", 0.1)? as f32;
            let norm = Normalization::parse(&args.flag_or("norm", "relatif"))?;
            let builder = Valuator::open(&dir)?;
            // `auto` spells out the fabric's pick so --rescore-factor /
            // --nprobe are honored instead of the builder defaults.
            let backend = ba.resolve(builder.auto_kind())?;
            let repeat = args.usize_or("repeat", 1)?.max(1);
            let metrics = Arc::new(Metrics::default());
            let mut builder = builder
                .backend(backend)
                .workers(ba.workers)
                .fit_from_store(damping)
                .metrics(metrics.clone());
            // Explicit exact companion for quantized stores whose manifest
            // predates (or lost) the recorded rescore_dir pointer.
            if let Some(rs) = args.flag("rescore-store") {
                builder = builder.rescore_store(rs);
            }
            let valuator = builder.build()?;
            let g = valuator.gradient_row(row).ok_or_else(|| {
                anyhow!("row {row} out of range (store has {} rows)", valuator.rows())
            })?;
            let mut res = Vec::new();
            let mut report = None;
            for _ in 0..repeat {
                let (r, rep) = valuator.query_with_report(
                    QueryRequest::gradients(g.clone(), 1, topk).with_norm(norm),
                )?;
                res = r;
                report = rep;
            }
            println!(
                "backend       {} ({} rows, k={}, {} workers, norm {:?})",
                valuator.kind().name(),
                valuator.rows(),
                valuator.k(),
                valuator.workers(),
                norm
            );
            if let Some(rep) = &report {
                print!("{}", rep.render());
            }
            if repeat > 1 {
                let lat = metrics.obs.query_latency.snapshot();
                println!(
                    "latency over {repeat} runs: p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms",
                    lat.percentile_ms(50.0),
                    lat.percentile_ms(95.0),
                    lat.percentile_ms(99.0)
                );
            }
            for &(score, id) in &res[0].top {
                println!("  [{score:+.6}] id {id}");
            }
            Ok(())
        }
        // Multi-stage sessions: one query scored across many checkpoints
        // over ONE shared scan pool. `init` synthesizes an offline
        // session (N stage stores + session.json — the CI/bench fixture),
        // `stat` opens and describes it, `query` fans a stored row out to
        // every stage and prints per-stage + combined rankings.
        "session" => {
            let action = args
                .positional
                .first()
                .map(String::as_str)
                .ok_or_else(|| {
                    anyhow!(
                        "usage: session init|stat|query <session_dir> \
                         [--combine weighted-sum|borda|per-stage] [--workers N] \
                         [--row N] [--topk K] [--stages a,b] \
                         | session init <dir> [--stages N] [--n-train N] [--shards N] [--seed S]"
                    )
                })?;
            let dir = args
                .positional
                .get(1)
                .map(PathBuf::from)
                .ok_or_else(|| anyhow!("session {action}: missing session directory"))?;
            if action == "init" {
                let n_stages = args.usize_or("stages", 2)?.max(1);
                let n_train = args.usize_or("n-train", 1024)?.max(1);
                let n_shards = args.usize_or("shards", 2)?.max(1);
                let seed = args.usize_or("seed", 42)? as u64;
                let k = 64usize;
                std::fs::create_dir_all(&dir)?;
                let mut specs = Vec::with_capacity(n_stages);
                for si in 0..n_stages {
                    // One rng stream per stage: stages hold DIFFERENT
                    // gradients (checkpoints diverge), same k.
                    let mut rows = vec![0.0f32; n_train * k];
                    logra::util::rng::Pcg32::new(seed, si as u64).fill_normal(&mut rows, 1.0);
                    let ids: Vec<u64> = (0..n_train as u64).collect();
                    let flat = dir.join(format!(".stage-{si}-src"));
                    let _ = std::fs::remove_dir_all(&flat);
                    std::fs::create_dir_all(&flat)?;
                    let mut w = logra::store::GradStoreWriter::create(&flat, k)?;
                    w.append(&ids, &rows)?;
                    w.finalize()?;
                    let name = format!("stage-{si}");
                    let sdir = dir.join(&name);
                    let _ = std::fs::remove_dir_all(&sdir);
                    shard_store(&flat, &sdir, n_shards)?;
                    std::fs::remove_dir_all(&flat)?;
                    specs.push(stage_spec(&name, &name));
                }
                let man = SessionManifest { version: SESSION_VERSION, stages: specs };
                man.save(&dir)?;
                println!(
                    "session ready: {} ({n_stages} stages x {n_train} rows, k={k}, \
                     {n_shards} shards each)",
                    dir.display()
                );
                return Ok(());
            }
            let combine_name = args.flag_or("combine", "weighted-sum");
            let combine = Combine::parse(&combine_name).ok_or_else(|| {
                anyhow!("unknown --combine {combine_name:?}; try weighted-sum|borda|per-stage")
            })?;
            let ba = BackendArgs::from_args(&args)?;
            let sess = Session::open(&dir, SessionConfig { combine, workers: ba.workers })?;
            match action {
                "stat" => {
                    println!(
                        "session {} — {} stages, combine {}, {} shared workers",
                        dir.display(),
                        sess.stages().len(),
                        sess.combine().name(),
                        sess.workers()
                    );
                    for st in sess.stages() {
                        let v = st.valuator();
                        let kind = v
                            .resolved_kind(st.spec().backend)
                            .map(|k| k.name())
                            .unwrap_or("?");
                        println!(
                            "  stage {:<12} {:>7} rows, k={}, backend {}, weight {}, \
                             damping {}, precond {}, norm {:?}, generation {}, quarantined {}",
                            st.name(),
                            v.rows(),
                            v.k(),
                            kind,
                            st.spec().weight,
                            st.spec().damping,
                            st.spec().preconditioner.name(),
                            st.spec().norm,
                            v.generation(),
                            v.quarantined().len()
                        );
                    }
                    sess.shutdown();
                    Ok(())
                }
                "query" => {
                    let row = args.usize_or("row", 0)?;
                    let topk = args.usize_or("topk", 5)?;
                    let g = sess.gradient_row(row).ok_or_else(|| {
                        anyhow!("row {row} out of range of the session's first stage")
                    })?;
                    let mut req = QueryRequest::gradients(g, 1, topk);
                    // Flags override per-stage manifest defaults only when
                    // explicitly passed — otherwise each stage keeps its
                    // own spec'd norm and backend route.
                    if let Some(n) = args.flag("norm") {
                        req = req.with_norm(Normalization::parse(n)?);
                    }
                    if ba.backend != "auto" {
                        let choice = match ba.backend.as_str() {
                            "exact" => BackendChoice::Exact,
                            "quantized" => BackendChoice::Quantized,
                            "ann" => BackendChoice::Ann { nprobe: Some(ba.nprobe) },
                            other => {
                                return Err(anyhow!(
                                    "unknown backend {other:?}; try auto|exact|quantized|ann"
                                ))
                            }
                        };
                        req = req.with_backend(choice);
                    }
                    let subset: Option<Vec<String>> = args
                        .flag("stages")
                        .map(|s| s.split(',').map(str::to_string).collect());
                    let report = sess.query_stages(req, subset.as_deref())?;
                    for sr in &report.stages {
                        println!(
                            "stage {} (weight {}, generation {}, quarantined {}):",
                            sr.name, sr.weight, sr.generation, sr.quarantined_shards
                        );
                        if let Some(rep) = &sr.report {
                            println!(
                                "  via {} — {} shards, {} rows, {:.3} ms",
                                rep.backend,
                                rep.shards,
                                rep.rows_scanned,
                                rep.total_nanos as f64 / 1e6
                            );
                        }
                        for &(score, id) in &sr.results[0].top {
                            println!("  [{score:+.6}] id {id}");
                        }
                    }
                    if let Some(combined) = &report.combined {
                        println!("combined ({}):", report.combine.name());
                        for &(score, id) in &combined[0].top {
                            println!("  [{score:+.6}] id {id}");
                        }
                    }
                    sess.shutdown();
                    Ok(())
                }
                other => Err(anyhow!("unknown session action {other:?}; try init|stat|query")),
            }
        }
        // Observability driver: fire N concurrent queries at the store
        // (pool-backed so shard tasks interleave), then export the span
        // ring as Chrome trace-event JSON (load it in chrome://tracing or
        // Perfetto) and print the latency percentiles.
        "trace" => {
            let dir = args.positional.first().map(PathBuf::from).ok_or_else(|| {
                anyhow!(
                    "usage: trace <store_dir> [--queries N] [--concurrency N] [--topk K] \
                     [--backend auto|exact|quantized|ann] [--nprobe N] \
                     [--rescore-factor N] [--workers N] [--damping X] [--out FILE]"
                )
            })?;
            let n_queries = args.usize_or("queries", 8)?.max(1);
            let concurrency = args.usize_or("concurrency", 8)?.max(1).min(n_queries);
            let topk = args.usize_or("topk", 5)?;
            let ba = BackendArgs::from_args(&args)?;
            let damping = args.f64_or("damping", 0.1)? as f32;
            let out_path = PathBuf::from(args.flag_or("out", "trace.json"));
            let metrics = Arc::new(Metrics::default());
            let builder = Valuator::open(&dir)?;
            let backend = ba.resolve(builder.auto_kind())?;
            let valuator = builder
                .backend(backend)
                .workers(ba.workers)
                .fit_from_store(damping)
                .pool(PoolMode::Auto)
                .metrics(metrics.clone())
                .build()?;
            let rows = valuator.rows();
            if rows == 0 {
                return Err(anyhow!("store {} is empty — nothing to trace", dir.display()));
            }
            let next = std::sync::atomic::AtomicUsize::new(0);
            let failures = std::sync::Mutex::new(Vec::<String>::new());
            std::thread::scope(|s| {
                for _ in 0..concurrency {
                    s.spawn(|| loop {
                        let q = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if q >= n_queries {
                            break;
                        }
                        let Some(g) = valuator.gradient_row(q % rows) else { break };
                        if let Err(e) = valuator.query(QueryRequest::gradients(g, 1, topk)) {
                            failures.lock().unwrap().push(format!("query {q}: {e}"));
                        }
                    });
                }
            });
            let failures = failures.into_inner().unwrap();
            if !failures.is_empty() {
                return Err(anyhow!(
                    "{} of {n_queries} traced queries failed: {}",
                    failures.len(),
                    failures.join("; ")
                ));
            }
            let events = metrics.obs.trace.events();
            std::fs::write(&out_path, chrome_trace_json(&events))?;
            println!(
                "traced {n_queries} queries ({} span events) -> {}",
                events.len(),
                out_path.display()
            );
            let lat = metrics.obs.query_latency.snapshot();
            println!(
                "query latency p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms",
                lat.percentile_ms(50.0),
                lat.percentile_ms(95.0),
                lat.percentile_ms(99.0)
            );
            let wait = metrics.obs.queue_wait.snapshot();
            println!(
                "queue wait    p50 {:.3} ms, p95 {:.3} ms, p99 {:.3} ms",
                wait.percentile_ms(50.0),
                wait.percentile_ms(95.0),
                wait.percentile_ms(99.0)
            );
            if let Some(pool) = valuator.scan_pool() {
                let snap = pool.snapshot();
                println!(
                    "pool          {} workers, {} tasks, {:.3} busy s",
                    snap.workers,
                    snap.tasks_completed,
                    snap.total_busy_seconds()
                );
            }
            Ok(())
        }
        // The valuation server: Valuator + shared Metrics behind four HTTP
        // endpoints, with admission control, per-request deadlines, and
        // client-disconnect cancellation. `--offline` synthesizes a
        // sharded store first (the runtime-free shape CI boots).
        "serve" => {
            // Session serving: every stage behind one listener, one
            // shared scan pool, per-stage reload slots. The single-store
            // path below is untouched.
            if let Some(sdir) = args.flag("session") {
                let combine_name = args.flag_or("combine", "weighted-sum");
                let combine = Combine::parse(&combine_name).ok_or_else(|| {
                    anyhow!(
                        "unknown --combine {combine_name:?}; try weighted-sum|borda|per-stage"
                    )
                })?;
                let ba = BackendArgs::from_args(&args)?;
                let reload_ms = args.usize_or("reload-ms", 0)? as u64;
                let sess = Session::open(
                    PathBuf::from(sdir),
                    SessionConfig { combine, workers: ba.workers },
                )?;
                let cfg = ServeConfig {
                    addr: args.flag_or("addr", "127.0.0.1:7878"),
                    max_in_flight: args.usize_or("max-in-flight", 8)?.max(1),
                    default_deadline_ms: args.usize_or("deadline-ms", 0)? as u64,
                    default_topk: args.usize_or("topk", 5)?.max(1),
                    poll_interval: std::time::Duration::from_millis(
                        args.usize_or("poll-ms", 15)?.max(1) as u64,
                    ),
                };
                println!(
                    "serving session {} — {} stages, combine {}, {} shared workers, \
                     max_in_flight {}{}",
                    sess.dir().display(),
                    sess.stages().len(),
                    sess.combine().name(),
                    sess.workers(),
                    cfg.max_in_flight,
                    if reload_ms > 0 {
                        format!(" (per-stage reload every {reload_ms} ms)")
                    } else {
                        String::new()
                    }
                );
                for st in sess.stages() {
                    println!(
                        "  stage {:<12} {:>7} rows, k={}, generation {}",
                        st.name(),
                        st.valuator().rows(),
                        st.valuator().k(),
                        st.valuator().generation()
                    );
                }
                let reload_every = (reload_ms > 0)
                    .then(|| std::time::Duration::from_millis(reload_ms));
                let server = Server::start_session(sess, cfg, reload_every)?;
                println!(
                    "listening on http://{} (POST /query, GET /metrics /healthz /debug/trace)",
                    server.addr()
                );
                server.join();
                return Ok(());
            }
            let offline = args.has_switch("offline");
            let dir = if offline {
                let n_train = args.usize_or("n-train", 2048)?.max(1);
                let n_shards = args.usize_or("shards", 4)?.max(1);
                let k = 64usize;
                let base = root.join("runs").join("serve-offline");
                let _ = std::fs::remove_dir_all(&base);
                std::fs::create_dir_all(&base)?;
                let mut rng = logra::util::rng::Pcg32::seeded(0x5EBE);
                let mut rows = vec![0.0f32; n_train * k];
                rng.fill_normal(&mut rows, 1.0);
                let ids: Vec<u64> = (0..n_train as u64).collect();
                let mut w = logra::store::GradStoreWriter::create(&base, k)?;
                w.append(&ids, &rows)?;
                w.finalize()?;
                // Shard so the pool-backed parallel engine serves it —
                // cancellation needs in-flight shard tasks to skip.
                let dir = if n_shards > 1 {
                    let sharded = root.join("runs").join("serve-offline-sharded");
                    let _ = std::fs::remove_dir_all(&sharded);
                    shard_store(&base, &sharded, n_shards)?;
                    sharded
                } else {
                    base
                };
                println!("offline store ready: {n_train} rows, k={k}, {n_shards} shards");
                dir
            } else {
                args.positional.first().map(PathBuf::from).ok_or_else(|| {
                    anyhow!(
                        "usage: serve <store_dir> [--addr A] [--max-in-flight N] \
                         [--deadline-ms N] [--poll-ms N] [--reload-ms N] [--topk K] \
                         [--backend auto|exact|quantized|ann] [--nprobe N] \
                         [--rescore-factor N] [--workers N] [--damping X] \
                         | serve --offline [--n-train N] [--shards N] \
                         | serve --session <session_dir> [--combine C]"
                    )
                })?
            };
            let ba = BackendArgs::from_args(&args)?;
            let damping = args.f64_or("damping", 0.1)? as f32;
            let reload_ms = args.usize_or("reload-ms", 0)? as u64;
            let metrics = Arc::new(Metrics::default());
            let builder = Valuator::open(&dir)?;
            let backend = ba.resolve(builder.auto_kind())?;
            // With live reload the scan pool must outlive any one
            // snapshot, so it is spawned here and shared into every
            // rebuilt valuator; a static serve keeps the old Auto shape.
            let pool = (reload_ms > 0)
                .then(|| Arc::new(logra::valuation::ScanPool::spawn(ba.workers)));
            let pool_mode = match &pool {
                Some(p) => PoolMode::Shared(p.clone()),
                None => PoolMode::Auto,
            };
            let valuator = Arc::new(
                builder
                    .backend(backend)
                    .workers(ba.workers)
                    .fit_from_store(damping)
                    .pool(pool_mode)
                    .metrics(metrics.clone())
                    .build()?,
            );
            let cfg = ServeConfig {
                addr: args.flag_or("addr", "127.0.0.1:7878"),
                max_in_flight: args.usize_or("max-in-flight", 8)?.max(1),
                default_deadline_ms: args.usize_or("deadline-ms", 0)? as u64,
                default_topk: args.usize_or("topk", 5)?.max(1),
                poll_interval: std::time::Duration::from_millis(
                    args.usize_or("poll-ms", 15)?.max(1) as u64,
                ),
            };
            println!(
                "serving {} — {} rows, k={}, backend {}, {} workers, max_in_flight {}, \
                 generation {}{}",
                dir.display(),
                valuator.rows(),
                valuator.k(),
                valuator.kind().name(),
                valuator.workers(),
                cfg.max_in_flight,
                valuator.generation(),
                if reload_ms > 0 {
                    format!(" (reload every {reload_ms} ms)")
                } else {
                    String::new()
                }
            );
            let reload = pool.map(|pool| {
                ReloadConfig::standard(
                    dir.clone(),
                    std::time::Duration::from_millis(reload_ms),
                    backend,
                    damping,
                    ba.workers,
                    pool,
                    metrics.clone(),
                )
            });
            let server = Server::start_with_reload(valuator, metrics, cfg, reload)?;
            println!(
                "listening on http://{} (POST /query, GET /metrics /healthz /debug/trace)",
                server.addr()
            );
            server.join();
            Ok(())
        }
        // Closed-loop load bench against a running serve instance;
        // `--bench-out BENCH_scan.json` merges the gated serve_c*_* keys.
        "loadgen" => {
            let cfg = loadgen::LoadgenConfig {
                addr: args.flag_or("addr", "127.0.0.1:7878"),
                clients: args.usize_or("clients", 8)?.max(1),
                requests_per_client: args.usize_or("requests", 32)?.max(1),
                topk: args.usize_or("topk", 5)?.max(1),
                max_retries: args.usize_or("max-retries", 3)?,
            };
            let report = loadgen::run(&cfg)?;
            print!("{}", report.render());
            if report.completed == 0 {
                return Err(anyhow!("no request completed — is the server up?"));
            }
            if let Some(path) = args.flag("bench-out") {
                let entries = loadgen::bench_entries(&report);
                loadgen::merge_bench_json(&PathBuf::from(path), &entries)?;
                println!("merged {} serve keys -> {path}", entries.len());
            }
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}; try --help")),
    }
}
