//! Training / evaluation loops over the AOT `train_step` / `eval_loss`
//! artifacts. Used by the end-to-end example and (heavily) by the
//! counterfactual eval harness, which retrains models hundreds of times.

use anyhow::Result;

use crate::model::dataset::{Batch, Dataset};
use crate::runtime::literal::{
    f32_lit, i32_scalar, to_f32_scalar, to_f32_vec, to_i32_scalar, u32_scalar,
};
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;

/// Flat model + optimizer state (mirrors the artifact calling convention).
#[derive(Clone)]
pub struct ModelState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i32,
}

impl ModelState {
    pub fn n(&self) -> usize {
        self.params.len()
    }
}

/// Training/eval driver bound to one artifact runtime.
pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        Trainer { rt }
    }

    /// Fresh parameters from the `init(seed)` artifact.
    pub fn init(&self, seed: u32) -> Result<ModelState> {
        let out = self.rt.run("init", &[u32_scalar(seed)])?;
        let params = to_f32_vec(&out[0])?;
        let n = params.len();
        Ok(ModelState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 })
    }

    /// One optimizer step on a batch; returns the mean batch loss.
    pub fn step(&self, st: &mut ModelState, batch: &Batch) -> Result<f32> {
        let man = &self.rt.manifest;
        let n = st.n();
        let mut args = vec![
            f32_lit(&[n], &st.params)?,
            f32_lit(&[n], &st.m)?,
            f32_lit(&[n], &st.v)?,
            i32_scalar(st.step),
        ];
        args.extend(batch.literals(man)?);
        let out = self.rt.run("train_step", &args)?;
        st.params = to_f32_vec(&out[0])?;
        st.m = to_f32_vec(&out[1])?;
        st.v = to_f32_vec(&out[2])?;
        st.step = to_i32_scalar(&out[3])?;
        to_f32_scalar(&out[4]).map_err(Into::into)
    }

    /// Train for `epochs` shuffled epochs over `indices`; returns the mean
    /// loss per epoch.
    pub fn train(
        &self,
        st: &mut ModelState,
        ds: &Dataset,
        indices: &[usize],
        epochs: usize,
        rng: &mut Pcg32,
    ) -> Result<Vec<f32>> {
        let man = &self.rt.manifest;
        let mut epoch_losses = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            let mut order = indices.to_vec();
            rng.shuffle(&mut order);
            let mut total = 0.0f64;
            let mut nb = 0usize;
            for batch in ds.batches(&order, man.train_batch) {
                total += self.step(st, &batch)? as f64;
                nb += 1;
            }
            epoch_losses.push((total / nb.max(1) as f64) as f32);
        }
        Ok(epoch_losses)
    }

    /// Per-example losses (and logits for MLP) over `indices`.
    /// Returns (losses, logits_flat_or_empty).
    pub fn eval(
        &self,
        st: &ModelState,
        ds: &Dataset,
        indices: &[usize],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let man = &self.rt.manifest;
        let n = st.n();
        let mut losses = Vec::with_capacity(indices.len());
        let mut logits = Vec::new();
        for batch in ds.batches(indices, man.log_batch) {
            let mut args = vec![f32_lit(&[n], &st.params)?];
            args.extend(batch.literals(man)?);
            let out = self.rt.run("eval_loss", &args)?;
            let l = to_f32_vec(&out[0])?;
            losses.extend_from_slice(&l[..batch.real()]);
            if out.len() > 1 {
                let lg = to_f32_vec(&out[1])?;
                let c = lg.len() / batch.size();
                logits.extend_from_slice(&lg[..batch.real() * c]);
            }
        }
        Ok((losses, logits))
    }

    /// Mean eval loss over `indices`.
    pub fn mean_loss(&self, st: &ModelState, ds: &Dataset, indices: &[usize]) -> Result<f64> {
        let (losses, _) = self.eval(st, ds, indices)?;
        Ok(crate::util::stats::mean(
            &losses.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        ))
    }

    /// Predicted classes for an MLP model over `indices`.
    pub fn predictions(
        &self,
        st: &ModelState,
        ds: &Dataset,
        indices: &[usize],
    ) -> Result<Vec<i32>> {
        let man = &self.rt.manifest;
        let (_, logits) = self.eval(st, ds, indices)?;
        let c = man.classes;
        assert!(c > 0, "predictions need an MLP artifact");
        Ok(logits
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32
            })
            .collect())
    }
}
