//! Unified dataset/batch view over the LM corpus and the image sets, so
//! the coordinator, eval harness and baselines are generic in the model
//! kind. A [`Batch`] knows how to render itself as the artifact-call
//! literals that follow the flat (params, [proj,] *batch) convention.

use anyhow::Result;
use xla::Literal;

use crate::data::{
    image_batches, token_batches, Corpus, ImageBatch, ImageSet, TokenBatch,
};
use crate::runtime::literal::{f32_lit, i32_lit};
use crate::runtime::Manifest;

/// A dataset of either LM documents or labelled images.
pub enum Dataset<'a> {
    Lm(&'a Corpus),
    Mlp(&'a ImageSet),
}

impl<'a> Dataset<'a> {
    pub fn len(&self) -> usize {
        match self {
            Dataset::Lm(c) => c.docs.len(),
            Dataset::Mlp(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fixed-shape batches over `indices` (pad rows repeat; `real` marks
    /// genuine rows).
    pub fn batches(&self, indices: &[usize], batch: usize) -> Vec<Batch> {
        match self {
            Dataset::Lm(c) => token_batches(c, indices, batch)
                .into_iter()
                .map(Batch::Tok)
                .collect(),
            Dataset::Mlp(s) => image_batches(s, indices, batch)
                .into_iter()
                .map(Batch::Img)
                .collect(),
        }
    }

    /// Batches over the full dataset in index order.
    pub fn all_batches(&self, batch: usize) -> Vec<Batch> {
        let idx: Vec<usize> = (0..self.len()).collect();
        self.batches(&idx, batch)
    }

    /// Tokens per example (LM: seq_len; MLP: 1) — throughput accounting.
    pub fn tokens_per_example(&self) -> usize {
        match self {
            Dataset::Lm(c) => c.seq_len,
            Dataset::Mlp(_) => 1,
        }
    }
}

/// One fixed-shape batch of either kind.
#[derive(Clone, Debug)]
pub enum Batch {
    Tok(TokenBatch),
    Img(ImageBatch),
}

impl Batch {
    pub fn ids(&self) -> &[u64] {
        match self {
            Batch::Tok(b) => &b.ids,
            Batch::Img(b) => &b.ids,
        }
    }

    pub fn real(&self) -> usize {
        match self {
            Batch::Tok(b) => b.real,
            Batch::Img(b) => b.real,
        }
    }

    pub fn size(&self) -> usize {
        self.ids().len()
    }

    /// The batch literals in artifact order (LM: tokens; MLP: images,
    /// labels). `man` supplies the static shapes to validate against.
    pub fn literals(&self, man: &Manifest) -> Result<Vec<Literal>> {
        match self {
            Batch::Tok(b) => {
                let bsz = b.ids.len();
                Ok(vec![i32_lit(&[bsz, man.seq_len], &b.tokens)?])
            }
            Batch::Img(b) => {
                let bsz = b.ids.len();
                Ok(vec![
                    f32_lit(&[bsz, man.input_dim], &b.features)?,
                    i32_lit(&[bsz], &b.labels)?,
                ])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusSpec, ImageSpec};

    #[test]
    fn dataset_len_and_batches() {
        let c = crate::data::corpus::generate(CorpusSpec::new(256, 16, 33, 1));
        let ds = Dataset::Lm(&c);
        assert_eq!(ds.len(), 33);
        let batches = ds.all_batches(8);
        assert_eq!(batches.len(), 5);
        assert_eq!(batches[4].real(), 1);
        assert_eq!(ds.tokens_per_example(), 16);

        let imgs = crate::data::images::generate(ImageSpec::fmnist_like(12, 3, 10, 2));
        let ds2 = Dataset::Mlp(&imgs);
        assert_eq!(ds2.len(), 10);
        assert_eq!(ds2.tokens_per_example(), 1);
        assert_eq!(ds2.all_batches(4).len(), 3);
    }
}
