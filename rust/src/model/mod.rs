//! Host-side model driver: parameter/optimizer state and the training /
//! evaluation loops that repeatedly invoke the `train_step` / `eval_loss`
//! artifacts. All numerics stay inside the AOT HLO programs; this layer
//! only shuttles flat vectors.

pub mod dataset;
pub mod generate;
pub mod trainer;

pub use dataset::{Batch, Dataset};
pub use trainer::{ModelState, Trainer};
