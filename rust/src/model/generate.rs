//! Autoregressive sampling through the `logits` artifact (LM only).
//!
//! The qualitative experiment (Fig. 5) queries the valuation system with
//! MODEL OUTPUTS, so the coordinator needs generation. The artifact is
//! closed over [1, seq_len]; causality makes positions ≥ current length
//! irrelevant, so we run the full window each step and read the logits at
//! the frontier — O(T) executions per sequence, fine at this scale.

use anyhow::Result;

use crate::runtime::literal::{f32_lit, i32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;

/// Sample a continuation of `prompt` up to the artifact's seq_len.
/// `temperature` 0 = greedy.
pub fn generate(
    rt: &Runtime,
    params: &[f32],
    prompt: &[i32],
    temperature: f32,
    rng: &mut Pcg32,
) -> Result<Vec<i32>> {
    let man = &rt.manifest;
    anyhow::ensure!(man.is_lm(), "generate needs an LM artifact");
    let t = man.seq_len;
    let v = man.vocab;
    anyhow::ensure!(!prompt.is_empty() && prompt.len() <= t, "bad prompt length");
    let params_lit = f32_lit(&[man.n_params], params)?;
    let mut tokens = vec![0i32; t];
    tokens[..prompt.len()].copy_from_slice(prompt);
    let mut len = prompt.len();
    while len < t {
        let tok_lit = i32_lit(&[1, t], &tokens)?;
        let out = rt.run_ref("logits", &[&params_lit, &tok_lit])?;
        let logits = to_f32_vec(&out[0])?; // [1, T, V]
        let row = &logits[(len - 1) * v..len * v];
        let next = if temperature <= 0.0 {
            argmax(row)
        } else {
            sample_softmax(row, temperature, rng)
        };
        tokens[len] = next as i32;
        len += 1;
    }
    Ok(tokens)
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn sample_softmax(row: &[f32], temperature: f32, rng: &mut Pcg32) -> usize {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut cdf = Vec::with_capacity(row.len());
    let mut acc = 0.0f64;
    for &l in row {
        acc += (((l - max) / temperature) as f64).exp();
        cdf.push(acc);
    }
    rng.categorical_cdf(&cdf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sampling_basics() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        let mut rng = Pcg32::seeded(1);
        // Near-zero temperature concentrates on the max.
        let mut hits = 0;
        for _ in 0..50 {
            if sample_softmax(&[0.0, 10.0, 0.0], 0.05, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits >= 49);
        // High temperature spreads out.
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(sample_softmax(&[0.0, 1.0, 0.5], 10.0, &mut rng));
        }
        assert!(seen.len() >= 2);
    }
}
