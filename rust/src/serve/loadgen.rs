//! `logra loadgen` — closed-loop load bench against a running
//! `logra serve` instance, with a `BENCH_scan.json` read-modify-write so
//! the serving SLO rides the same CI gate as the scan benches.
//!
//! N client threads each hold one keep-alive connection and issue
//! `POST /query` requests back-to-back (closed loop: a client's next
//! request starts when its previous response lands). Per-request wall
//! latency feeds p50/p99; a client that hits an I/O or non-200 response
//! counts an error and reconnects instead of dying — the summary reports
//! per-client error counts (the serving mirror of the
//! `examples/serve_queries.rs` fix).
//!
//! A 429 (admission gate full) or 503 (backend shutting down / reloading)
//! is the server ASKING for a retry, not a failure: the client backs off
//! with jittered exponential delay (base 2 ms doubled per attempt, capped
//! at 100 ms) and re-sends on the same keep-alive connection, up to
//! [`LoadgenConfig::max_retries`] times before counting an error. Retries
//! are reported separately from errors — a run that rode out overload is
//! distinguishable from one that dropped work.

use std::io::BufReader;
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Pcg32;
use crate::util::stats::percentile;

use super::http;

/// Load-generator knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// `topk` per query.
    pub topk: usize,
    /// Backoff-and-retry budget per request for 429/503 responses; after
    /// this many retries the request counts as an error.
    pub max_retries: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7878".into(),
            clients: 8,
            requests_per_client: 32,
            topk: 5,
            max_retries: 3,
        }
    }
}

/// What one run measured.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub clients: usize,
    /// Requests attempted across all clients.
    pub attempted: usize,
    /// Requests that returned 200 with a parseable body.
    pub completed: usize,
    /// Failed requests per client (I/O error, non-200, bad body). Clients
    /// reconnect and continue instead of dying.
    pub per_client_errors: Vec<usize>,
    /// Backoff-and-retry attempts across all clients (429/503 responses
    /// that were re-sent; not counted in `per_client_errors` unless the
    /// retry budget ran out).
    pub retries: usize,
    /// Session servers only: (stage name, responses in which that stage
    /// carried an `"error"` entry), aggregated across clients and sorted
    /// by name. A 200 with stage errors still counts as completed — the
    /// combined ranking degraded, the request did not fail. Empty against
    /// single-store servers (their responses carry no `"stage_errors"`).
    pub stage_errors: Vec<(String, usize)>,
    pub wall_seconds: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl LoadgenReport {
    pub fn errors(&self) -> usize {
        self.per_client_errors.iter().sum()
    }

    /// Human-readable summary (what `logra loadgen` prints).
    pub fn render(&self) -> String {
        let mut s = format!(
            "loadgen: {} clients x {} requests, {} ok / {} errors / {} retries in {:.2}s\n\
             throughput  {:.1} queries/s\n\
             latency     p50 {:.3} ms, p99 {:.3} ms\n",
            self.clients,
            if self.clients > 0 { self.attempted / self.clients } else { 0 },
            self.completed,
            self.errors(),
            self.retries,
            self.wall_seconds,
            self.qps,
            self.p50_ms,
            self.p99_ms
        );
        if self.errors() > 0 {
            s.push_str("per-client errors: ");
            for (c, e) in self.per_client_errors.iter().enumerate() {
                if c > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("client {c}: {e}"));
            }
            s.push('\n');
        }
        if !self.stage_errors.is_empty() {
            s.push_str("per-stage errors: ");
            for (i, (name, n)) in self.stage_errors.iter().enumerate() {
                if i > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("{name}: {n}"));
            }
            s.push('\n');
        }
        s
    }
}

/// One-shot HTTP request on a fresh connection (health checks, tests).
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> Result<http::Response> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    http::write_request(&mut writer, method, path, body)?;
    Ok(http::read_response(&mut reader)?)
}

/// Why one `POST /query` attempt did not complete.
enum QueryFailure {
    /// The server answered cleanly but asked us to come back: 429
    /// (admission gate full) or 503 (backend unavailable). The keep-alive
    /// connection is still good — back off and re-send on it.
    Retryable(u16),
    /// Anything else: I/O error, other non-200, malformed body. The
    /// connection state is suspect — count an error and reconnect.
    Other(String),
}

/// One keep-alive client connection.
struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Client { writer, reader: BufReader::new(stream) })
    }

    fn query(&mut self, body: &str) -> std::result::Result<Vec<String>, QueryFailure> {
        let io = |e: std::io::Error| QueryFailure::Other(e.to_string());
        http::write_request(&mut self.writer, "POST", "/query", body.as_bytes())
            .map_err(io)?;
        let res = http::read_response(&mut self.reader).map_err(io)?;
        match res.status {
            200 => {}
            429 | 503 => return Err(QueryFailure::Retryable(res.status)),
            s => {
                return Err(QueryFailure::Other(format!("status {s}: {}", res.body_str())))
            }
        }
        // Parse so "completed" means a well-formed scored response, not
        // just 200 bytes on the wire.
        let v = json::parse(&res.body_str())
            .map_err(|e| QueryFailure::Other(format!("{e:#}")))?;
        v.get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| QueryFailure::Other("response missing results array".into()))?;
        Ok(stage_error_names(&v))
    }
}

/// Names of the stages that carried an `"error"` entry in a session
/// server's 200 response (empty for single-store responses, which have
/// no `"stage_errors"` field).
fn stage_error_names(v: &Json) -> Vec<String> {
    let mut names = Vec::new();
    if v.get("stage_errors").and_then(Json::as_u64).unwrap_or(0) > 0 {
        if let Some(stages) = v.get("stages").and_then(Json::as_arr) {
            for st in stages {
                if st.get("error").is_some() {
                    if let Some(name) = st.get("name").and_then(Json::as_str) {
                        names.push(name.to_string());
                    }
                }
            }
        }
    }
    names
}

/// Jittered exponential backoff before retry number `attempt` (0-based):
/// 2 ms doubled per attempt, capped at 100 ms, with up to one extra base
/// delay of uniform jitter so clients that collided on a 429 don't all
/// come back in lockstep.
fn backoff_delay(attempt: usize, rng: &mut Pcg32) -> Duration {
    let base_ms = (2u64 << attempt.min(16)).min(100);
    Duration::from_micros(base_ms * 1000 + rng.below(1000) as u64 * base_ms)
}

/// Run the closed loop. Row indices cycle deterministically per client so
/// runs are comparable; the store size comes from `GET /healthz`.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    let health = http_request(&cfg.addr, "GET", "/healthz", b"")?;
    if health.status != 200 {
        bail!("healthz returned {}: {}", health.status, health.body_str());
    }
    let rows = json::parse(&health.body_str())?
        .get("rows")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("healthz body missing rows"))? as usize;
    if rows == 0 {
        bail!("server store is empty — nothing to query");
    }

    let clients = cfg.clients.max(1);
    let per_client = cfg.requests_per_client.max(1);
    let t0 = Instant::now();
    type ClientOutcome = (Vec<f64>, usize, usize, std::collections::BTreeMap<String, usize>);
    let outcomes: Vec<ClientOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let mut latencies = Vec::with_capacity(per_client);
                    let mut errors = 0usize;
                    let mut retries = 0usize;
                    let mut stage_errs = std::collections::BTreeMap::<String, usize>::new();
                    let mut rng = Pcg32::new(0xB0FF, c as u64);
                    let mut conn = Client::connect(&cfg.addr).ok();
                    for q in 0..per_client {
                        let row = (c * 37 + q * 13) % rows;
                        let body =
                            format!("{{\"row\":{row},\"topk\":{}}}", cfg.topk.max(1));
                        let t = Instant::now();
                        // The request's retry budget: a 429/503 backs off
                        // and re-sends (the latency sample includes the
                        // backoff — that wait IS the cost of overload);
                        // anything else, or running out of budget, counts
                        // an error and reconnects so one bad response
                        // can't kill the client thread.
                        let mut attempt = 0usize;
                        loop {
                            let outcome = match conn.as_mut() {
                                Some(client) => client.query(&body),
                                None => Err(QueryFailure::Other("not connected".into())),
                            };
                            match outcome {
                                Ok(staged) => {
                                    latencies.push(t.elapsed().as_secs_f64());
                                    for name in staged {
                                        *stage_errs.entry(name).or_insert(0) += 1;
                                    }
                                    break;
                                }
                                Err(QueryFailure::Retryable(_))
                                    if attempt < cfg.max_retries =>
                                {
                                    retries += 1;
                                    std::thread::sleep(backoff_delay(attempt, &mut rng));
                                    attempt += 1;
                                }
                                Err(_) => {
                                    errors += 1;
                                    conn = Client::connect(&cfg.addr).ok();
                                    break;
                                }
                            }
                        }
                    }
                    (latencies, errors, retries, stage_errs)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or((Vec::new(), per_client, 0, Default::default()))
            })
            .collect()
    });
    let wall_seconds = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::new();
    let mut per_client_errors = Vec::with_capacity(clients);
    let mut retries = 0usize;
    let mut stage_error_map = std::collections::BTreeMap::<String, usize>::new();
    for (lat, errs, rts, staged) in outcomes {
        latencies.extend(lat);
        per_client_errors.push(errs);
        retries += rts;
        for (name, n) in staged {
            *stage_error_map.entry(name).or_insert(0) += n;
        }
    }
    let completed = latencies.len();
    Ok(LoadgenReport {
        clients,
        attempted: clients * per_client,
        completed,
        per_client_errors,
        retries,
        stage_errors: stage_error_map.into_iter().collect(),
        wall_seconds,
        qps: completed as f64 / wall_seconds.max(1e-9),
        p50_ms: percentile(&latencies, 50.0) * 1e3,
        p99_ms: percentile(&latencies, 99.0) * 1e3,
    })
}

/// The gated bench keys for a run at `clients` concurrency:
/// `serve_cN_qps` (higher is better) and `serve_cN_p50_ms` /
/// `serve_cN_p99_ms` (latency ceilings), matching
/// `scripts/bench_gate.py`.
pub fn bench_entries(report: &LoadgenReport) -> Vec<(String, f64)> {
    let c = report.clients;
    vec![
        (format!("serve_c{c}_qps"), report.qps),
        (format!("serve_c{c}_p50_ms"), report.p50_ms),
        (format!("serve_c{c}_p99_ms"), report.p99_ms),
    ]
}

/// Read-modify-write `entries` into the JSON object at `path`
/// (`BENCH_scan.json`): existing keys are replaced in place, new keys
/// appended, every other key (the microbench rows) left untouched. The
/// file is created as a fresh object when missing.
pub fn merge_bench_json(path: &Path, entries: &[(String, f64)]) -> Result<()> {
    let mut root = if path.exists() {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        json::parse(&text).with_context(|| format!("parse {}", path.display()))?
    } else {
        Json::Obj(Vec::new())
    };
    let Json::Obj(pairs) = &mut root else {
        bail!("{} is not a JSON object", path.display());
    };
    for (key, value) in entries {
        let v = Json::Float(*value);
        match pairs.iter_mut().find(|(k, _)| k == key) {
            Some((_, slot)) => *slot = v,
            None => pairs.push((key.clone(), v)),
        }
    }
    let mut text = root.render();
    text.push('\n');
    std::fs::write(path, text).with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_updates_and_preserves_keys() {
        let dir = std::env::temp_dir().join("logra-loadgen-merge-test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_scan.json");
        std::fs::write(
            &path,
            "{\n  \"rows\": 8192,\n  \"kernel_arm\": \"avx2\",\n  \"serve_c8_qps\": 1.0\n}\n",
        )
        .unwrap();
        merge_bench_json(
            &path,
            &[
                ("serve_c8_qps".to_string(), 120.5),
                ("serve_c8_p50_ms".to_string(), 12.25),
            ],
        )
        .unwrap();
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("rows").and_then(Json::as_u64), Some(8192));
        assert_eq!(v.get("kernel_arm").and_then(Json::as_str), Some("avx2"));
        assert_eq!(v.get("serve_c8_qps").and_then(Json::as_f64), Some(120.5));
        assert_eq!(v.get("serve_c8_p50_ms").and_then(Json::as_f64), Some(12.25));
    }

    #[test]
    fn merge_creates_missing_file() {
        let dir = std::env::temp_dir().join("logra-loadgen-merge-create");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_scan.json");
        merge_bench_json(&path, &[("serve_c8_qps".to_string(), 9.5)]).unwrap();
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("serve_c8_qps").and_then(Json::as_f64), Some(9.5));
    }

    #[test]
    fn report_renders_per_client_errors() {
        let r = LoadgenReport {
            clients: 2,
            attempted: 8,
            completed: 6,
            per_client_errors: vec![0, 2],
            retries: 3,
            stage_errors: Vec::new(),
            wall_seconds: 1.0,
            qps: 6.0,
            p50_ms: 1.0,
            p99_ms: 2.0,
        };
        let s = r.render();
        assert!(s.contains("6 ok / 2 errors / 3 retries"));
        assert!(s.contains("client 1: 2"));
        assert!(!s.contains("per-stage"));
    }

    #[test]
    fn report_renders_stage_errors() {
        let r = LoadgenReport {
            clients: 1,
            attempted: 4,
            completed: 4,
            per_client_errors: vec![0],
            retries: 0,
            stage_errors: vec![("finetune".to_string(), 3)],
            wall_seconds: 1.0,
            qps: 4.0,
            p50_ms: 1.0,
            p99_ms: 2.0,
        };
        assert!(r.render().contains("per-stage errors: finetune: 3"));
    }

    #[test]
    fn stage_error_names_reads_session_bodies() {
        // Single-store response: no stage_errors field -> nothing.
        let single = json::parse(r#"{"results": []}"#).unwrap();
        assert!(stage_error_names(&single).is_empty());
        // Session response with one degraded stage.
        let session = json::parse(
            r#"{"results": [], "stage_errors": 1, "stages": [
                {"name": "pretrain", "results": []},
                {"name": "finetune", "error": "store open failed"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(stage_error_names(&session), vec!["finetune".to_string()]);
        // stage_errors 0 short-circuits the scan.
        let clean = json::parse(
            r#"{"results": [], "stage_errors": 0, "stages": [{"name": "a", "results": []}]}"#,
        )
        .unwrap();
        assert!(stage_error_names(&clean).is_empty());
    }

    #[test]
    fn backoff_doubles_with_cap_and_bounded_jitter() {
        let mut rng = Pcg32::new(0xB0FF, 0);
        for (attempt, base_ms) in [(0u64, 2u64), (1, 4), (2, 8), (5, 64), (6, 100), (40, 100)]
        {
            let d = backoff_delay(attempt as usize, &mut rng);
            assert!(
                d >= Duration::from_millis(base_ms),
                "attempt {attempt}: {d:?} under base {base_ms}ms"
            );
            assert!(
                d <= Duration::from_millis(2 * base_ms),
                "attempt {attempt}: {d:?} over base+jitter {}ms",
                2 * base_ms
            );
        }
    }
}
