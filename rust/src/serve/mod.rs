//! `logra serve` — the observability-first valuation server.
//!
//! A threaded HTTP/1.1 server (hand-rolled framing over
//! `std::net::TcpListener`, no new dependencies — see [`http`]) over the
//! [`Valuator`] facade. One accept thread, one thread per connection
//! (keep-alive), one shared `Arc<Metrics>`:
//!
//! - `POST /query` — JSON body `{"row": N}` or
//!   `{"gradient": [...], "nt": 1}`, optional per-request `"topk"`,
//!   `"norm"` (`"none"`/`"relatif"`), `"deadline_ms"`, and `"backend"`
//!   (`"auto"`/`"exact"`/`"quantized"`/`"ann"`, plus `"nprobe"` with
//!   `"ann"`) — a backend the fabric cannot serve is a 400. The response
//!   carries ids + scores (floats rendered shortest-roundtrip, so they
//!   re-parse bit-identical), a server-wide `request_id`, the name of the
//!   backend that ACTUALLY served (after `auto` resolution), and the full
//!   [`QueryReport`] stage breakdown.
//! - `GET /metrics` — [`render_exposition`] verbatim (counters, pool
//!   snapshot, histograms) plus the server's own `logra_serve_*`
//!   families, from the one shared `Arc<Metrics>`.
//! - `GET /healthz` — store / backend / pool liveness as JSON.
//! - `GET /debug/trace` — the [`TraceRing`](crate::obs::TraceRing) as
//!   Chrome trace-event JSON ([`chrome_trace_json`]).
//!
//! # Admission control, deadlines, cancellation
//!
//! At most [`ServeConfig::max_in_flight`] queries run at once; excess
//! `POST /query` requests are rejected immediately with a 429 JSON error
//! (no queueing — the caller retries, the scan pool never sees the
//! query). While a query is in flight the handler waits through
//! [`PendingScores::wait_with_report_until`], re-checking every
//! [`ServeConfig::poll_interval`]:
//!
//! - **deadline** (per-request `deadline_ms`, default
//!   [`ServeConfig::default_deadline_ms`]; 0 = none) → the wait cancels,
//!   the pool skips the query's unstarted shard tasks (the
//!   `tasks_cancelled` pool counter), and the client gets a 504.
//! - **client disconnect** (detected with a non-blocking `peek` on the
//!   connection) → same cancellation, no response (nobody is listening),
//!   counted in `logra_serve_disconnects_total`.
//!
//! Cancellation needs a pool-backed backend (a sharded f32 or quantized
//! fabric): the sequential engine scans eagerly at admission, so there is
//! nothing left to cancel by the time the handler waits.
//!
//! # Live reload (generation-snapshotted serving)
//!
//! With a [`ReloadConfig`] ([`Server::start_with_reload`], or
//! `logra serve --reload-ms N`), the server follows a live-growing store:
//! a reloader thread re-reads the manifest generation every `interval`
//! and, when it advances, rebuilds the valuator (via the config's
//! `rebuild` closure — normally [`Valuator::open_degraded`], so a shard
//! failing validation is quarantined rather than fatal) and swaps it into
//! the shared [`Slot`]. Every query pins one snapshot at admission and
//! serves entirely from it: responses carry the `generation` they were
//! answered under, and no response ever blends shards from two
//! generations. A failed rebuild leaves the previous snapshot serving and
//! increments `logra_store_reload_errors_total`; `/healthz` and
//! `/metrics` expose the live generation, quarantined-shard count, and
//! IVF fallback-shard count.

pub mod http;
pub mod loadgen;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::Metrics;
use crate::obs::export::simple;
use crate::obs::{chrome_trace_json, render_exposition, QueryReport};
use crate::store::{current_generation, Slot};
use crate::util::json::{self, Json};
use crate::valuation::{
    Backend, BackendChoice, Normalization, PoolMode, QueryRequest, QueryResult, ScanBackend,
    ScanPool, ValuationError, Valuator,
};

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// Queries allowed in flight at once; excess is rejected with 429.
    pub max_in_flight: usize,
    /// Default per-query deadline in ms (0 = none); any request can
    /// override with `"deadline_ms"`.
    pub default_deadline_ms: u64,
    /// `topk` when the request omits it.
    pub default_topk: usize,
    /// How often an in-flight query re-checks deadline + disconnect.
    pub poll_interval: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            max_in_flight: 8,
            default_deadline_ms: 0,
            default_topk: 5,
            poll_interval: Duration::from_millis(15),
        }
    }
}

/// Server-side counters, exported as `logra_serve_*` families on
/// `/metrics` alongside the shared [`Metrics`] exposition.
#[derive(Default)]
struct ServeStats {
    /// HTTP requests handled (all endpoints, all statuses).
    requests: AtomicU64,
    /// `POST /query` requests admitted past the in-flight gate.
    queries: AtomicU64,
    /// Queries rejected at admission (`max_in_flight` exceeded).
    rejected: AtomicU64,
    /// Queries cancelled by deadline expiry.
    deadline_expired: AtomicU64,
    /// Queries cancelled because the client disconnected mid-flight.
    disconnects: AtomicU64,
    /// Requests answered with a 4xx/5xx status.
    errors: AtomicU64,
    /// Successful manifest reloads (valuator snapshot swaps).
    reloads: AtomicU64,
    /// Reload attempts that failed (previous snapshot kept serving).
    reload_errors: AtomicU64,
}

struct Shared {
    /// The serving snapshot. Queries pin one `Arc<Valuator>` at admission
    /// and never observe a mid-flight swap; the reloader thread publishes
    /// new generations here.
    valuator: Slot<Valuator>,
    metrics: Arc<Metrics>,
    cfg: ServeConfig,
    stats: ServeStats,
    in_flight: AtomicUsize,
    next_request_id: AtomicU64,
}

/// RAII decrement for the admission gate.
struct InFlightGuard<'a>(&'a Shared);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Shared {
    /// Claim an in-flight slot, or `None` when the server is saturated.
    fn admit(&self) -> Option<InFlightGuard<'_>> {
        let limit = self.cfg.max_in_flight.max(1);
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= limit {
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(InFlightGuard(self)),
                Err(now) => cur = now,
            }
        }
    }

    /// `/metrics`: the shared exposition plus the `logra_serve_*` families.
    fn render_metrics(&self) -> String {
        let valuator = self.valuator.load();
        let pool = valuator.scan_pool().map(|p| p.snapshot());
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        let mut out = render_exposition(
            &self.metrics,
            pool.as_ref(),
            &[
                (
                    "logra_store_rows",
                    "Rows in the served store fabric.",
                    valuator.rows() as f64,
                ),
                (
                    "logra_store_k",
                    "Projected gradient dimension.",
                    valuator.k() as f64,
                ),
            ],
        );
        simple(
            &mut out,
            "logra_store_generation",
            "Manifest generation of the serving snapshot.",
            "gauge",
            valuator.generation() as f64,
        );
        simple(
            &mut out,
            "logra_store_reloads_total",
            "Successful manifest reloads (snapshot swaps).",
            "counter",
            ld(&self.stats.reloads),
        );
        simple(
            &mut out,
            "logra_store_reload_errors_total",
            "Reload attempts that failed; the previous snapshot kept serving.",
            "counter",
            ld(&self.stats.reload_errors),
        );
        simple(
            &mut out,
            "logra_store_quarantined_shards",
            "Shards that failed validation at reload and were quarantined.",
            "gauge",
            valuator.quarantined().len() as f64,
        );
        simple(
            &mut out,
            "logra_store_ivf_fallback_shards",
            "Shards the IVF engine serves via dense fallback (no index sidecar).",
            "gauge",
            valuator.ivf_fallback_shards() as f64,
        );
        simple(
            &mut out,
            "logra_serve_requests_total",
            "HTTP requests handled by logra serve (all endpoints).",
            "counter",
            ld(&self.stats.requests),
        );
        simple(
            &mut out,
            "logra_serve_queries_total",
            "POST /query requests admitted past the in-flight gate.",
            "counter",
            ld(&self.stats.queries),
        );
        simple(
            &mut out,
            "logra_serve_rejected_total",
            "Queries rejected at admission (max_in_flight exceeded).",
            "counter",
            ld(&self.stats.rejected),
        );
        simple(
            &mut out,
            "logra_serve_deadline_expired_total",
            "Queries cancelled by per-request deadline expiry.",
            "counter",
            ld(&self.stats.deadline_expired),
        );
        simple(
            &mut out,
            "logra_serve_disconnects_total",
            "Queries cancelled because the client disconnected mid-flight.",
            "counter",
            ld(&self.stats.disconnects),
        );
        simple(
            &mut out,
            "logra_serve_errors_total",
            "Requests answered with a 4xx/5xx status.",
            "counter",
            ld(&self.stats.errors),
        );
        simple(
            &mut out,
            "logra_serve_in_flight",
            "Queries currently inside the admission gate.",
            "gauge",
            self.in_flight.load(Ordering::Relaxed) as f64,
        );
        simple(
            &mut out,
            "logra_serve_max_in_flight",
            "Admission gate capacity.",
            "gauge",
            self.cfg.max_in_flight.max(1) as f64,
        );
        out
    }

    /// `/healthz`: store / backend / pool liveness (the JSON subset has
    /// no booleans, so liveness is `"status": "ok"` plus numbers).
    fn render_healthz(&self) -> String {
        let valuator = self.valuator.load();
        let mut pairs = vec![
            ("status".to_string(), Json::Str("ok".into())),
            ("backend".to_string(), Json::Str(valuator.kind().name().into())),
            ("rows".to_string(), Json::Num(valuator.rows() as u64)),
            ("k".to_string(), Json::Num(valuator.k() as u64)),
            ("workers".to_string(), Json::Num(valuator.workers() as u64)),
            ("generation".to_string(), Json::Num(valuator.generation())),
            (
                "quarantined_shards".to_string(),
                Json::Num(valuator.quarantined().len() as u64),
            ),
            (
                "ivf_fallback_shards".to_string(),
                Json::Num(valuator.ivf_fallback_shards() as u64),
            ),
            (
                "reloads".to_string(),
                Json::Num(self.stats.reloads.load(Ordering::Relaxed)),
            ),
            (
                "reload_errors".to_string(),
                Json::Num(self.stats.reload_errors.load(Ordering::Relaxed)),
            ),
            (
                "in_flight".to_string(),
                Json::Num(self.in_flight.load(Ordering::Relaxed) as u64),
            ),
            (
                "max_in_flight".to_string(),
                Json::Num(self.cfg.max_in_flight.max(1) as u64),
            ),
        ];
        if let Some(p) = valuator.scan_pool() {
            let s = p.snapshot();
            pairs.push((
                "pool".to_string(),
                Json::Obj(vec![
                    ("workers".to_string(), Json::Num(s.workers as u64)),
                    ("in_flight".to_string(), Json::Num(s.in_flight as u64)),
                    ("queue_depth".to_string(), Json::Num(s.queue_depth as u64)),
                    ("tasks_completed".to_string(), Json::Num(s.tasks_completed)),
                    ("tasks_cancelled".to_string(), Json::Num(s.tasks_cancelled)),
                ]),
            ));
        }
        Json::Obj(pairs).render()
    }
}

// ------------------------------------------------------------ query bodies

/// Query input: a stored row index, or inline gradient rows.
pub(crate) enum QueryBody {
    Row(u64),
    Gradient { rows: Vec<f32>, nt: usize },
}

/// A parsed `POST /query` body.
pub(crate) struct ParsedQuery {
    pub(crate) body: QueryBody,
    pub(crate) topk: usize,
    pub(crate) norm: Option<Normalization>,
    pub(crate) deadline_ms: Option<u64>,
    pub(crate) backend: Option<BackendChoice>,
}

/// Parse a query body against the server defaults. Errors are
/// caller-facing strings (they become 400 JSON errors).
pub(crate) fn parse_query_body(
    text: &str,
    default_topk: usize,
) -> Result<ParsedQuery, String> {
    let v = json::parse(text).map_err(|e| format!("invalid JSON body: {e:#}"))?;
    if !matches!(v, Json::Obj(_)) {
        return Err("query body must be a JSON object".into());
    }
    let topk = match v.get("topk") {
        None => default_topk,
        Some(t) => t
            .as_u64()
            .filter(|&t| t > 0)
            .ok_or("\"topk\" must be a positive integer")? as usize,
    };
    let norm = match v.get("norm") {
        None => None,
        Some(n) => {
            let s = n.as_str().ok_or("\"norm\" must be \"none\" or \"relatif\"")?;
            Some(Normalization::parse(s).map_err(|e| format!("{e:#}"))?)
        }
    };
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(d) => {
            Some(d.as_u64().ok_or("\"deadline_ms\" must be a non-negative integer")?)
        }
    };
    let backend = match v.get("backend") {
        None => None,
        Some(b) => {
            let s = b.as_str().ok_or(
                "\"backend\" must be \"auto\", \"exact\", \"quantized\", or \"ann\"",
            )?;
            Some(BackendChoice::parse(s).ok_or(
                "\"backend\" must be \"auto\", \"exact\", \"quantized\", or \"ann\"",
            )?)
        }
    };
    let backend = match v.get("nprobe") {
        None => backend,
        Some(n) => {
            let n = n
                .as_u64()
                .filter(|&n| n > 0)
                .ok_or("\"nprobe\" must be a positive integer")? as usize;
            match backend {
                Some(BackendChoice::Ann { .. }) => {
                    Some(BackendChoice::Ann { nprobe: Some(n) })
                }
                _ => return Err("\"nprobe\" requires \"backend\": \"ann\"".into()),
            }
        }
    };
    let body = match (v.get("row"), v.get("gradient")) {
        (Some(_), Some(_)) => {
            return Err("pass either \"row\" or \"gradient\", not both".into())
        }
        (Some(r), None) => {
            QueryBody::Row(r.as_u64().ok_or("\"row\" must be a non-negative integer")?)
        }
        (None, Some(g)) => {
            let arr = g.as_arr().ok_or("\"gradient\" must be an array of numbers")?;
            let rows: Vec<f32> = arr
                .iter()
                .map(|x| x.as_f64().map(|f| f as f32))
                .collect::<Option<_>>()
                .ok_or("\"gradient\" must be an array of numbers")?;
            let nt = match v.get("nt") {
                None => 1,
                Some(n) => n
                    .as_u64()
                    .filter(|&n| n > 0)
                    .ok_or("\"nt\" must be a positive integer")? as usize,
            };
            QueryBody::Gradient { rows, nt }
        }
        (None, None) => return Err("query body needs \"row\" or \"gradient\"".into()),
    };
    Ok(ParsedQuery { body, topk, norm, deadline_ms, backend })
}

// -------------------------------------------------------------- responses

/// `{"error":{"code":...,"message":...}}` through the shared escape-safe
/// JSON writer.
fn error_body(code: &str, message: &str) -> String {
    Json::Obj(vec![(
        "error".to_string(),
        Json::Obj(vec![
            ("code".to_string(), Json::Str(code.to_string())),
            ("message".to_string(), Json::Str(message.to_string())),
        ]),
    )])
    .render()
}

fn report_json(rep: &QueryReport) -> Json {
    Json::Obj(vec![
        ("query_id".to_string(), Json::Num(rep.query_id)),
        ("backend".to_string(), Json::Str(rep.backend.to_string())),
        ("shards".to_string(), Json::Num(rep.shards as u64)),
        ("rows_scanned".to_string(), Json::Num(rep.rows_scanned)),
        ("candidates_rescored".to_string(), Json::Num(rep.candidates_rescored)),
        ("admission_nanos".to_string(), Json::Num(rep.admission_nanos)),
        ("queue_wait_nanos".to_string(), Json::Num(rep.queue_wait_nanos)),
        ("scan_nanos".to_string(), Json::Num(rep.scan_nanos)),
        ("merge_nanos".to_string(), Json::Num(rep.merge_nanos)),
        ("rescore_nanos".to_string(), Json::Num(rep.rescore_nanos)),
        ("total_nanos".to_string(), Json::Num(rep.total_nanos)),
        (
            "workers".to_string(),
            Json::Arr(rep.workers.iter().map(|&w| Json::Num(w as u64)).collect()),
        ),
    ])
}

/// The `POST /query` 200 body. Scores go through [`Json::Float`]'s
/// shortest-roundtrip rendering, so a client parsing them back recovers
/// the exact bits `Valuator::query` produced.
fn query_response_body(
    request_id: u64,
    backend: &str,
    generation: u64,
    results: &[QueryResult],
    report: Option<&QueryReport>,
) -> String {
    let results_json: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::Obj(vec![
                (
                    "ids".to_string(),
                    Json::Arr(r.top.iter().map(|&(_, id)| Json::Num(id)).collect()),
                ),
                (
                    "scores".to_string(),
                    Json::Arr(r.top.iter().map(|&(s, _)| Json::Float(s)).collect()),
                ),
            ])
        })
        .collect();
    let mut pairs = vec![
        ("request_id".to_string(), Json::Num(request_id)),
        ("backend".to_string(), Json::Str(backend.to_string())),
        ("generation".to_string(), Json::Num(generation)),
        ("results".to_string(), Json::Arr(results_json)),
    ];
    if let Some(rep) = report {
        pairs.push(("report".to_string(), report_json(rep)));
    }
    Json::Obj(pairs).render()
}

// ----------------------------------------------------------------- server

/// How a server follows a live-growing store. See the module docs'
/// "Live reload" section.
pub struct ReloadConfig {
    /// The store directory whose manifest generation is probed.
    pub dir: PathBuf,
    /// How often the reloader thread probes for a new generation.
    pub interval: Duration,
    /// Rebuild the serving valuator after the generation advanced. Runs
    /// on the reloader thread; an `Err` keeps the previous snapshot
    /// serving and counts in `logra_store_reload_errors_total`.
    pub rebuild: Box<dyn Fn() -> Result<Valuator, ValuationError> + Send + Sync>,
}

impl ReloadConfig {
    /// The standard rebuild recipe: reopen the store degraded (corrupt
    /// shards quarantined, not fatal), keep the backend/damping/worker
    /// choices from startup, and attach the long-lived shared scan pool
    /// so warm workers survive the swap.
    pub fn standard(
        dir: PathBuf,
        interval: Duration,
        backend: Backend,
        damping: f32,
        workers: usize,
        pool: Arc<ScanPool>,
        metrics: Arc<Metrics>,
    ) -> ReloadConfig {
        let store_dir = dir.clone();
        let rebuild = Box::new(move || {
            Valuator::open_degraded(&store_dir)?
                .backend(backend)
                .workers(workers)
                .fit_from_store(damping)
                .pool(PoolMode::Shared(pool.clone()))
                .metrics(metrics.clone())
                .build()
        });
        ReloadConfig { dir, interval, rebuild }
    }
}

/// A running `logra serve` instance. Dropping (or [`Server::stop`]) shuts
/// the accept loop down; in-flight connection threads notice on their
/// next read/write against a closed socket or idle timeout.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    reloader: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `cfg.addr` and start serving `valuator` (which should have
    /// been built with the same `metrics` handle — `/metrics` and
    /// `/query` reports read from it).
    pub fn start(
        valuator: Arc<Valuator>,
        metrics: Arc<Metrics>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        Self::start_with_reload(valuator, metrics, cfg, None)
    }

    /// [`Server::start`], optionally following a live-growing store:
    /// with a [`ReloadConfig`] a reloader thread probes the manifest
    /// generation and swaps in rebuilt snapshots as it advances.
    pub fn start_with_reload(
        valuator: Arc<Valuator>,
        metrics: Arc<Metrics>,
        cfg: ServeConfig,
        reload: Option<ReloadConfig>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            valuator: Slot::new(valuator),
            metrics,
            cfg,
            stats: ServeStats::default(),
            in_flight: AtomicUsize::new(0),
            next_request_id: AtomicU64::new(0),
        });
        let reloader = match reload {
            None => None,
            Some(r) => Some(spawn_reloader(shared.clone(), shutdown.clone(), r)?),
        };
        let flag = shutdown.clone();
        let accept = std::thread::Builder::new()
            .name("logra-serve-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let shared = shared.clone();
                    let _ = std::thread::Builder::new()
                        .name("logra-serve-conn".into())
                        .spawn(move || handle_conn(&shared, stream));
                }
            })?;
        Ok(Server { addr, shutdown, accept: Some(accept), reloader })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (it only exits on `stop`).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    fn shut(&mut self) {
        if let Some(h) = self.accept.take() {
            self.shutdown.store(true, Ordering::Release);
            // Wake the blocking accept with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
        if let Some(h) = self.reloader.take() {
            self.shutdown.store(true, Ordering::Release);
            let _ = h.join();
        }
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(mut self) {
        self.shut();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shut();
    }
}

/// The reloader thread: probe the store's manifest generation every
/// `cfg.interval` and, when it advances past the serving snapshot's,
/// rebuild and swap. Queries already pinned to the old snapshot finish
/// against it (the `Arc` keeps it alive); new admissions pin the new one.
/// Sleeps in short slices so shutdown stays responsive.
fn spawn_reloader(
    shared: Arc<Shared>,
    shutdown: Arc<AtomicBool>,
    cfg: ReloadConfig,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new().name("logra-serve-reload".into()).spawn(move || {
        let slice = Duration::from_millis(100);
        let mut next = Instant::now() + cfg.interval;
        while !shutdown.load(Ordering::Acquire) {
            let wait = next.saturating_duration_since(Instant::now());
            if !wait.is_zero() {
                std::thread::sleep(wait.min(slice));
                continue;
            }
            next = Instant::now() + cfg.interval;
            let serving = shared.valuator.load().generation();
            match current_generation(&cfg.dir) {
                // A generation BEHIND the serving one is not a reload
                // trigger: publishers only move forward, so it means the
                // probe raced a store rebuild — wait for it to finish.
                Ok(published) if published > serving => match (cfg.rebuild)() {
                    Ok(v) => {
                        shared.valuator.store(Arc::new(v));
                        shared.stats.reloads.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        shared.stats.reload_errors.fetch_add(1, Ordering::Relaxed);
                    }
                },
                Ok(_) => {}
                Err(_) => {
                    shared.stats.reload_errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    })
}

/// Per-connection idle read timeout — a keep-alive client that goes
/// silent for this long is dropped so connection threads don't pile up.
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Has the peer gone away? A non-blocking 1-byte peek distinguishes
/// "closed" (`Ok(0)` / hard error) from "quiet but alive" (`WouldBlock`)
/// and "pipelined bytes waiting" (`Ok(n)`).
fn peer_closed(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut buf = [0u8; 1];
    let closed = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// What one routed request resolves to.
enum Outcome {
    /// Write this response, keep serving the connection.
    Respond { status: u16, content_type: &'static str, body: String },
    /// The client vanished mid-query; there is nobody to answer.
    Disconnected,
}

fn respond(status: u16, body: String) -> Outcome {
    Outcome::Respond { status, content_type: "application/json", body }
}

fn handle_conn(shared: &Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(req)) => req,
            // Clean close between requests.
            Ok(None) => return,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Malformed framing: answer 400 once, then close.
                shared.stats.requests.fetch_add(1, Ordering::Relaxed);
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let body = error_body("bad_request", &format!("{e}"));
                let _ = http::write_response(
                    &mut writer,
                    400,
                    "application/json",
                    body.as_bytes(),
                    false,
                );
                return;
            }
            Err(_) => return,
        };
        shared.stats.requests.fetch_add(1, Ordering::Relaxed);
        let keep_alive = req.keep_alive();
        match route(shared, &req, &writer) {
            Outcome::Respond { status, content_type, body } => {
                if status >= 400 {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                }
                if http::write_response(
                    &mut writer,
                    status,
                    content_type,
                    body.as_bytes(),
                    keep_alive,
                )
                .is_err()
                {
                    return;
                }
            }
            Outcome::Disconnected => return,
        }
        if !keep_alive {
            return;
        }
    }
}

fn route(shared: &Arc<Shared>, req: &http::Request, stream: &TcpStream) -> Outcome {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(200, shared.render_healthz()),
        ("GET", "/metrics") => Outcome::Respond {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            body: shared.render_metrics(),
        },
        ("GET", "/debug/trace") => {
            respond(200, chrome_trace_json(&shared.metrics.obs.trace.events()))
        }
        ("POST", "/query") => handle_query(shared, req, stream),
        (_, "/healthz" | "/metrics" | "/debug/trace" | "/query") => respond(
            405,
            error_body("method_not_allowed", &format!("{} not allowed here", req.method)),
        ),
        (_, path) => respond(404, error_body("not_found", &format!("no route {path}"))),
    }
}

fn handle_query(shared: &Arc<Shared>, req: &http::Request, stream: &TcpStream) -> Outcome {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return respond(400, error_body("bad_request", "body is not UTF-8"));
    };
    let parsed = match parse_query_body(text, shared.cfg.default_topk) {
        Ok(p) => p,
        Err(msg) => return respond(400, error_body("bad_request", &msg)),
    };

    // Admission: reject fast instead of queueing — the client can retry,
    // and the scan pool's own queue stays reserved for admitted work.
    let Some(_guard) = shared.admit() else {
        shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
        return respond(
            429,
            error_body(
                "overloaded",
                &format!(
                    "{} queries already in flight (max_in_flight)",
                    shared.cfg.max_in_flight.max(1)
                ),
            ),
        );
    };
    shared.stats.queries.fetch_add(1, Ordering::Relaxed);
    let request_id = shared.next_request_id.fetch_add(1, Ordering::Relaxed) + 1;

    // Pin ONE snapshot for the whole query: admission, row lookup, scan,
    // and the response's generation all come from this Arc, so a reload
    // swapping the slot mid-flight can never mix two generations into
    // one answer.
    let valuator = shared.valuator.load();

    // Resolve which engine a per-request backend choice lands on BEFORE
    // building the query: an unservable choice is the caller's mistake
    // (400), and the 200 response names the engine that actually served
    // (after "auto" resolution), not the wire-level choice.
    let served = match valuator.resolved_kind(parsed.backend) {
        Ok(kind) => kind.name(),
        Err(ValuationError::InvalidConfig(m)) => {
            return respond(400, error_body("bad_request", &m))
        }
        Err(e) => return respond(500, error_body("internal", &format!("{e}"))),
    };

    let query = match parsed.body {
        QueryBody::Row(row) => match valuator.gradient_row(row as usize) {
            Some(g) => QueryRequest::gradients(g, 1, parsed.topk),
            None => {
                return respond(
                    400,
                    error_body(
                        "bad_request",
                        &format!(
                            "row {row} out of range (store has {} rows)",
                            valuator.rows()
                        ),
                    ),
                )
            }
        },
        QueryBody::Gradient { rows, nt } => QueryRequest::gradients(rows, nt, parsed.topk),
    };
    let query = match parsed.norm {
        Some(n) => query.with_norm(n),
        None => query,
    };
    let query = match parsed.backend {
        Some(b) => query.with_backend(b),
        None => query,
    };

    let deadline_ms = parsed.deadline_ms.unwrap_or(shared.cfg.default_deadline_ms);
    let deadline =
        (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));

    let pending = match valuator.query_async(query) {
        Ok(p) => p,
        Err(ValuationError::BadQuery(m) | ValuationError::InvalidConfig(m)) => {
            return respond(400, error_body("bad_request", &m))
        }
        Err(ValuationError::Shutdown) => {
            return respond(503, error_body("shutting_down", "backend is shut down"))
        }
        Err(e) => return respond(500, error_body("internal", &format!("{e}"))),
    };

    // Wait, re-checking disconnect + deadline each poll interval. A
    // cancellation makes the pool skip this query's unstarted shard tasks
    // (PoolSnapshot::tasks_cancelled).
    let disconnected = std::cell::Cell::new(false);
    let mut should_cancel = || {
        if peer_closed(stream) {
            disconnected.set(true);
            return true;
        }
        matches!(deadline, Some(d) if Instant::now() >= d)
    };
    match pending.wait_with_report_until(&mut should_cancel, shared.cfg.poll_interval) {
        Ok((results, report)) => respond(
            200,
            query_response_body(
                request_id,
                served,
                valuator.generation(),
                &results,
                report.as_ref(),
            ),
        ),
        Err(ValuationError::Cancelled { .. }) => {
            if disconnected.get() {
                shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
                Outcome::Disconnected
            } else {
                shared.stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
                respond(
                    504,
                    error_body(
                        "deadline_expired",
                        &format!("query exceeded its {deadline_ms} ms deadline"),
                    ),
                )
            }
        }
        Err(ValuationError::QueryPoisoned { query_id, message }) => respond(
            500,
            error_body("query_poisoned", &format!("query {query_id}: {message}")),
        ),
        Err(ValuationError::Shutdown) => {
            respond(503, error_body("shutting_down", "backend is shut down"))
        }
        Err(e) => respond(500, error_body("internal", &format!("{e}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_row_query_with_defaults() {
        let p = parse_query_body(r#"{"row": 3}"#, 7).unwrap();
        assert!(matches!(p.body, QueryBody::Row(3)));
        assert_eq!(p.topk, 7);
        assert!(p.norm.is_none());
        assert!(p.deadline_ms.is_none());
    }

    #[test]
    fn parses_gradient_query_with_overrides() {
        let p = parse_query_body(
            r#"{"gradient": [1.0, -2.5, 3, 4.0], "nt": 2, "topk": 9,
               "norm": "relatif", "deadline_ms": 250}"#,
            5,
        )
        .unwrap();
        match p.body {
            QueryBody::Gradient { rows, nt } => {
                assert_eq!(rows, vec![1.0, -2.5, 3.0, 4.0]);
                assert_eq!(nt, 2);
            }
            _ => panic!("expected gradient body"),
        }
        assert_eq!(p.topk, 9);
        assert_eq!(p.norm, Some(Normalization::RelatIf));
        assert_eq!(p.deadline_ms, Some(250));
        assert!(p.backend.is_none());
    }

    #[test]
    fn parses_backend_and_nprobe_overrides() {
        let p = parse_query_body(r#"{"row": 1, "backend": "exact"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Exact));
        let p = parse_query_body(r#"{"row": 1, "backend": "quantized"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Quantized));
        let p = parse_query_body(r#"{"row": 1, "backend": "auto"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Auto));
        let p = parse_query_body(r#"{"row": 1, "backend": "ann"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Ann { nprobe: None }));
        let p = parse_query_body(r#"{"row": 1, "backend": "ann", "nprobe": 3}"#, 5)
            .unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Ann { nprobe: Some(3) }));
    }

    #[test]
    fn rejects_bad_backend_and_stray_nprobe() {
        for bad in [
            r#"{"row": 1, "backend": "bogus"}"#,
            r#"{"row": 1, "backend": 7}"#,
            r#"{"row": 1, "nprobe": 4}"#,
            r#"{"row": 1, "backend": "exact", "nprobe": 4}"#,
            r#"{"row": 1, "backend": "ann", "nprobe": 0}"#,
            r#"{"row": 1, "backend": "ann", "nprobe": "many"}"#,
        ] {
            assert!(parse_query_body(bad, 5).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_malformed_query_bodies() {
        for bad in [
            "not json",
            "[1,2]",
            "{}",
            r#"{"row": 1, "gradient": [1.0]}"#,
            r#"{"row": -1}"#,
            r#"{"row": 1, "topk": 0}"#,
            r#"{"gradient": ["x"]}"#,
            r#"{"gradient": [1.0], "nt": 0}"#,
            r#"{"row": 1, "norm": "weird"}"#,
            r#"{"row": 1, "deadline_ms": "soon"}"#,
        ] {
            assert!(parse_query_body(bad, 5).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn error_body_escapes_messages() {
        let body = error_body("bad_request", "quote\" and\nnewline");
        let v = json::parse(&body).unwrap();
        let err = v.get("error").unwrap();
        assert_eq!(err.get("code").and_then(Json::as_str), Some("bad_request"));
        assert_eq!(
            err.get("message").and_then(Json::as_str),
            Some("quote\" and\nnewline")
        );
    }

    #[test]
    fn query_response_roundtrips_scores_bit_exact() {
        let results = vec![QueryResult {
            top: vec![(0.12345678901234567, 42), (-3.5e-5, 7)],
        }];
        let body = query_response_body(9, "parallel-f32", 3, &results, None);
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("request_id").and_then(Json::as_u64), Some(9));
        assert_eq!(v.get("backend").and_then(Json::as_str), Some("parallel-f32"));
        assert_eq!(v.get("generation").and_then(Json::as_u64), Some(3));
        let r0 = &v.get("results").and_then(Json::as_arr).unwrap()[0];
        let ids: Vec<u64> = r0
            .get("ids")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap())
            .collect();
        assert_eq!(ids, vec![42, 7]);
        let scores: Vec<f64> = r0
            .get("scores")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        assert_eq!(scores[0].to_bits(), 0.12345678901234567f64.to_bits());
        assert_eq!(scores[1].to_bits(), (-3.5e-5f64).to_bits());
    }
}
