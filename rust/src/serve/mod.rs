//! `logra serve` — the observability-first valuation server.
//!
//! A threaded HTTP/1.1 server (hand-rolled framing over
//! `std::net::TcpListener`, no new dependencies — see [`http`]) over the
//! [`Valuator`] facade. One accept thread, one thread per connection
//! (keep-alive), one shared `Arc<Metrics>`:
//!
//! - `POST /query` — JSON body `{"row": N}` or
//!   `{"gradient": [...], "nt": 1}`, optional per-request `"topk"`,
//!   `"norm"` (`"none"`/`"relatif"`), `"deadline_ms"`, and `"backend"`
//!   (`"auto"`/`"exact"`/`"quantized"`/`"ann"`, plus `"nprobe"` with
//!   `"ann"`) — a backend the fabric cannot serve is a 400. The response
//!   carries ids + scores (floats rendered shortest-roundtrip, so they
//!   re-parse bit-identical), a server-wide `request_id`, the name of the
//!   backend that ACTUALLY served (after `auto` resolution), and the full
//!   [`QueryReport`] stage breakdown.
//! - `GET /metrics` — [`render_exposition`] verbatim (counters, pool
//!   snapshot, histograms) plus the server's own `logra_serve_*`
//!   families, from the one shared `Arc<Metrics>`.
//! - `GET /healthz` — store / backend / pool liveness as JSON.
//! - `GET /debug/trace` — the [`TraceRing`](crate::obs::TraceRing) as
//!   Chrome trace-event JSON ([`chrome_trace_json`]).
//!
//! # Admission control, deadlines, cancellation
//!
//! At most [`ServeConfig::max_in_flight`] queries run at once; excess
//! `POST /query` requests are rejected immediately with a 429 JSON error
//! (no queueing — the caller retries, the scan pool never sees the
//! query). While a query is in flight the handler waits through
//! [`PendingScores::wait_with_report_until`], re-checking every
//! [`ServeConfig::poll_interval`]:
//!
//! - **deadline** (per-request `deadline_ms`, default
//!   [`ServeConfig::default_deadline_ms`]; 0 = none) → the wait cancels,
//!   the pool skips the query's unstarted shard tasks (the
//!   `tasks_cancelled` pool counter), and the client gets a 504.
//! - **client disconnect** (detected with a non-blocking `peek` on the
//!   connection) → same cancellation, no response (nobody is listening),
//!   counted in `logra_serve_disconnects_total`.
//!
//! Cancellation needs a pool-backed backend (a sharded f32 or quantized
//! fabric): the sequential engine scans eagerly at admission, so there is
//! nothing left to cancel by the time the handler waits.
//!
//! # Live reload (generation-snapshotted serving)
//!
//! With a [`ReloadConfig`] ([`Server::start_with_reload`], or
//! `logra serve --reload-ms N`), the server follows a live-growing store:
//! a reloader thread re-reads the manifest generation every `interval`
//! and, when it advances, rebuilds the valuator (via the config's
//! `rebuild` closure — normally [`Valuator::open_degraded`], so a shard
//! failing validation is quarantined rather than fatal) and swaps it into
//! the shared [`Slot`]. Every query pins one snapshot at admission and
//! serves entirely from it: responses carry the `generation` they were
//! answered under, and no response ever blends shards from two
//! generations. A failed rebuild leaves the previous snapshot serving and
//! increments `logra_store_reload_errors_total`; `/healthz` and
//! `/metrics` expose the live generation, quarantined-shard count, and
//! IVF fallback-shard count.
//!
//! # Session serving (`logra serve --session`)
//!
//! [`Server::start_session`] fronts a multi-stage
//! [`Session`](crate::session::Session) instead of one store: `POST
//! /query` fans out to every stage (or a per-request `"stages": [...]`
//! subset) over the session's ONE shared scan pool and answers with the
//! combined ranking in the usual top-level `results` array plus a
//! per-stage `stages` breakdown (name, weight, generation, backend, ids
//! + scores, `QueryReport`) and a `stage_errors` count — a stage failing
//! mid-query degrades to an `error` entry for that stage while the
//! others still answer. Each stage is pinned to its OWN generation
//! snapshot at admission and reloaded independently; `/healthz` reports
//! the per-stage `{name, generation, quarantined_shards}` array and
//! `/metrics` adds the stage-labeled `logra_session_stage_*` families.

pub mod http;
pub mod loadgen;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::Metrics;
use crate::obs::export::{pool_families, simple};
use crate::obs::{
    chrome_trace_json, render_exposition, render_session_exposition, QueryReport, SpanEvent,
    StageMetrics,
};
use crate::session::{build_stage_valuator, combine_rankings, Combine, Session, StageReport,
    StageSpec};
use crate::store::{current_generation, Slot};
use crate::util::json::{self, Json};
use crate::valuation::{
    Backend, BackendChoice, Normalization, PendingScores, PoolMode, QueryRequest, QueryResult,
    ScanBackend, ScanPool, ValuationError, Valuator,
};

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests).
    pub addr: String,
    /// Queries allowed in flight at once; excess is rejected with 429.
    pub max_in_flight: usize,
    /// Default per-query deadline in ms (0 = none); any request can
    /// override with `"deadline_ms"`.
    pub default_deadline_ms: u64,
    /// `topk` when the request omits it.
    pub default_topk: usize,
    /// How often an in-flight query re-checks deadline + disconnect.
    pub poll_interval: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7878".into(),
            max_in_flight: 8,
            default_deadline_ms: 0,
            default_topk: 5,
            poll_interval: Duration::from_millis(15),
        }
    }
}

/// Server-side counters, exported as `logra_serve_*` families on
/// `/metrics` alongside the shared [`Metrics`] exposition.
#[derive(Default)]
struct ServeStats {
    /// HTTP requests handled (all endpoints, all statuses).
    requests: AtomicU64,
    /// `POST /query` requests admitted past the in-flight gate.
    queries: AtomicU64,
    /// Queries rejected at admission (`max_in_flight` exceeded).
    rejected: AtomicU64,
    /// Queries cancelled by deadline expiry.
    deadline_expired: AtomicU64,
    /// Queries cancelled because the client disconnected mid-flight.
    disconnects: AtomicU64,
    /// Requests answered with a 4xx/5xx status.
    errors: AtomicU64,
    /// Successful manifest reloads (valuator snapshot swaps).
    reloads: AtomicU64,
    /// Reload attempts that failed (previous snapshot kept serving).
    reload_errors: AtomicU64,
}

/// One stage of a session server: the stage's manifest spec plus its own
/// reloadable snapshot slot and metrics instance. Each stage is pinned
/// and reloaded INDEPENDENTLY — one stage's store growing never blurs
/// another stage's generation.
struct ServeStage {
    spec: StageSpec,
    /// Resolved store directory the per-stage reloader probes.
    store_dir: PathBuf,
    slot: Slot<Valuator>,
    metrics: Arc<Metrics>,
}

/// A session server's serving state: the manifest's stages over ONE
/// shared scan pool (owned here; stage valuators attach via
/// `PoolMode::Shared`).
struct SessionServing {
    combine: Combine,
    pool: Arc<ScanPool>,
    stages: Vec<ServeStage>,
}

impl SessionServing {
    fn stage_named(&self, name: &str) -> Option<&ServeStage> {
        self.stages.iter().find(|st| st.spec.name == name)
    }
}

/// What this server fronts: one store, or a multi-stage session.
enum Serving {
    Single {
        /// The serving snapshot. Queries pin one `Arc<Valuator>` at
        /// admission and never observe a mid-flight swap; the reloader
        /// thread publishes new generations here.
        valuator: Slot<Valuator>,
    },
    Session(SessionServing),
}

struct Shared {
    serving: Serving,
    /// Single mode: the one Metrics instance the valuator records into.
    /// Session mode: unused placeholder — each stage carries its own.
    metrics: Arc<Metrics>,
    cfg: ServeConfig,
    stats: ServeStats,
    in_flight: AtomicUsize,
    next_request_id: AtomicU64,
}

/// RAII decrement for the admission gate.
struct InFlightGuard<'a>(&'a Shared);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Shared {
    /// Claim an in-flight slot, or `None` when the server is saturated.
    fn admit(&self) -> Option<InFlightGuard<'_>> {
        let limit = self.cfg.max_in_flight.max(1);
        let mut cur = self.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= limit {
                return None;
            }
            match self.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(InFlightGuard(self)),
                Err(now) => cur = now,
            }
        }
    }

    /// The single-store slot. Only reachable from code paths that already
    /// branched on [`Serving`]; a session server never calls this.
    fn single_slot(&self) -> &Slot<Valuator> {
        match &self.serving {
            Serving::Single { valuator } => valuator,
            Serving::Session(_) => unreachable!("single-store slot on a session server"),
        }
    }

    /// The `logra_store_reload*_` + `logra_serve_*` families shared by
    /// both serving modes.
    fn serve_families(&self, out: &mut String) {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed) as f64;
        simple(
            out,
            "logra_store_reloads_total",
            "Successful manifest reloads (snapshot swaps).",
            "counter",
            ld(&self.stats.reloads),
        );
        simple(
            out,
            "logra_store_reload_errors_total",
            "Reload attempts that failed; the previous snapshot kept serving.",
            "counter",
            ld(&self.stats.reload_errors),
        );
        simple(
            out,
            "logra_serve_requests_total",
            "HTTP requests handled by logra serve (all endpoints).",
            "counter",
            ld(&self.stats.requests),
        );
        simple(
            out,
            "logra_serve_queries_total",
            "POST /query requests admitted past the in-flight gate.",
            "counter",
            ld(&self.stats.queries),
        );
        simple(
            out,
            "logra_serve_rejected_total",
            "Queries rejected at admission (max_in_flight exceeded).",
            "counter",
            ld(&self.stats.rejected),
        );
        simple(
            out,
            "logra_serve_deadline_expired_total",
            "Queries cancelled by per-request deadline expiry.",
            "counter",
            ld(&self.stats.deadline_expired),
        );
        simple(
            out,
            "logra_serve_disconnects_total",
            "Queries cancelled because the client disconnected mid-flight.",
            "counter",
            ld(&self.stats.disconnects),
        );
        simple(
            out,
            "logra_serve_errors_total",
            "Requests answered with a 4xx/5xx status.",
            "counter",
            ld(&self.stats.errors),
        );
        simple(
            out,
            "logra_serve_in_flight",
            "Queries currently inside the admission gate.",
            "gauge",
            self.in_flight.load(Ordering::Relaxed) as f64,
        );
        simple(
            out,
            "logra_serve_max_in_flight",
            "Admission gate capacity.",
            "gauge",
            self.cfg.max_in_flight.max(1) as f64,
        );
    }

    /// `/metrics`: the shared exposition plus the `logra_serve_*` families
    /// — and, on a session server, the `logra_session_stage_*` families
    /// (each stage's OWN `Metrics` instance, labeled by stage name).
    fn render_metrics(&self) -> String {
        match &self.serving {
            Serving::Single { valuator } => {
                let valuator = valuator.load();
                let pool = valuator.scan_pool().map(|p| p.snapshot());
                let mut out = render_exposition(
                    &self.metrics,
                    pool.as_ref(),
                    &[
                        (
                            "logra_store_rows",
                            "Rows in the served store fabric.",
                            valuator.rows() as f64,
                        ),
                        (
                            "logra_store_k",
                            "Projected gradient dimension.",
                            valuator.k() as f64,
                        ),
                    ],
                );
                simple(
                    &mut out,
                    "logra_store_generation",
                    "Manifest generation of the serving snapshot.",
                    "gauge",
                    valuator.generation() as f64,
                );
                simple(
                    &mut out,
                    "logra_store_quarantined_shards",
                    "Shards that failed validation at reload and were quarantined.",
                    "gauge",
                    valuator.quarantined().len() as f64,
                );
                simple(
                    &mut out,
                    "logra_store_ivf_fallback_shards",
                    "Shards the IVF engine serves via dense fallback (no index sidecar).",
                    "gauge",
                    valuator.ivf_fallback_shards() as f64,
                );
                self.serve_families(&mut out);
                out
            }
            Serving::Session(sess) => {
                let mut out = String::with_capacity(4096);
                simple(
                    &mut out,
                    "logra_session_stages",
                    "Stages served by this session.",
                    "gauge",
                    sess.stages.len() as f64,
                );
                simple(
                    &mut out,
                    "logra_pool_workers",
                    "Scan-pool workers of the ONE session-shared pool.",
                    "gauge",
                    sess.pool.workers() as f64,
                );
                pool_families(&mut out, &sess.pool.snapshot());
                self.serve_families(&mut out);
                let pinned: Vec<(Arc<Valuator>, &ServeStage)> =
                    sess.stages.iter().map(|st| (st.slot.load(), st)).collect();
                let stage_metrics: Vec<StageMetrics<'_>> = pinned
                    .iter()
                    .map(|(v, st)| StageMetrics {
                        stage: &st.spec.name,
                        metrics: &*st.metrics,
                        generation: v.generation(),
                        quarantined_shards: v.quarantined().len(),
                    })
                    .collect();
                render_session_exposition(&mut out, &stage_metrics);
                out
            }
        }
    }

    /// `/healthz`: store / backend / pool liveness (the JSON subset has
    /// no booleans, so liveness is `"status": "ok"` plus numbers). A
    /// session server reports a per-stage array — each stage's own name,
    /// generation, and quarantine state — instead of a single store's.
    fn render_healthz(&self) -> String {
        let valuator = match &self.serving {
            Serving::Single { valuator } => valuator.load(),
            Serving::Session(sess) => return self.render_session_healthz(sess),
        };
        let mut pairs = vec![
            ("status".to_string(), Json::Str("ok".into())),
            ("backend".to_string(), Json::Str(valuator.kind().name().into())),
            ("rows".to_string(), Json::Num(valuator.rows() as u64)),
            ("k".to_string(), Json::Num(valuator.k() as u64)),
            ("workers".to_string(), Json::Num(valuator.workers() as u64)),
            ("generation".to_string(), Json::Num(valuator.generation())),
            (
                "quarantined_shards".to_string(),
                Json::Num(valuator.quarantined().len() as u64),
            ),
            (
                "ivf_fallback_shards".to_string(),
                Json::Num(valuator.ivf_fallback_shards() as u64),
            ),
            (
                "reloads".to_string(),
                Json::Num(self.stats.reloads.load(Ordering::Relaxed)),
            ),
            (
                "reload_errors".to_string(),
                Json::Num(self.stats.reload_errors.load(Ordering::Relaxed)),
            ),
            (
                "in_flight".to_string(),
                Json::Num(self.in_flight.load(Ordering::Relaxed) as u64),
            ),
            (
                "max_in_flight".to_string(),
                Json::Num(self.cfg.max_in_flight.max(1) as u64),
            ),
        ];
        if let Some(p) = valuator.scan_pool() {
            let s = p.snapshot();
            pairs.push((
                "pool".to_string(),
                Json::Obj(vec![
                    ("workers".to_string(), Json::Num(s.workers as u64)),
                    ("in_flight".to_string(), Json::Num(s.in_flight as u64)),
                    ("queue_depth".to_string(), Json::Num(s.queue_depth as u64)),
                    ("tasks_completed".to_string(), Json::Num(s.tasks_completed)),
                    ("tasks_cancelled".to_string(), Json::Num(s.tasks_cancelled)),
                ]),
            ));
        }
        Json::Obj(pairs).render()
    }

    /// Session `/healthz`: the per-stage `{name, generation,
    /// quarantined_shards, ...}` array plus the shared-pool snapshot.
    fn render_session_healthz(&self, sess: &SessionServing) -> String {
        let stages_json: Vec<Json> = sess
            .stages
            .iter()
            .map(|st| {
                let v = st.slot.load();
                Json::Obj(vec![
                    ("name".to_string(), Json::Str(st.spec.name.clone())),
                    ("backend".to_string(), Json::Str(v.kind().name().into())),
                    ("rows".to_string(), Json::Num(v.rows() as u64)),
                    ("generation".to_string(), Json::Num(v.generation())),
                    (
                        "quarantined_shards".to_string(),
                        Json::Num(v.quarantined().len() as u64),
                    ),
                    (
                        "ivf_fallback_shards".to_string(),
                        Json::Num(v.ivf_fallback_shards() as u64),
                    ),
                ])
            })
            .collect();
        let s = sess.pool.snapshot();
        // Top-level "rows" mirrors the first stage — the session's
        // reference row space for `{"row": N}` queries — so loadgen's
        // row-cycling works unchanged against a session server.
        let rows0 = sess.stages.first().map_or(0, |st| st.slot.load().rows() as u64);
        Json::Obj(vec![
            ("status".to_string(), Json::Str("ok".into())),
            ("combine".to_string(), Json::Str(sess.combine.name().into())),
            ("rows".to_string(), Json::Num(rows0)),
            ("workers".to_string(), Json::Num(s.workers as u64)),
            ("stages".to_string(), Json::Arr(stages_json)),
            (
                "reloads".to_string(),
                Json::Num(self.stats.reloads.load(Ordering::Relaxed)),
            ),
            (
                "reload_errors".to_string(),
                Json::Num(self.stats.reload_errors.load(Ordering::Relaxed)),
            ),
            (
                "in_flight".to_string(),
                Json::Num(self.in_flight.load(Ordering::Relaxed) as u64),
            ),
            (
                "max_in_flight".to_string(),
                Json::Num(self.cfg.max_in_flight.max(1) as u64),
            ),
            (
                "pool".to_string(),
                Json::Obj(vec![
                    ("workers".to_string(), Json::Num(s.workers as u64)),
                    ("in_flight".to_string(), Json::Num(s.in_flight as u64)),
                    ("queue_depth".to_string(), Json::Num(s.queue_depth as u64)),
                    ("tasks_completed".to_string(), Json::Num(s.tasks_completed)),
                    ("tasks_cancelled".to_string(), Json::Num(s.tasks_cancelled)),
                ]),
            ),
        ])
        .render()
    }
}

// ------------------------------------------------------------ query bodies

/// Query input: a stored row index, or inline gradient rows.
pub(crate) enum QueryBody {
    Row(u64),
    Gradient { rows: Vec<f32>, nt: usize },
}

/// A parsed `POST /query` body.
pub(crate) struct ParsedQuery {
    pub(crate) body: QueryBody,
    pub(crate) topk: usize,
    pub(crate) norm: Option<Normalization>,
    pub(crate) deadline_ms: Option<u64>,
    pub(crate) backend: Option<BackendChoice>,
    /// Session servers only: restrict the fan-out to these stage names.
    pub(crate) stages: Option<Vec<String>>,
}

/// Parse a query body against the server defaults. Errors are
/// caller-facing strings (they become 400 JSON errors).
pub(crate) fn parse_query_body(
    text: &str,
    default_topk: usize,
) -> Result<ParsedQuery, String> {
    let v = json::parse(text).map_err(|e| format!("invalid JSON body: {e:#}"))?;
    if !matches!(v, Json::Obj(_)) {
        return Err("query body must be a JSON object".into());
    }
    let topk = match v.get("topk") {
        None => default_topk,
        Some(t) => t
            .as_u64()
            .filter(|&t| t > 0)
            .ok_or("\"topk\" must be a positive integer")? as usize,
    };
    let norm = match v.get("norm") {
        None => None,
        Some(n) => {
            let s = n.as_str().ok_or("\"norm\" must be \"none\" or \"relatif\"")?;
            Some(Normalization::parse(s).map_err(|e| format!("{e:#}"))?)
        }
    };
    let deadline_ms = match v.get("deadline_ms") {
        None => None,
        Some(d) => {
            Some(d.as_u64().ok_or("\"deadline_ms\" must be a non-negative integer")?)
        }
    };
    let backend = match v.get("backend") {
        None => None,
        Some(b) => {
            let s = b.as_str().ok_or(
                "\"backend\" must be \"auto\", \"exact\", \"quantized\", or \"ann\"",
            )?;
            Some(BackendChoice::parse(s).ok_or(
                "\"backend\" must be \"auto\", \"exact\", \"quantized\", or \"ann\"",
            )?)
        }
    };
    let backend = match v.get("nprobe") {
        None => backend,
        Some(n) => {
            let n = n
                .as_u64()
                .filter(|&n| n > 0)
                .ok_or("\"nprobe\" must be a positive integer")? as usize;
            match backend {
                Some(BackendChoice::Ann { .. }) => {
                    Some(BackendChoice::Ann { nprobe: Some(n) })
                }
                _ => return Err("\"nprobe\" requires \"backend\": \"ann\"".into()),
            }
        }
    };
    let stages = match v.get("stages") {
        None => None,
        Some(s) => {
            let arr = s.as_arr().ok_or("\"stages\" must be an array of stage names")?;
            if arr.is_empty() {
                return Err("\"stages\" must name at least one stage".into());
            }
            let names: Vec<String> = arr
                .iter()
                .map(|x| x.as_str().map(str::to_string))
                .collect::<Option<_>>()
                .ok_or("\"stages\" must be an array of stage names")?;
            Some(names)
        }
    };
    let body = match (v.get("row"), v.get("gradient")) {
        (Some(_), Some(_)) => {
            return Err("pass either \"row\" or \"gradient\", not both".into())
        }
        (Some(r), None) => {
            QueryBody::Row(r.as_u64().ok_or("\"row\" must be a non-negative integer")?)
        }
        (None, Some(g)) => {
            let arr = g.as_arr().ok_or("\"gradient\" must be an array of numbers")?;
            let rows: Vec<f32> = arr
                .iter()
                .map(|x| x.as_f64().map(|f| f as f32))
                .collect::<Option<_>>()
                .ok_or("\"gradient\" must be an array of numbers")?;
            let nt = match v.get("nt") {
                None => 1,
                Some(n) => n
                    .as_u64()
                    .filter(|&n| n > 0)
                    .ok_or("\"nt\" must be a positive integer")? as usize,
            };
            QueryBody::Gradient { rows, nt }
        }
        (None, None) => return Err("query body needs \"row\" or \"gradient\"".into()),
    };
    Ok(ParsedQuery { body, topk, norm, deadline_ms, backend, stages })
}

// -------------------------------------------------------------- responses

/// `{"error":{"code":...,"message":...}}` through the shared escape-safe
/// JSON writer.
fn error_body(code: &str, message: &str) -> String {
    Json::Obj(vec![(
        "error".to_string(),
        Json::Obj(vec![
            ("code".to_string(), Json::Str(code.to_string())),
            ("message".to_string(), Json::Str(message.to_string())),
        ]),
    )])
    .render()
}

fn report_json(rep: &QueryReport) -> Json {
    Json::Obj(vec![
        ("query_id".to_string(), Json::Num(rep.query_id)),
        ("backend".to_string(), Json::Str(rep.backend.to_string())),
        ("shards".to_string(), Json::Num(rep.shards as u64)),
        ("rows_scanned".to_string(), Json::Num(rep.rows_scanned)),
        ("candidates_rescored".to_string(), Json::Num(rep.candidates_rescored)),
        ("admission_nanos".to_string(), Json::Num(rep.admission_nanos)),
        ("queue_wait_nanos".to_string(), Json::Num(rep.queue_wait_nanos)),
        ("scan_nanos".to_string(), Json::Num(rep.scan_nanos)),
        ("merge_nanos".to_string(), Json::Num(rep.merge_nanos)),
        ("rescore_nanos".to_string(), Json::Num(rep.rescore_nanos)),
        ("total_nanos".to_string(), Json::Num(rep.total_nanos)),
        (
            "workers".to_string(),
            Json::Arr(rep.workers.iter().map(|&w| Json::Num(w as u64)).collect()),
        ),
    ])
}

/// Per-test-row `{ids, scores}` objects. Scores go through
/// [`Json::Float`]'s shortest-roundtrip rendering, so a client parsing
/// them back recovers the exact bits `Valuator::query` produced.
fn results_json(results: &[QueryResult]) -> Json {
    Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::Obj(vec![
                    (
                        "ids".to_string(),
                        Json::Arr(r.top.iter().map(|&(_, id)| Json::Num(id)).collect()),
                    ),
                    (
                        "scores".to_string(),
                        Json::Arr(r.top.iter().map(|&(s, _)| Json::Float(s)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

/// The `POST /query` 200 body (single-store mode).
fn query_response_body(
    request_id: u64,
    backend: &str,
    generation: u64,
    results: &[QueryResult],
    report: Option<&QueryReport>,
) -> String {
    let mut pairs = vec![
        ("request_id".to_string(), Json::Num(request_id)),
        ("backend".to_string(), Json::Str(backend.to_string())),
        ("generation".to_string(), Json::Num(generation)),
        ("results".to_string(), results_json(results)),
    ];
    if let Some(rep) = report {
        pairs.push(("report".to_string(), report_json(rep)));
    }
    Json::Obj(pairs).render()
}

// ----------------------------------------------------------------- server

/// How a server follows a live-growing store. See the module docs'
/// "Live reload" section.
pub struct ReloadConfig {
    /// The store directory whose manifest generation is probed.
    pub dir: PathBuf,
    /// How often the reloader thread probes for a new generation.
    pub interval: Duration,
    /// Rebuild the serving valuator after the generation advanced. Runs
    /// on the reloader thread; an `Err` keeps the previous snapshot
    /// serving and counts in `logra_store_reload_errors_total`.
    pub rebuild: Box<dyn Fn() -> Result<Valuator, ValuationError> + Send + Sync>,
}

impl ReloadConfig {
    /// The standard rebuild recipe: reopen the store degraded (corrupt
    /// shards quarantined, not fatal), keep the backend/damping/worker
    /// choices from startup, and attach the long-lived shared scan pool
    /// so warm workers survive the swap.
    pub fn standard(
        dir: PathBuf,
        interval: Duration,
        backend: Backend,
        damping: f32,
        workers: usize,
        pool: Arc<ScanPool>,
        metrics: Arc<Metrics>,
    ) -> ReloadConfig {
        let store_dir = dir.clone();
        let rebuild = Box::new(move || {
            Valuator::open_degraded(&store_dir)?
                .backend(backend)
                .workers(workers)
                .fit_from_store(damping)
                .pool(PoolMode::Shared(pool.clone()))
                .metrics(metrics.clone())
                .build()
        });
        ReloadConfig { dir, interval, rebuild }
    }
}

/// A running `logra serve` instance. Dropping (or [`Server::stop`]) shuts
/// the accept loop down; in-flight connection threads notice on their
/// next read/write against a closed socket or idle timeout.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    reloader: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `cfg.addr` and start serving `valuator` (which should have
    /// been built with the same `metrics` handle — `/metrics` and
    /// `/query` reports read from it).
    pub fn start(
        valuator: Arc<Valuator>,
        metrics: Arc<Metrics>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        Self::start_with_reload(valuator, metrics, cfg, None)
    }

    /// [`Server::start`], optionally following a live-growing store:
    /// with a [`ReloadConfig`] a reloader thread probes the manifest
    /// generation and swaps in rebuilt snapshots as it advances.
    pub fn start_with_reload(
        valuator: Arc<Valuator>,
        metrics: Arc<Metrics>,
        cfg: ServeConfig,
        reload: Option<ReloadConfig>,
    ) -> Result<Server> {
        Self::launch(Serving::Single { valuator: Slot::new(valuator) }, metrics, cfg, reload, None)
    }

    /// Serve a multi-stage [`Session`]: `POST /query` fans out to every
    /// stage (or a per-request `"stages"` subset) over the session's ONE
    /// shared pool and answers with per-stage + combined scores. With
    /// `reload_interval`, a reloader thread probes EVERY stage's store
    /// generation and swaps rebuilt snapshots per stage — each query pins
    /// each selected stage's snapshot at admission, so no answer ever
    /// blends two generations of one stage.
    pub fn start_session(
        session: Session,
        cfg: ServeConfig,
        reload_interval: Option<Duration>,
    ) -> Result<Server> {
        let (stages, pool, combine) = session.into_parts();
        let stages: Vec<ServeStage> = stages
            .into_iter()
            .map(|st| {
                let (spec, store_dir, valuator, metrics) = st.into_parts();
                ServeStage { spec, store_dir, slot: Slot::new(valuator), metrics }
            })
            .collect();
        Self::launch(
            Serving::Session(SessionServing { combine, pool, stages }),
            Arc::new(Metrics::default()),
            cfg,
            None,
            reload_interval,
        )
    }

    fn launch(
        serving: Serving,
        metrics: Arc<Metrics>,
        cfg: ServeConfig,
        reload: Option<ReloadConfig>,
        session_reload_interval: Option<Duration>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(Shared {
            serving,
            metrics,
            cfg,
            stats: ServeStats::default(),
            in_flight: AtomicUsize::new(0),
            next_request_id: AtomicU64::new(0),
        });
        let reloader = match (reload, session_reload_interval) {
            (Some(r), _) => Some(spawn_reloader(shared.clone(), shutdown.clone(), r)?),
            (None, Some(interval)) => {
                Some(spawn_session_reloader(shared.clone(), shutdown.clone(), interval)?)
            }
            (None, None) => None,
        };
        let flag = shutdown.clone();
        let accept = std::thread::Builder::new()
            .name("logra-serve-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    let shared = shared.clone();
                    let _ = std::thread::Builder::new()
                        .name("logra-serve-conn".into())
                        .spawn(move || handle_conn(&shared, stream));
                }
            })?;
        Ok(Server { addr, shutdown, accept: Some(accept), reloader })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (it only exits on `stop`).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    fn shut(&mut self) {
        if let Some(h) = self.accept.take() {
            self.shutdown.store(true, Ordering::Release);
            // Wake the blocking accept with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
        if let Some(h) = self.reloader.take() {
            self.shutdown.store(true, Ordering::Release);
            let _ = h.join();
        }
    }

    /// Stop accepting and join the accept thread.
    pub fn stop(mut self) {
        self.shut();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shut();
    }
}

/// The reloader thread: probe the store's manifest generation every
/// `cfg.interval` and, when it advances past the serving snapshot's,
/// rebuild and swap. Queries already pinned to the old snapshot finish
/// against it (the `Arc` keeps it alive); new admissions pin the new one.
/// Sleeps in short slices so shutdown stays responsive.
fn spawn_reloader(
    shared: Arc<Shared>,
    shutdown: Arc<AtomicBool>,
    cfg: ReloadConfig,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new().name("logra-serve-reload".into()).spawn(move || {
        let slice = Duration::from_millis(100);
        let mut next = Instant::now() + cfg.interval;
        while !shutdown.load(Ordering::Acquire) {
            let wait = next.saturating_duration_since(Instant::now());
            if !wait.is_zero() {
                std::thread::sleep(wait.min(slice));
                continue;
            }
            next = Instant::now() + cfg.interval;
            let serving = shared.single_slot().load().generation();
            match current_generation(&cfg.dir) {
                // A generation BEHIND the serving one is not a reload
                // trigger: publishers only move forward, so it means the
                // probe raced a store rebuild — wait for it to finish.
                Ok(published) if published > serving => match (cfg.rebuild)() {
                    Ok(v) => {
                        shared.single_slot().store(Arc::new(v));
                        shared.stats.reloads.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        shared.stats.reload_errors.fetch_add(1, Ordering::Relaxed);
                    }
                },
                Ok(_) => {}
                Err(_) => {
                    shared.stats.reload_errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    })
}

/// The session reloader: one thread probing EVERY stage's store
/// generation, rebuilding stages independently with the same recipe the
/// session was opened with ([`build_stage_valuator`] — same spec, same
/// shared pool, same per-stage metrics). A failed stage rebuild keeps
/// that stage's previous snapshot serving; the other stages are
/// unaffected either way.
fn spawn_session_reloader(
    shared: Arc<Shared>,
    shutdown: Arc<AtomicBool>,
    interval: Duration,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new().name("logra-serve-reload".into()).spawn(move || {
        let slice = Duration::from_millis(100);
        let mut next = Instant::now() + interval;
        while !shutdown.load(Ordering::Acquire) {
            let wait = next.saturating_duration_since(Instant::now());
            if !wait.is_zero() {
                std::thread::sleep(wait.min(slice));
                continue;
            }
            next = Instant::now() + interval;
            let Serving::Session(sess) = &shared.serving else { return };
            for st in &sess.stages {
                let serving = st.slot.load().generation();
                match current_generation(&st.store_dir) {
                    Ok(published) if published > serving => {
                        match build_stage_valuator(
                            &st.spec,
                            &st.store_dir,
                            &sess.pool,
                            sess.pool.workers(),
                            &st.metrics,
                        ) {
                            Ok(v) => {
                                st.slot.store(Arc::new(v));
                                shared.stats.reloads.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(_) => {
                                shared.stats.reload_errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Ok(_) => {}
                    Err(_) => {
                        shared.stats.reload_errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    })
}

/// Per-connection idle read timeout — a keep-alive client that goes
/// silent for this long is dropped so connection threads don't pile up.
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Has the peer gone away? A non-blocking 1-byte peek distinguishes
/// "closed" (`Ok(0)` / hard error) from "quiet but alive" (`WouldBlock`)
/// and "pipelined bytes waiting" (`Ok(n)`).
fn peer_closed(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return true;
    }
    let mut buf = [0u8; 1];
    let closed = match stream.peek(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => false,
        Err(_) => true,
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// What one routed request resolves to.
enum Outcome {
    /// Write this response, keep serving the connection.
    Respond { status: u16, content_type: &'static str, body: String },
    /// The client vanished mid-query; there is nobody to answer.
    Disconnected,
}

fn respond(status: u16, body: String) -> Outcome {
    Outcome::Respond { status, content_type: "application/json", body }
}

fn handle_conn(shared: &Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(req)) => req,
            // Clean close between requests.
            Ok(None) => return,
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                // Malformed framing: answer 400 once, then close.
                shared.stats.requests.fetch_add(1, Ordering::Relaxed);
                shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                let body = error_body("bad_request", &format!("{e}"));
                let _ = http::write_response(
                    &mut writer,
                    400,
                    "application/json",
                    body.as_bytes(),
                    false,
                );
                return;
            }
            Err(_) => return,
        };
        shared.stats.requests.fetch_add(1, Ordering::Relaxed);
        let keep_alive = req.keep_alive();
        match route(shared, &req, &writer) {
            Outcome::Respond { status, content_type, body } => {
                if status >= 400 {
                    shared.stats.errors.fetch_add(1, Ordering::Relaxed);
                }
                if http::write_response(
                    &mut writer,
                    status,
                    content_type,
                    body.as_bytes(),
                    keep_alive,
                )
                .is_err()
                {
                    return;
                }
            }
            Outcome::Disconnected => return,
        }
        if !keep_alive {
            return;
        }
    }
}

fn route(shared: &Arc<Shared>, req: &http::Request, stream: &TcpStream) -> Outcome {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(200, shared.render_healthz()),
        ("GET", "/metrics") => Outcome::Respond {
            status: 200,
            content_type: "text/plain; version=0.0.4",
            body: shared.render_metrics(),
        },
        ("GET", "/debug/trace") => {
            let events: Vec<SpanEvent> = match &shared.serving {
                Serving::Single { .. } => shared.metrics.obs.trace.events(),
                // Session: one merged trace over every stage's ring (the
                // spans already interleave on the shared pool's lanes).
                Serving::Session(sess) => {
                    let mut ev: Vec<SpanEvent> = sess
                        .stages
                        .iter()
                        .flat_map(|st| st.metrics.obs.trace.events())
                        .collect();
                    ev.sort_by_key(|e| e.seq);
                    ev
                }
            };
            respond(200, chrome_trace_json(&events))
        }
        ("POST", "/query") => match &shared.serving {
            Serving::Single { .. } => handle_query(shared, req, stream),
            Serving::Session(_) => handle_session_query(shared, req, stream),
        },
        (_, "/healthz" | "/metrics" | "/debug/trace" | "/query") => respond(
            405,
            error_body("method_not_allowed", &format!("{} not allowed here", req.method)),
        ),
        (_, path) => respond(404, error_body("not_found", &format!("no route {path}"))),
    }
}

fn handle_query(shared: &Arc<Shared>, req: &http::Request, stream: &TcpStream) -> Outcome {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return respond(400, error_body("bad_request", "body is not UTF-8"));
    };
    let parsed = match parse_query_body(text, shared.cfg.default_topk) {
        Ok(p) => p,
        Err(msg) => return respond(400, error_body("bad_request", &msg)),
    };
    if parsed.stages.is_some() {
        return respond(
            400,
            error_body(
                "bad_request",
                "\"stages\" requires a session server (logra serve --session)",
            ),
        );
    }

    // Admission: reject fast instead of queueing — the client can retry,
    // and the scan pool's own queue stays reserved for admitted work.
    let Some(_guard) = shared.admit() else {
        shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
        return respond(
            429,
            error_body(
                "overloaded",
                &format!(
                    "{} queries already in flight (max_in_flight)",
                    shared.cfg.max_in_flight.max(1)
                ),
            ),
        );
    };
    shared.stats.queries.fetch_add(1, Ordering::Relaxed);
    let request_id = shared.next_request_id.fetch_add(1, Ordering::Relaxed) + 1;

    // Pin ONE snapshot for the whole query: admission, row lookup, scan,
    // and the response's generation all come from this Arc, so a reload
    // swapping the slot mid-flight can never mix two generations into
    // one answer.
    let valuator = shared.single_slot().load();

    // Resolve which engine a per-request backend choice lands on BEFORE
    // building the query: an unservable choice is the caller's mistake
    // (400), and the 200 response names the engine that actually served
    // (after "auto" resolution), not the wire-level choice.
    let served = match valuator.resolved_kind(parsed.backend) {
        Ok(kind) => kind.name(),
        Err(ValuationError::InvalidConfig(m)) => {
            return respond(400, error_body("bad_request", &m))
        }
        Err(e) => return respond(500, error_body("internal", &format!("{e}"))),
    };

    let query = match parsed.body {
        QueryBody::Row(row) => match valuator.gradient_row(row as usize) {
            Some(g) => QueryRequest::gradients(g, 1, parsed.topk),
            None => {
                return respond(
                    400,
                    error_body(
                        "bad_request",
                        &format!(
                            "row {row} out of range (store has {} rows)",
                            valuator.rows()
                        ),
                    ),
                )
            }
        },
        QueryBody::Gradient { rows, nt } => QueryRequest::gradients(rows, nt, parsed.topk),
    };
    let query = match parsed.norm {
        Some(n) => query.with_norm(n),
        None => query,
    };
    let query = match parsed.backend {
        Some(b) => query.with_backend(b),
        None => query,
    };

    let deadline_ms = parsed.deadline_ms.unwrap_or(shared.cfg.default_deadline_ms);
    let deadline =
        (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));

    let pending = match valuator.query_async(query) {
        Ok(p) => p,
        Err(ValuationError::BadQuery(m) | ValuationError::InvalidConfig(m)) => {
            return respond(400, error_body("bad_request", &m))
        }
        Err(ValuationError::Shutdown) => {
            return respond(503, error_body("shutting_down", "backend is shut down"))
        }
        Err(e) => return respond(500, error_body("internal", &format!("{e}"))),
    };

    // Wait, re-checking disconnect + deadline each poll interval. A
    // cancellation makes the pool skip this query's unstarted shard tasks
    // (PoolSnapshot::tasks_cancelled).
    let disconnected = std::cell::Cell::new(false);
    let mut should_cancel = || {
        if peer_closed(stream) {
            disconnected.set(true);
            return true;
        }
        matches!(deadline, Some(d) if Instant::now() >= d)
    };
    match pending.wait_with_report_until(&mut should_cancel, shared.cfg.poll_interval) {
        Ok((results, report)) => respond(
            200,
            query_response_body(
                request_id,
                served,
                valuator.generation(),
                &results,
                report.as_ref(),
            ),
        ),
        Err(ValuationError::Cancelled { .. }) => {
            if disconnected.get() {
                shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
                Outcome::Disconnected
            } else {
                shared.stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
                respond(
                    504,
                    error_body(
                        "deadline_expired",
                        &format!("query exceeded its {deadline_ms} ms deadline"),
                    ),
                )
            }
        }
        Err(ValuationError::QueryPoisoned { query_id, message }) => respond(
            500,
            error_body("query_poisoned", &format!("query {query_id}: {message}")),
        ),
        Err(ValuationError::Shutdown) => {
            respond(503, error_body("shutting_down", "backend is shut down"))
        }
        Err(e) => respond(500, error_body("internal", &format!("{e}"))),
    }
}

/// One stage's share of a session query, accumulated for the response.
struct SessionStageOutcome {
    name: String,
    weight: f64,
    served: &'static str,
    generation: u64,
    quarantined: usize,
    result: Result<(Vec<QueryResult>, Option<QueryReport>), String>,
}

/// The session `POST /query` 200 body: the top-level `results` array
/// (the combined ranking — or the first successful stage's results under
/// per-stage-only combining, so single-store clients like `logra loadgen`
/// keep parsing session responses unchanged), plus the per-stage
/// breakdown and a `stage_errors` count.
fn session_response_body(
    request_id: u64,
    combine: Combine,
    outcomes: &[SessionStageOutcome],
    combined: Option<&[QueryResult]>,
    results: &[QueryResult],
) -> String {
    let stage_errors = outcomes.iter().filter(|o| o.result.is_err()).count() as u64;
    let stages_json: Vec<Json> = outcomes
        .iter()
        .map(|o| {
            let mut pairs = vec![
                ("name".to_string(), Json::Str(o.name.clone())),
                ("weight".to_string(), Json::Float(o.weight)),
                ("generation".to_string(), Json::Num(o.generation)),
                ("quarantined_shards".to_string(), Json::Num(o.quarantined as u64)),
            ];
            match &o.result {
                Ok((results, report)) => {
                    pairs.push(("backend".to_string(), Json::Str(o.served.to_string())));
                    pairs.push(("results".to_string(), results_json(results)));
                    if let Some(rep) = report {
                        pairs.push(("report".to_string(), report_json(rep)));
                    }
                }
                Err(m) => pairs.push(("error".to_string(), Json::Str(m.clone()))),
            }
            Json::Obj(pairs)
        })
        .collect();
    Json::Obj(vec![
        ("request_id".to_string(), Json::Num(request_id)),
        ("combine".to_string(), Json::Str(combine.name().to_string())),
        ("results".to_string(), results_json(combined.unwrap_or(results))),
        ("stages".to_string(), Json::Arr(stages_json)),
        ("stage_errors".to_string(), Json::Num(stage_errors)),
    ])
    .render()
}

/// Session fan-out: pin every selected stage's snapshot at admission,
/// admit the query to all of them via `query_async` (their shard tasks
/// interleave on the ONE shared pool), then wait each out and combine.
/// A stage failing mid-query degrades to a per-stage `error` entry;
/// cancellation (deadline/disconnect) aborts the whole request, exactly
/// like the single-store path.
fn handle_session_query(
    shared: &Arc<Shared>,
    req: &http::Request,
    stream: &TcpStream,
) -> Outcome {
    let Serving::Session(sess) = &shared.serving else {
        return respond(500, error_body("internal", "session route on a single-store server"));
    };
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return respond(400, error_body("bad_request", "body is not UTF-8"));
    };
    let parsed = match parse_query_body(text, shared.cfg.default_topk) {
        Ok(p) => p,
        Err(msg) => return respond(400, error_body("bad_request", &msg)),
    };

    // Stage selection: always manifest order, so a subset never reorders
    // the fan-out (and duplicate names collapse).
    let selected: Vec<&ServeStage> = match &parsed.stages {
        None => sess.stages.iter().collect(),
        Some(names) => {
            for name in names {
                if sess.stage_named(name).is_none() {
                    let known: Vec<&str> =
                        sess.stages.iter().map(|st| st.spec.name.as_str()).collect();
                    return respond(
                        400,
                        error_body(
                            "bad_request",
                            &format!("unknown stage {name:?}; this session has {known:?}"),
                        ),
                    );
                }
            }
            sess.stages
                .iter()
                .filter(|st| names.iter().any(|n| n == &st.spec.name))
                .collect()
        }
    };

    let Some(_guard) = shared.admit() else {
        shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
        return respond(
            429,
            error_body(
                "overloaded",
                &format!(
                    "{} queries already in flight (max_in_flight)",
                    shared.cfg.max_in_flight.max(1)
                ),
            ),
        );
    };
    shared.stats.queries.fetch_add(1, Ordering::Relaxed);
    let request_id = shared.next_request_id.fetch_add(1, Ordering::Relaxed) + 1;

    // Pin EVERY selected stage's snapshot here: each stage's admission,
    // scan, and reported generation come from its own pinned Arc, so a
    // per-stage reload mid-flight never blends generations.
    let pinned: Vec<Arc<Valuator>> = selected.iter().map(|st| st.slot.load()).collect();

    // Per-stage serving engine: a request-level backend override beats
    // the stage's manifest default; an unservable choice is a 400.
    let mut served: Vec<&'static str> = Vec::with_capacity(selected.len());
    for (st, v) in selected.iter().zip(&pinned) {
        match v.resolved_kind(parsed.backend.or(st.spec.backend)) {
            Ok(kind) => served.push(kind.name()),
            Err(ValuationError::InvalidConfig(m)) => {
                return respond(
                    400,
                    error_body("bad_request", &format!("stage {:?}: {m}", st.spec.name)),
                )
            }
            Err(e) => return respond(500, error_body("internal", &format!("{e}"))),
        }
    }

    // `"row"` queries resolve against the FIRST selected stage's store —
    // the session's reference row space.
    let (rows, nt) = match parsed.body {
        QueryBody::Row(row) => match pinned[0].gradient_row(row as usize) {
            Some(g) => (g, 1),
            None => {
                return respond(
                    400,
                    error_body(
                        "bad_request",
                        &format!(
                            "row {row} out of range (stage {:?} has {} rows)",
                            selected[0].spec.name,
                            pinned[0].rows()
                        ),
                    ),
                )
            }
        },
        QueryBody::Gradient { rows, nt } => (rows, nt),
    };

    let deadline_ms = parsed.deadline_ms.unwrap_or(shared.cfg.default_deadline_ms);
    let deadline =
        (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));

    // Admit to EVERY stage first, wait after — that is the whole point of
    // the shared pool: stage A's shard tasks run while stage B's queue.
    let mut pendings: Vec<Result<PendingScores, String>> =
        Vec::with_capacity(selected.len());
    for (st, v) in selected.iter().zip(&pinned) {
        let mut q = QueryRequest::gradients(rows.clone(), nt, parsed.topk);
        if let Some(n) = parsed.norm {
            q = q.with_norm(n);
        }
        if let Some(b) = parsed.backend.or(st.spec.backend) {
            q = q.with_backend(b);
        }
        match v.query_async(q) {
            Ok(p) => pendings.push(Ok(p)),
            // A malformed query is malformed for every stage: 400 now.
            Err(ValuationError::BadQuery(m) | ValuationError::InvalidConfig(m)) => {
                return respond(
                    400,
                    error_body("bad_request", &format!("stage {:?}: {m}", st.spec.name)),
                )
            }
            Err(e) => pendings.push(Err(format!("{e}"))),
        }
    }

    let disconnected = std::cell::Cell::new(false);
    let mut should_cancel = || {
        if peer_closed(stream) {
            disconnected.set(true);
            return true;
        }
        matches!(deadline, Some(d) if Instant::now() >= d)
    };
    let mut outcomes: Vec<SessionStageOutcome> = Vec::with_capacity(selected.len());
    for (i, pending) in pendings.into_iter().enumerate() {
        let result = match pending {
            Err(m) => Err(m),
            Ok(p) => {
                match p.wait_with_report_until(&mut should_cancel, shared.cfg.poll_interval) {
                    Ok(ok) => Ok(ok),
                    Err(ValuationError::Cancelled { .. }) => {
                        // Cancellation aborts the WHOLE request; remaining
                        // pendings are dropped and their unstarted shard
                        // tasks skipped by the pool.
                        if disconnected.get() {
                            shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
                            return Outcome::Disconnected;
                        }
                        shared.stats.deadline_expired.fetch_add(1, Ordering::Relaxed);
                        return respond(
                            504,
                            error_body(
                                "deadline_expired",
                                &format!("query exceeded its {deadline_ms} ms deadline"),
                            ),
                        );
                    }
                    Err(e) => Err(format!("{e}")),
                }
            }
        };
        outcomes.push(SessionStageOutcome {
            name: selected[i].spec.name.clone(),
            weight: selected[i].spec.weight,
            served: served[i],
            generation: pinned[i].generation(),
            quarantined: pinned[i].quarantined().len(),
            result,
        });
    }

    // Combine over the stages that SUCCEEDED; every stage failing is the
    // whole request failing.
    let ok_reports: Vec<StageReport> = outcomes
        .iter()
        .filter_map(|o| {
            o.result.as_ref().ok().map(|(results, _)| StageReport {
                name: o.name.clone(),
                weight: o.weight,
                generation: o.generation,
                quarantined_shards: o.quarantined,
                results: results.clone(),
                report: None,
            })
        })
        .collect();
    if ok_reports.is_empty() {
        let first = outcomes
            .iter()
            .find_map(|o| o.result.as_ref().err().cloned())
            .unwrap_or_else(|| "no stages selected".into());
        return respond(
            500,
            error_body("internal", &format!("every selected stage failed: {first}")),
        );
    }
    let combined = combine_rankings(sess.combine, &ok_reports, parsed.topk.max(1));
    respond(
        200,
        session_response_body(
            request_id,
            sess.combine,
            &outcomes,
            combined.as_deref(),
            &ok_reports[0].results,
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_row_query_with_defaults() {
        let p = parse_query_body(r#"{"row": 3}"#, 7).unwrap();
        assert!(matches!(p.body, QueryBody::Row(3)));
        assert_eq!(p.topk, 7);
        assert!(p.norm.is_none());
        assert!(p.deadline_ms.is_none());
    }

    #[test]
    fn parses_gradient_query_with_overrides() {
        let p = parse_query_body(
            r#"{"gradient": [1.0, -2.5, 3, 4.0], "nt": 2, "topk": 9,
               "norm": "relatif", "deadline_ms": 250}"#,
            5,
        )
        .unwrap();
        match p.body {
            QueryBody::Gradient { rows, nt } => {
                assert_eq!(rows, vec![1.0, -2.5, 3.0, 4.0]);
                assert_eq!(nt, 2);
            }
            _ => panic!("expected gradient body"),
        }
        assert_eq!(p.topk, 9);
        assert_eq!(p.norm, Some(Normalization::RelatIf));
        assert_eq!(p.deadline_ms, Some(250));
        assert!(p.backend.is_none());
    }

    #[test]
    fn parses_backend_and_nprobe_overrides() {
        let p = parse_query_body(r#"{"row": 1, "backend": "exact"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Exact));
        let p = parse_query_body(r#"{"row": 1, "backend": "quantized"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Quantized));
        let p = parse_query_body(r#"{"row": 1, "backend": "auto"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Auto));
        let p = parse_query_body(r#"{"row": 1, "backend": "ann"}"#, 5).unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Ann { nprobe: None }));
        let p = parse_query_body(r#"{"row": 1, "backend": "ann", "nprobe": 3}"#, 5)
            .unwrap();
        assert_eq!(p.backend, Some(BackendChoice::Ann { nprobe: Some(3) }));
    }

    #[test]
    fn rejects_bad_backend_and_stray_nprobe() {
        for bad in [
            r#"{"row": 1, "backend": "bogus"}"#,
            r#"{"row": 1, "backend": 7}"#,
            r#"{"row": 1, "nprobe": 4}"#,
            r#"{"row": 1, "backend": "exact", "nprobe": 4}"#,
            r#"{"row": 1, "backend": "ann", "nprobe": 0}"#,
            r#"{"row": 1, "backend": "ann", "nprobe": "many"}"#,
        ] {
            assert!(parse_query_body(bad, 5).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parses_stage_subsets() {
        let p = parse_query_body(r#"{"row": 1}"#, 5).unwrap();
        assert!(p.stages.is_none());
        let p = parse_query_body(r#"{"row": 1, "stages": ["pretrain", "finetune"]}"#, 5)
            .unwrap();
        assert_eq!(
            p.stages,
            Some(vec!["pretrain".to_string(), "finetune".to_string()])
        );
        for bad in [
            r#"{"row": 1, "stages": []}"#,
            r#"{"row": 1, "stages": "pretrain"}"#,
            r#"{"row": 1, "stages": [3]}"#,
        ] {
            assert!(parse_query_body(bad, 5).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn session_response_keeps_toplevel_results() {
        let outcomes = vec![
            SessionStageOutcome {
                name: "pt".into(),
                weight: 1.0,
                served: "parallel-f32",
                generation: 2,
                quarantined: 0,
                result: Ok((vec![QueryResult { top: vec![(1.5, 4)] }], None)),
            },
            SessionStageOutcome {
                name: "ft".into(),
                weight: 0.5,
                served: "parallel-f32",
                generation: 7,
                quarantined: 1,
                result: Err("store went away".into()),
            },
        ];
        let combined = vec![QueryResult { top: vec![(1.5, 4)] }];
        let body =
            session_response_body(3, Combine::WeightedSum, &outcomes, Some(&combined), &[]);
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("request_id").and_then(Json::as_u64), Some(3));
        assert_eq!(v.get("combine").and_then(Json::as_str), Some("weighted-sum"));
        assert_eq!(v.get("stage_errors").and_then(Json::as_u64), Some(1));
        // The top-level results array survives for single-store clients.
        let r0 = &v.get("results").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(
            r0.get("ids").and_then(Json::as_arr).unwrap()[0].as_u64(),
            Some(4)
        );
        let stages = v.get("stages").and_then(Json::as_arr).unwrap();
        assert_eq!(stages[0].get("name").and_then(Json::as_str), Some("pt"));
        assert_eq!(stages[0].get("generation").and_then(Json::as_u64), Some(2));
        assert!(stages[0].get("error").is_none());
        assert_eq!(
            stages[1].get("error").and_then(Json::as_str),
            Some("store went away")
        );
        assert_eq!(stages[1].get("quarantined_shards").and_then(Json::as_u64), Some(1));
    }

    #[test]
    fn rejects_malformed_query_bodies() {
        for bad in [
            "not json",
            "[1,2]",
            "{}",
            r#"{"row": 1, "gradient": [1.0]}"#,
            r#"{"row": -1}"#,
            r#"{"row": 1, "topk": 0}"#,
            r#"{"gradient": ["x"]}"#,
            r#"{"gradient": [1.0], "nt": 0}"#,
            r#"{"row": 1, "norm": "weird"}"#,
            r#"{"row": 1, "deadline_ms": "soon"}"#,
        ] {
            assert!(parse_query_body(bad, 5).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn error_body_escapes_messages() {
        let body = error_body("bad_request", "quote\" and\nnewline");
        let v = json::parse(&body).unwrap();
        let err = v.get("error").unwrap();
        assert_eq!(err.get("code").and_then(Json::as_str), Some("bad_request"));
        assert_eq!(
            err.get("message").and_then(Json::as_str),
            Some("quote\" and\nnewline")
        );
    }

    #[test]
    fn query_response_roundtrips_scores_bit_exact() {
        let results = vec![QueryResult {
            top: vec![(0.12345678901234567, 42), (-3.5e-5, 7)],
        }];
        let body = query_response_body(9, "parallel-f32", 3, &results, None);
        let v = json::parse(&body).unwrap();
        assert_eq!(v.get("request_id").and_then(Json::as_u64), Some(9));
        assert_eq!(v.get("backend").and_then(Json::as_str), Some("parallel-f32"));
        assert_eq!(v.get("generation").and_then(Json::as_u64), Some(3));
        let r0 = &v.get("results").and_then(Json::as_arr).unwrap()[0];
        let ids: Vec<u64> = r0
            .get("ids")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_u64().unwrap())
            .collect();
        assert_eq!(ids, vec![42, 7]);
        let scores: Vec<f64> = r0
            .get("scores")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        assert_eq!(scores[0].to_bits(), 0.12345678901234567f64.to_bits());
        assert_eq!(scores[1].to_bits(), (-3.5e-5f64).to_bits());
    }
}
