//! Minimal HTTP/1.1 framing for `logra serve` and `logra loadgen` — no
//! new dependencies, same hand-rolled-subset philosophy as
//! [`crate::util::json`].
//!
//! Supports exactly what the valuation server needs: one request line,
//! `name: value` headers, a `Content-Length`-framed body, keep-alive
//! connection reuse, and the mirror-image response framing the load
//! generator and the integration tests read back. Deliberately NOT a
//! general HTTP stack: no chunked transfer encoding, no trailers, no
//! `Expect: 100-continue`, no TLS.

use std::io::{self, BufRead, Write};

/// Hard cap on request/response bodies (a valuation query is a few KiB of
/// JSON; a gradient body tops out around `nt * k` floats).
pub const MAX_BODY: usize = 16 * 1024 * 1024;
/// Hard cap on one header/request line.
const MAX_LINE: usize = 8 * 1024;
/// Hard cap on header count.
const MAX_HEADERS: usize = 64;

/// One parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub version: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive; `Connection: close` (any case)
    /// opts out, and HTTP/1.0 must opt in explicitly.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            Some(_) => self.version != "HTTP/1.0",
            None => self.version != "HTTP/1.0",
        }
    }
}

/// One parsed HTTP response (client side: `logra loadgen`, tests).
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Read one CRLF (or bare-LF) terminated line. `Ok(None)` only on clean
/// EOF before the first byte — EOF mid-line is an error.
fn read_line<R: BufRead>(r: &mut R) -> io::Result<Option<String>> {
    let mut buf = Vec::new();
    let n = r.take(MAX_LINE as u64 + 1).read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') {
        return Err(if buf.len() > MAX_LINE {
            bad("header line exceeds limit")
        } else {
            io::ErrorKind::UnexpectedEof.into()
        });
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map(Some).map_err(|_| bad("non-UTF-8 header line"))
}

/// Parse `Name: value` header lines until the blank separator line.
fn read_headers<R: BufRead>(r: &mut R) -> io::Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?.ok_or(io::ErrorKind::UnexpectedEof)?;
        if line.is_empty() {
            return Ok(headers);
        }
        if headers.len() >= MAX_HEADERS {
            return Err(bad("too many headers"));
        }
        let (name, value) =
            line.split_once(':').ok_or_else(|| bad("malformed header line"))?;
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
}

fn read_body<R: BufRead>(
    r: &mut R,
    headers: &[(String, String)],
) -> io::Result<Vec<u8>> {
    let len = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.parse::<usize>().map_err(|_| bad("bad Content-Length")))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY {
        return Err(bad(format!("body of {len} bytes exceeds limit ({MAX_BODY})")));
    }
    let mut body = vec![0u8; len];
    io::Read::read_exact(r, &mut body)?;
    Ok(body)
}

/// Read one request off a (possibly keep-alive) connection. `Ok(None)`
/// means the peer closed cleanly between requests; a malformed request
/// surfaces as [`io::ErrorKind::InvalidData`] (answer 400, then close).
pub fn read_request<R: BufRead>(r: &mut R) -> io::Result<Option<Request>> {
    // Tolerate stray blank lines between pipelined requests (RFC 9112 §2.2).
    let line = loop {
        match read_line(r)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => break l,
        }
    };
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/") => {
            (m.to_string(), p.to_string(), v.to_string())
        }
        _ => return Err(bad(format!("malformed request line {line:?}"))),
    };
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(Some(Request { method, path, version, headers, body }))
}

/// Read one response (client side). EOF before the status line is an
/// error here — a client that just sent a request expects an answer.
pub fn read_response<R: BufRead>(r: &mut R) -> io::Result<Response> {
    let line = read_line(r)?.ok_or(io::ErrorKind::UnexpectedEof)?;
    let mut parts = line.split_whitespace();
    let status = match (parts.next(), parts.next()) {
        (Some(v), Some(code)) if v.starts_with("HTTP/") => {
            code.parse::<u16>().map_err(|_| bad("bad status code"))?
        }
        _ => return Err(bad(format!("malformed status line {line:?}"))),
    };
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(Response { status, headers, body })
}

/// Canonical reason phrase for the statuses this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one `Content-Length`-framed response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(body)?;
    w.flush()
}

/// Write one request (client side).
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    body: &[u8],
) -> io::Result<()> {
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nHost: logra\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /query HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"row\":1}";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/query");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"{\"row\":1}");
        assert!(req.keep_alive());
    }

    #[test]
    fn clean_eof_between_requests_is_none() {
        assert!(read_request(&mut Cursor::new(&b""[..])).unwrap().is_none());
    }

    #[test]
    fn connection_close_and_http10() {
        let raw = b"GET / HTTP/1.1\r\nConnection: Close\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert!(req.keep_alive());
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        let err = read_request(&mut Cursor::new(&b"not an http line\r\n\r\n"[..]))
            .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        let err = read_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // Truncated body: EOF mid-read, not a silent short body.
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        assert!(read_request(&mut Cursor::new(&raw[..])).is_err());
    }

    #[test]
    fn response_roundtrips() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, "application/json", b"{\"error\":1}", true)
            .unwrap();
        let res = read_response(&mut Cursor::new(&wire[..])).unwrap();
        assert_eq!(res.status, 429);
        assert_eq!(res.header("content-type"), Some("application/json"));
        assert_eq!(res.body, b"{\"error\":1}");

        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/query", b"{}").unwrap();
        let req = read_request(&mut Cursor::new(&wire[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{}");
    }
}
