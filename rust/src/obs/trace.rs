//! Per-query span tracing: a bounded ring of timestamped span events,
//! exportable as Chrome trace-event JSON.
//!
//! Every instrumented stage of the query path (admission, queue wait,
//! each `(query, shard)` scan task, merge, rescore, the whole query)
//! records one [`SpanEvent`] into the shared [`TraceRing`]. Recording is
//! one relaxed atomic increment to claim a slot plus one short per-slot
//! mutex write — bounded memory, no allocation, and the ring simply
//! overwrites the oldest events under sustained load, so it always holds
//! the trace of the most recent queries.
//!
//! [`chrome_trace_json`] renders events in the Chrome trace-event format
//! (`{"traceEvents": [...]}` with complete `"ph": "X"` events), loadable
//! in `chrome://tracing` or Perfetto; `logra trace` writes it to disk.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Small dense id for the calling thread ("lane"), assigned on first use.
/// Lanes map to Chrome trace `tid`s and to `PoolSnapshot::worker_lanes`,
/// so trace rows line up with pool workers.
pub fn thread_lane() -> u32 {
    static NEXT_LANE: AtomicU32 = AtomicU32::new(0);
    thread_local! {
        static LANE: u32 = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
    }
    LANE.with(|l| *l)
}

/// One completed span on the query path. Times are nanoseconds since the
/// owning [`Obs`](super::Obs) epoch (a per-process monotonic origin).
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Stage name from the fixed span taxonomy (`"admission"`,
    /// `"queue_wait"`, `"scan"`, `"merge"`, `"rescore"`, `"query"`).
    pub name: &'static str,
    /// Observability query id (one per admitted query, process-wide).
    pub query: u64,
    /// Shard index for per-shard scan spans; `None` for query-level spans.
    pub shard: Option<u32>,
    /// Lane (thread) the span ran on — the Chrome trace `tid`.
    pub lane: u32,
    pub start_nanos: u64,
    pub dur_nanos: u64,
    /// Global record sequence number (assigned by the ring; later events
    /// have larger `seq`, which survives ring wraparound).
    pub seq: u64,
}

/// Bounded lock-light ring buffer of the most recent [`SpanEvent`]s.
pub struct TraceRing {
    next: AtomicU64,
    slots: Vec<Mutex<Option<SpanEvent>>>,
}

impl TraceRing {
    /// Ring holding the most recent `capacity` events (min 1).
    pub fn with_capacity(capacity: usize) -> Self {
        TraceRing {
            next: AtomicU64::new(0),
            slots: (0..capacity.max(1)).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Record one event (its `seq` field is assigned here). Under
    /// contention the claim is a single relaxed `fetch_add`; an event
    /// overwritten before a concurrent reader copies its slot simply drops
    /// out of that reader's view — the ring never blocks the hot path on
    /// readers.
    pub fn record(&self, mut event: SpanEvent) {
        let seq = self.next.fetch_add(1, Ordering::Relaxed);
        event.seq = seq;
        let slot = (seq % self.slots.len() as u64) as usize;
        *self.slots[slot].lock().unwrap() = Some(event);
    }

    /// Total events ever recorded (including ones already overwritten).
    pub fn recorded(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// The retained events, oldest first (at most `capacity`, with
    /// monotonically increasing `seq`).
    pub fn events(&self) -> Vec<SpanEvent> {
        let mut out: Vec<SpanEvent> =
            self.slots.iter().filter_map(|s| s.lock().unwrap().clone()).collect();
        out.sort_by_key(|e| e.seq);
        out
    }
}

/// Render span events as Chrome trace-event JSON (complete `"X"` events,
/// microsecond integer timestamps — `chrome://tracing` / Perfetto /
/// [`crate::util::json`]-parseable). Lanes become `tid`s so each worker
/// thread gets its own track; the query id (and shard, when present) ride
/// in `args`. Durations round up to 1 µs so sub-microsecond spans stay
/// visible.
pub fn chrome_trace_json(events: &[SpanEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 112 + 32);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        // Span names come from the fixed taxonomy today, but they still go
        // through the crate's one escape-correct string writer — a future
        // name must not be able to corrupt the trace document.
        out.push_str("{\"name\":\"");
        crate::util::json::escape_into(&mut out, e.name);
        out.push_str(&format!(
            "\",\"cat\":\"logra\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
             \"pid\":1,\"tid\":{},\"args\":{{\"query\":{}",
            e.start_nanos / 1_000,
            (e.dur_nanos / 1_000).max(1),
            e.lane,
            e.query
        ));
        if let Some(shard) = e.shard {
            out.push_str(&format!(",\"shard\":{shard}"));
        }
        out.push_str("}}");
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &'static str, start: u64) -> SpanEvent {
        SpanEvent {
            name,
            query: 7,
            shard: None,
            lane: thread_lane(),
            start_nanos: start,
            dur_nanos: 500,
            seq: 0,
        }
    }

    #[test]
    fn ring_retains_most_recent_in_seq_order() {
        let ring = TraceRing::with_capacity(4);
        for i in 0..10u64 {
            ring.record(ev("scan", i * 1000));
        }
        assert_eq!(ring.recorded(), 10);
        assert_eq!(ring.capacity(), 4);
        let events = ring.events();
        assert_eq!(events.len(), 4);
        let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
    }

    #[test]
    fn lanes_are_stable_per_thread() {
        let a = thread_lane();
        let b = thread_lane();
        assert_eq!(a, b);
        let other = std::thread::spawn(thread_lane).join().unwrap();
        assert_ne!(a, other);
    }

    #[test]
    fn chrome_json_shape() {
        let mut e = ev("query", 2_000);
        e.shard = Some(3);
        let json = chrome_trace_json(&[e]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ts\":2"));
        assert!(json.contains("\"shard\":3"));
        assert!(json.ends_with("]}"));
        assert_eq!(chrome_trace_json(&[]), "{\"traceEvents\":[]}");
    }
}
