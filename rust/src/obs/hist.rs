//! Atomic log-bucketed latency histograms (HDR-style).
//!
//! A [`Histogram`] is a fixed array of [`BUCKETS`] atomic counters over
//! nanosecond values: recording a sample is two relaxed `fetch_add`s (one
//! bucket counter, one running sum) — no locks, no per-sample allocation —
//! so the scan hot path can feed it from every worker concurrently.
//!
//! # Bucket layout
//!
//! Buckets are log-linear with 8 sub-buckets per power of two (3
//! significand bits kept), the classic HDR-histogram shape:
//!
//! - values below 8 ns get exact unit buckets (`[v, v+1)`);
//! - a value with most-significant bit `m` (`8 ≤ 2^m ≤ 2^49`) lands in
//!   one of 8 sub-buckets of width `2^(m-3)` spanning `[2^m, 2^(m+1))`;
//! - everything above `2^50` ns (~13 days) collapses into the last bucket.
//!
//! The relative bucket width is at most 12.5%, so any quantile read from
//! bucket bounds is within one bucket width of the exact order statistic —
//! the property `rust/tests/obs.rs` pins against
//! [`crate::util::stats::percentile`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Sub-bucket resolution: 2^3 = 8 sub-buckets per power of two.
const SUB_BITS: u32 = 3;

/// Largest most-significant-bit position tracked with full resolution
/// (values up to `2^(MAX_MSB+1)` ns ≈ 13 days; beyond that the last
/// bucket absorbs everything).
const MAX_MSB: u32 = 49;

/// Total bucket count: 8 unit buckets + 8 sub-buckets for each msb in
/// `3..=49` — `8 + 47 * 8 = 384`.
pub const BUCKETS: usize = 384;

/// Bucket index for a nanosecond value (monotone non-decreasing in the
/// value; zero clamps to 1 ns).
pub fn bucket_index(nanos: u64) -> usize {
    let v = nanos.max(1);
    let msb = 63 - v.leading_zeros();
    if msb < SUB_BITS {
        v as usize
    } else if msb > MAX_MSB {
        BUCKETS - 1
    } else {
        let sub = ((v >> (msb - SUB_BITS)) & 0x7) as usize;
        (msb as usize - 2) * 8 + sub
    }
}

/// Half-open nanosecond range `[lo, hi)` covered by bucket `index`.
/// (The last bucket also absorbs values past its nominal `hi`.)
pub fn bucket_bounds(index: usize) -> (u64, u64) {
    if index < 8 {
        (index as u64, index as u64 + 1)
    } else {
        let m = (index / 8 + 2) as u32;
        let w = 1u64 << (m - SUB_BITS);
        let lo = (1u64 << m) + (index % 8) as u64 * w;
        (lo, lo + w)
    }
}

/// Lock-free log-bucketed histogram over nanosecond samples.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    sum_nanos: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_nanos: AtomicU64::new(0),
        }
    }

    /// Record one nanosecond sample (two relaxed atomic adds).
    pub fn record(&self, nanos: u64) {
        self.counts[bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Record a duration expressed in seconds.
    pub fn record_seconds(&self, seconds: f64) {
        self.record((seconds.max(0.0) * 1e9) as u64);
    }

    /// Point-in-time copy of the bucket counts (quantiles are read off the
    /// snapshot so concurrent recording cannot tear a percentile).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        HistogramSnapshot { counts, count, sum_nanos: self.sum_nanos.load(Ordering::Relaxed) }
    }
}

/// Point-in-time copy of a [`Histogram`]'s counters.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts ([`BUCKETS`] entries; see
    /// [`bucket_bounds`] for each bucket's nanosecond range).
    pub counts: Vec<u64>,
    /// Total samples (sum of `counts` — internally consistent even if
    /// samples landed mid-snapshot).
    pub count: u64,
    /// Sum of all recorded nanosecond values.
    pub sum_nanos: u64,
}

impl HistogramSnapshot {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean_nanos(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_nanos as f64 / self.count as f64
        }
    }

    /// Bucket holding the sample of (0-based) `rank`.
    fn bucket_of_rank(&self, rank: u64) -> usize {
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return i;
            }
        }
        BUCKETS - 1
    }

    /// Approximate percentile in nanoseconds: the midpoint of the bucket
    /// holding the round-rank sample (rank = `round(p/100 * (n-1))`, the
    /// same rank convention as [`crate::util::stats::percentile`]). 0.0
    /// when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * (self.count - 1) as f64).round() as u64;
        let (lo, hi) = bucket_bounds(self.bucket_of_rank(rank));
        (lo as f64 + hi as f64) / 2.0
    }

    /// Convenience: [`percentile`](Self::percentile) in milliseconds.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile(p) / 1e6
    }

    /// Nanosecond interval guaranteed to contain the EXACT interpolated
    /// percentile of the recorded samples: `[lo, hi)` where `lo` is the
    /// lower bound of the floor-rank sample's bucket and `hi` the upper
    /// bound of the ceil-rank sample's bucket. `(0, 0)` when empty.
    pub fn percentile_bounds(&self, p: f64) -> (f64, f64) {
        if self.count == 0 {
            return (0.0, 0.0);
        }
        let exact = (p.clamp(0.0, 100.0) / 100.0) * (self.count - 1) as f64;
        let lo = bucket_bounds(self.bucket_of_rank(exact.floor() as u64)).0;
        let hi = bucket_bounds(self.bucket_of_rank(exact.ceil() as u64)).1;
        (lo as f64, hi as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_matches_bounds() {
        // Every probe value must land in a bucket whose bounds contain it.
        let probes: Vec<u64> = (0..=64)
            .chain([100, 255, 256, 257, 1_000, 65_535, 1_000_000, 1_000_000_000])
            .chain((3..=49).flat_map(|m: u32| {
                let b = 1u64 << m;
                [b - 1, b, b + 1, b + (b >> 1)]
            }))
            .collect();
        for v in probes {
            let i = bucket_index(v);
            let (lo, hi) = bucket_bounds(i);
            let clamped = v.max(1);
            assert!(
                lo <= clamped && clamped < hi,
                "v={v} index={i} bounds=({lo},{hi})"
            );
        }
    }

    #[test]
    fn bucket_index_is_monotone_and_bounds_tile() {
        // Index is monotone in the value and consecutive buckets tile the
        // axis with no gaps or overlaps.
        let mut prev = bucket_index(1);
        for v in 2..100_000u64 {
            let i = bucket_index(v);
            assert!(i >= prev, "index regressed at v={v}");
            prev = i;
        }
        for i in 1..BUCKETS - 1 {
            assert_eq!(
                bucket_bounds(i).1,
                bucket_bounds(i + 1).0,
                "gap between buckets {i} and {}",
                i + 1
            );
        }
        // Relative width stays within the 12.5% HDR guarantee.
        for i in 8..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert!((hi - lo) as f64 / lo as f64 <= 0.125 + 1e-12, "bucket {i} too wide");
        }
    }

    #[test]
    fn overflow_lands_in_last_bucket() {
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_index(1u64 << 55), BUCKETS - 1);
        let (lo, _) = bucket_bounds(BUCKETS - 1);
        assert!(lo <= 1u64 << 50);
    }

    #[test]
    fn snapshot_counts_and_mean() {
        let h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum_nanos, 60);
        assert!((s.mean_nanos() - 20.0).abs() < 1e-9);
        assert!(!s.is_empty());
        let empty = Histogram::new().snapshot();
        assert!(empty.is_empty());
        assert_eq!(empty.percentile(50.0), 0.0);
        assert_eq!(empty.percentile_bounds(99.0), (0.0, 0.0));
    }
}
