//! Prometheus-style text exposition over service metrics, pool health,
//! and the latency histograms.
//!
//! [`render_exposition`] produces the classic text format (`# HELP` /
//! `# TYPE` comments, `name{labels} value` samples, cumulative
//! `_bucket{le="..."}` histogram series) from a
//! [`Metrics`](crate::coordinator::Metrics) instance, an optional
//! [`PoolSnapshot`](crate::valuation::PoolSnapshot), and any extra
//! caller-supplied gauges (e.g. store shape from `logra store stat
//! --metrics`). `examples/serve_queries.rs --metrics` prints it and CI
//! validates it with `scripts/check_metrics.py`.
//!
//! `logra serve` appends its own families on top of this exposition via
//! the same `simple` helper: the `logra_serve_*` request counters and
//! the live-store families (`logra_store_generation`,
//! `logra_store_reloads_total`, `logra_store_reload_errors_total`,
//! `logra_store_quarantined_shards`, `logra_store_ivf_fallback_shards`)
//! that track generation-snapshotted reload — see `serve::render_metrics`.

use std::sync::atomic::Ordering;

use crate::coordinator::metrics::Metrics;
use crate::valuation::PoolSnapshot;

use super::hist::{bucket_bounds, HistogramSnapshot};

pub(crate) fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

pub(crate) fn sample(out: &mut String, name: &str, labels: &str, value: f64) {
    out.push_str(name);
    out.push_str(labels);
    out.push(' ');
    out.push_str(&format!("{value}"));
    out.push('\n');
}

pub(crate) fn simple(out: &mut String, name: &str, help: &str, kind: &str, value: f64) {
    header(out, name, help, kind);
    sample(out, name, "", value);
}

/// Render one histogram as a cumulative-bucket Prometheus series (bucket
/// bounds in SECONDS; empty buckets are skipped, so `le` values are
/// strictly increasing and the series stays compact).
fn histogram(out: &mut String, name: &str, help: &str, snap: &HistogramSnapshot) {
    header(out, name, help, "histogram");
    let mut cumulative = 0u64;
    for (i, &c) in snap.counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        cumulative += c;
        let (_, hi) = bucket_bounds(i);
        out.push_str(&format!(
            "{name}_bucket{{le=\"{}\"}} {cumulative}\n",
            hi as f64 / 1e9
        ));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", snap.count));
    out.push_str(&format!("{name}_sum {}\n", snap.sum_nanos as f64 / 1e9));
    out.push_str(&format!("{name}_count {}\n", snap.count));
}

/// One stage's slice of a multi-stage session exposition: the stage's own
/// [`Metrics`] instance plus the snapshot facts its valuator reports.
pub struct StageMetrics<'a> {
    /// Stage name — becomes the `stage` label on every family.
    pub stage: &'a str,
    pub metrics: &'a Metrics,
    pub generation: u64,
    pub quarantined_shards: usize,
}

/// Render one histogram per stage under a single family header, each
/// series carrying the `stage` label (same compaction as the unlabeled
/// renderer: empty buckets are skipped).
fn labeled_histogram(out: &mut String, name: &str, help: &str, series: &[(&str, HistogramSnapshot)]) {
    header(out, name, help, "histogram");
    for (stage, snap) in series {
        let mut cumulative = 0u64;
        for (i, &c) in snap.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cumulative += c;
            let (_, hi) = bucket_bounds(i);
            out.push_str(&format!(
                "{name}_bucket{{stage=\"{stage}\",le=\"{}\"}} {cumulative}\n",
                hi as f64 / 1e9
            ));
        }
        out.push_str(&format!(
            "{name}_bucket{{stage=\"{stage}\",le=\"+Inf\"}} {}\n",
            snap.count
        ));
        out.push_str(&format!(
            "{name}_sum{{stage=\"{stage}\"}} {}\n",
            snap.sum_nanos as f64 / 1e9
        ));
        out.push_str(&format!("{name}_count{{stage=\"{stage}\"}} {}\n", snap.count));
    }
}

/// Append the `logra_session_stage_*` families of a multi-stage session:
/// one `# HELP`/`# TYPE` header per family, one `{stage="..."}`-labeled
/// sample (or bucket series) per stage. Each stage carries its OWN
/// `Metrics` instance, so these families are exact per-stage slices —
/// `logra serve --session` appends this after its session-level
/// exposition.
pub fn render_session_exposition(out: &mut String, stages: &[StageMetrics<'_>]) {
    if stages.is_empty() {
        return;
    }
    let ld = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed) as f64;
    let lbl = |s: &StageMetrics<'_>| format!("{{stage=\"{}\"}}", s.stage);

    header(
        out,
        "logra_session_stage_requests_total",
        "Queries admitted, per session stage.",
        "counter",
    );
    for s in stages {
        sample(out, "logra_session_stage_requests_total", &lbl(s), ld(&s.metrics.requests));
    }
    header(
        out,
        "logra_session_stage_rows_scanned_total",
        "Train rows covered by influence scans, per session stage.",
        "counter",
    );
    for s in stages {
        sample(out, "logra_session_stage_rows_scanned_total", &lbl(s), ld(&s.metrics.rows_scanned));
    }
    header(
        out,
        "logra_session_stage_shards_scanned_total",
        "Per-shard scan tasks completed, per session stage.",
        "counter",
    );
    for s in stages {
        sample(
            out,
            "logra_session_stage_shards_scanned_total",
            &lbl(s),
            ld(&s.metrics.shards_scanned),
        );
    }
    header(
        out,
        "logra_session_stage_candidates_rescored_total",
        "Candidate rows rescored at exact precision, per session stage.",
        "counter",
    );
    for s in stages {
        sample(
            out,
            "logra_session_stage_candidates_rescored_total",
            &lbl(s),
            ld(&s.metrics.candidates_rescored),
        );
    }
    header(
        out,
        "logra_session_stage_scan_seconds_total",
        "Wall seconds spent in influence scans, per session stage.",
        "counter",
    );
    for s in stages {
        sample(
            out,
            "logra_session_stage_scan_seconds_total",
            &lbl(s),
            ld(&s.metrics.scan_nanos) / 1e9,
        );
    }
    header(
        out,
        "logra_session_stage_generation",
        "Manifest generation each stage's current snapshot was opened at.",
        "gauge",
    );
    for s in stages {
        sample(out, "logra_session_stage_generation", &lbl(s), s.generation as f64);
    }
    header(
        out,
        "logra_session_stage_quarantined_shards",
        "Shards a degraded open excluded from each stage's fabric.",
        "gauge",
    );
    for s in stages {
        sample(
            out,
            "logra_session_stage_quarantined_shards",
            &lbl(s),
            s.quarantined_shards as f64,
        );
    }

    labeled_histogram(
        out,
        "logra_session_stage_query_latency_seconds",
        "End-to-end per-query latency, per session stage.",
        &stages
            .iter()
            .map(|s| (s.stage, s.metrics.obs.query_latency.snapshot()))
            .collect::<Vec<_>>(),
    );
    labeled_histogram(
        out,
        "logra_session_stage_queue_wait_seconds",
        "Per-query admission-to-first-scan-task wait, per session stage.",
        &stages
            .iter()
            .map(|s| (s.stage, s.metrics.obs.queue_wait.snapshot()))
            .collect::<Vec<_>>(),
    );
    labeled_histogram(
        out,
        "logra_session_stage_shard_scan_seconds",
        "Wall time of individual (query, shard) scan tasks, per session stage.",
        &stages
            .iter()
            .map(|s| (s.stage, s.metrics.obs.shard_scan.snapshot()))
            .collect::<Vec<_>>(),
    );
}

/// Render the full exposition: `Metrics` counters, the embedded
/// [`Obs`](super::Obs) histograms, optional pool health, and any extra
/// gauges as `(name, help, value)` triples (names must be valid
/// Prometheus metric names).
pub fn render_exposition(
    metrics: &Metrics,
    pool: Option<&PoolSnapshot>,
    extra_gauges: &[(&str, &str, f64)],
) -> String {
    let ld = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed) as f64;
    let mut out = String::with_capacity(4096);

    simple(
        &mut out,
        "logra_requests_total",
        "Valuation requests admitted.",
        "counter",
        ld(&metrics.requests),
    );
    simple(
        &mut out,
        "logra_batches_total",
        "Dynamic batches executed by the service worker.",
        "counter",
        ld(&metrics.batches),
    );
    simple(
        &mut out,
        "logra_rows_scanned_total",
        "Train rows covered by influence scans.",
        "counter",
        ld(&metrics.rows_scanned),
    );
    simple(
        &mut out,
        "logra_shards_scanned_total",
        "Per-shard scan tasks completed.",
        "counter",
        ld(&metrics.shards_scanned),
    );
    simple(
        &mut out,
        "logra_candidates_rescored_total",
        "Candidate rows rescored at exact precision (two-stage stage 2).",
        "counter",
        ld(&metrics.candidates_rescored),
    );
    simple(
        &mut out,
        "logra_rows_probed_total",
        "Rows named by IVF stage-0 probes (the pruned coarse-scan workload).",
        "counter",
        ld(&metrics.rows_probed),
    );
    simple(
        &mut out,
        "logra_scan_seconds_total",
        "Wall seconds spent in influence scans.",
        "counter",
        ld(&metrics.scan_nanos) / 1e9,
    );
    simple(
        &mut out,
        "logra_grad_seconds_total",
        "Wall seconds spent extracting query gradients.",
        "counter",
        ld(&metrics.grad_nanos) / 1e9,
    );
    simple(
        &mut out,
        "logra_queue_wait_seconds_total",
        "Summed admission-to-first-scan-task wait across queries.",
        "counter",
        ld(&metrics.queue_wait_nanos) / 1e9,
    );
    simple(
        &mut out,
        "logra_shard_scan_seconds_total",
        "Summed per-shard scan time across workers.",
        "counter",
        ld(&metrics.shard_scan_nanos) / 1e9,
    );
    simple(
        &mut out,
        "logra_stage1_seconds_total",
        "Two-stage engine: wall seconds in the quantized coarse scan.",
        "counter",
        ld(&metrics.stage1_nanos) / 1e9,
    );
    simple(
        &mut out,
        "logra_stage2_seconds_total",
        "Two-stage engine: wall seconds in the exact rescore.",
        "counter",
        ld(&metrics.stage2_nanos) / 1e9,
    );
    simple(
        &mut out,
        "logra_pool_workers",
        "Scan-pool workers actually spawned (0 = no pool).",
        "gauge",
        ld(&metrics.pool_workers),
    );
    simple(
        &mut out,
        "logra_scan_chunk_len",
        "Rows per kernel call resolved for the latest query.",
        "gauge",
        ld(&metrics.scan_chunk_len),
    );

    histogram(
        &mut out,
        "logra_query_latency_seconds",
        "End-to-end per-query latency (admission to results).",
        &metrics.obs.query_latency.snapshot(),
    );
    histogram(
        &mut out,
        "logra_queue_wait_seconds",
        "Per-query wait between admission-done and the first scan task.",
        &metrics.obs.queue_wait.snapshot(),
    );
    histogram(
        &mut out,
        "logra_shard_scan_seconds",
        "Wall time of individual (query, shard) scan tasks.",
        &metrics.obs.shard_scan.snapshot(),
    );

    if let Some(p) = pool {
        pool_families(&mut out, p);
    }

    for (name, help, value) in extra_gauges {
        simple(&mut out, name, help, "gauge", *value);
    }
    out
}

/// The `logra_pool_*` families for one [`PoolSnapshot`] — shared between
/// the single-store exposition above and the session server, where the
/// ONE shared pool is session-level rather than per-stage.
pub(crate) fn pool_families(out: &mut String, p: &PoolSnapshot) {
    simple(
        out,
        "logra_pool_queue_depth",
        "Scan tasks sitting in the bounded pool queue.",
        "gauge",
        p.queue_depth as f64,
    );
    simple(
        out,
        "logra_pool_in_flight",
        "Queries admitted to the pool but not yet completed.",
        "gauge",
        p.in_flight as f64,
    );
    simple(
        out,
        "logra_pool_queries_total",
        "Queries ever submitted to the scan pool.",
        "counter",
        p.queries_submitted as f64,
    );
    simple(
        out,
        "logra_pool_tasks_completed_total",
        "Pool scan tasks run to completion.",
        "counter",
        p.tasks_completed as f64,
    );
    simple(
        out,
        "logra_pool_tasks_failed_total",
        "Pool scan tasks that panicked.",
        "counter",
        p.tasks_failed as f64,
    );
    simple(
        out,
        "logra_pool_tasks_skipped_total",
        "Pool scan tasks fast-skipped on an already-failed query.",
        "counter",
        p.tasks_skipped as f64,
    );
    simple(
        out,
        "logra_pool_tasks_cancelled_total",
        "Pool scan tasks skipped because their query was cancelled \
         (client disconnect or deadline expiry).",
        "counter",
        p.tasks_cancelled as f64,
    );
    header(
        out,
        "logra_pool_worker_busy_seconds_total",
        "Per-worker seconds inside scan closures.",
        "counter",
    );
    for (w, secs) in p.busy_seconds.iter().enumerate() {
        sample(
            out,
            "logra_pool_worker_busy_seconds_total",
            &format!("{{worker=\"{w}\"}}"),
            *secs,
        );
    }
    header(
        out,
        "logra_pool_worker_lane",
        "Trace lane (Chrome trace tid) of each pool worker; -1 until \
         the worker first runs.",
        "gauge",
    );
    for (w, lane) in p.worker_lanes.iter().enumerate() {
        let v = if *lane == u32::MAX { -1.0 } else { *lane as f64 };
        sample(out, "logra_pool_worker_lane", &format!("{{worker=\"{w}\"}}"), v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_has_help_type_and_histograms() {
        let m = Metrics::default();
        m.requests.store(5, Ordering::Relaxed);
        m.obs.query_latency.record(1_000_000);
        m.obs.query_latency.record(2_000_000);
        let text = render_exposition(&m, None, &[("logra_store_rows", "Rows.", 42.0)]);
        assert!(text.contains("# HELP logra_requests_total"));
        assert!(text.contains("# TYPE logra_requests_total counter"));
        assert!(text.contains("logra_requests_total 5"));
        assert!(text.contains("# TYPE logra_query_latency_seconds histogram"));
        assert!(text.contains("logra_query_latency_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("logra_query_latency_seconds_count 2"));
        assert!(text.contains("logra_store_rows 42"));
        // Every sample line sits under a TYPE declaration for its family.
        for line in text.lines() {
            assert!(!line.is_empty(), "exposition must not contain blank lines");
        }
    }

    #[test]
    fn session_exposition_labels_every_family_per_stage() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.requests.store(3, Ordering::Relaxed);
        b.requests.store(7, Ordering::Relaxed);
        a.obs.query_latency.record(1_000_000);
        let mut out = String::new();
        render_session_exposition(
            &mut out,
            &[
                StageMetrics { stage: "pretrain", metrics: &a, generation: 2, quarantined_shards: 0 },
                StageMetrics { stage: "finetune", metrics: &b, generation: 5, quarantined_shards: 1 },
            ],
        );
        assert!(out.contains("# TYPE logra_session_stage_requests_total counter"));
        assert!(out.contains("logra_session_stage_requests_total{stage=\"pretrain\"} 3"));
        assert!(out.contains("logra_session_stage_requests_total{stage=\"finetune\"} 7"));
        assert!(out.contains("logra_session_stage_generation{stage=\"finetune\"} 5"));
        assert!(out.contains("logra_session_stage_quarantined_shards{stage=\"finetune\"} 1"));
        assert!(out.contains(
            "logra_session_stage_query_latency_seconds_bucket{stage=\"pretrain\",le=\"+Inf\"} 1"
        ));
        assert!(out
            .contains("logra_session_stage_query_latency_seconds_count{stage=\"finetune\"} 0"));
        // One header per family, not one per stage.
        assert_eq!(out.matches("# TYPE logra_session_stage_requests_total").count(), 1);
        // Empty input renders nothing.
        let mut empty = String::new();
        render_session_exposition(&mut empty, &[]);
        assert!(empty.is_empty());
    }
}
