//! Observability for the valuation pipeline: per-query tracing spans,
//! atomic latency histograms, and Prometheus-style exposition.
//!
//! This module is the lock-light instrumentation substrate the serving
//! path records into (the paper frames valuation as a *service* over
//! billion-token corpora — LogIX §5 — and a service needs to answer
//! "where did this query's 40ms go?"):
//!
//! - [`trace::TraceRing`]: a bounded ring of timestamped [`SpanEvent`]s
//!   covering every stage of a query (admission → IVF probe, when an
//!   index serves → queue wait → per-shard scans → merge → rescore),
//!   exportable as Chrome trace-event JSON via
//!   [`trace::chrome_trace_json`] (`logra trace --out trace.json`).
//! - [`hist::Histogram`]: HDR-style log-bucketed atomic histograms for
//!   end-to-end query latency, queue wait, and per-shard scan time —
//!   p50/p95/p99 without per-sample allocation.
//! - [`QueryReport`]: the per-query stage breakdown attached to
//!   [`PendingScores`](crate::valuation::PendingScores) when
//!   [`BackendConfig::metrics`](crate::valuation::BackendConfig) is set
//!   (`Valuator::query_with_report` / `PendingScores::wait_with_report`).
//! - [`export::render_exposition`]: Prometheus text format over
//!   [`Metrics`](crate::coordinator::Metrics) + pool snapshot +
//!   histograms (`serve_queries --metrics`, `logra store stat --metrics`).
//!
//! One [`Obs`] instance lives inside every
//! [`Metrics`](crate::coordinator::Metrics), so opting into metrics
//! (`BackendConfig::metrics` / `ValuatorBuilder::metrics`) opts into the
//! whole layer; without it the hot path pays nothing.

pub mod export;
pub mod hist;
pub mod trace;

pub use export::{render_exposition, render_session_exposition, StageMetrics};
pub use hist::{bucket_bounds, bucket_index, Histogram, HistogramSnapshot, BUCKETS};
pub use trace::{chrome_trace_json, thread_lane, SpanEvent, TraceRing};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Span events retained by default (the "last N queries" window of
/// `logra trace`; a concurrent 8-query run over a few dozen shards emits
/// a few hundred events).
pub const DEFAULT_TRACE_CAPACITY: usize = 4096;

/// Process-lifetime observability state: the trace ring, the latency
/// histograms, the query-id counter, and the monotonic time origin all
/// spans are stamped against. Embedded in
/// [`Metrics`](crate::coordinator::Metrics) (one per service / valuator
/// session).
pub struct Obs {
    epoch: Instant,
    next_query: AtomicU64,
    /// Recent span events (bounded; oldest overwritten).
    pub trace: TraceRing,
    /// End-to-end latency of each completed query (admission → results).
    pub query_latency: Histogram,
    /// Admission-to-first-scan-task wait of each query (pool queue depth
    /// made visible; near-zero on unpooled paths).
    pub queue_wait: Histogram,
    /// Wall time of each individual `(query, shard)` scan task.
    pub shard_scan: Histogram,
}

impl Default for Obs {
    fn default() -> Self {
        Obs {
            epoch: Instant::now(),
            next_query: AtomicU64::new(0),
            trace: TraceRing::with_capacity(DEFAULT_TRACE_CAPACITY),
            query_latency: Histogram::new(),
            queue_wait: Histogram::new(),
            shard_scan: Histogram::new(),
        }
    }
}

impl Obs {
    /// Nanoseconds since this instance's epoch — the time base every
    /// [`SpanEvent`] uses.
    pub fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Next observability query id (process-wide within this `Obs`).
    pub fn next_query_id(&self) -> u64 {
        self.next_query.fetch_add(1, Ordering::Relaxed)
    }

    /// Record one completed span into the trace ring, stamped with the
    /// calling thread's lane.
    pub fn span(
        &self,
        name: &'static str,
        query: u64,
        shard: Option<u32>,
        start_nanos: u64,
        dur_nanos: u64,
    ) {
        self.trace.record(SpanEvent {
            name,
            query,
            shard,
            lane: thread_lane(),
            start_nanos,
            dur_nanos,
            seq: 0,
        });
    }
}

/// Per-query scan observer, shared between the admitting thread and the
/// scan workers (pool or scoped). Created at admission; the first scan
/// task to start stamps the queue wait; every task registers its lane so
/// the final [`QueryReport`] can show worker spread.
pub struct ScanObs {
    query: u64,
    admitted: Instant,
    admitted_nanos: u64,
    /// Elapsed nanos at which admission work (preconditioning, RelatIF
    /// cache) finished and the scan was handed to its execution substrate.
    admission_nanos: AtomicU64,
    started: AtomicBool,
    queue_wait_nanos: AtomicU64,
    lanes: Mutex<Vec<u32>>,
}

impl ScanObs {
    pub fn new(obs: &Obs) -> Self {
        ScanObs {
            query: obs.next_query_id(),
            admitted: Instant::now(),
            admitted_nanos: obs.now_nanos(),
            admission_nanos: AtomicU64::new(0),
            started: AtomicBool::new(false),
            queue_wait_nanos: AtomicU64::new(0),
            lanes: Mutex::new(Vec::new()),
        }
    }

    pub fn query(&self) -> u64 {
        self.query
    }

    /// Nanoseconds since admission.
    pub fn elapsed_nanos(&self) -> u64 {
        self.admitted.elapsed().as_nanos() as u64
    }

    /// Obs-epoch timestamp of admission (span time base).
    pub fn admitted_nanos(&self) -> u64 {
        self.admitted_nanos
    }

    /// Mark admission work done (queue wait is measured from here, so
    /// preconditioning time cannot masquerade as queue depth). Records the
    /// `"admission"` span.
    pub fn admission_done(&self, obs: &Obs) {
        let at = self.elapsed_nanos();
        self.admission_nanos.store(at, Ordering::Relaxed);
        obs.span("admission", self.query, None, self.admitted_nanos, at);
    }

    pub fn admission_nanos(&self) -> u64 {
        self.admission_nanos.load(Ordering::Relaxed)
    }

    /// Called by every scan task as it starts: registers the worker lane;
    /// the FIRST task additionally stamps the query's queue wait into the
    /// histogram and the trace — uniformly on pooled, scoped-thread, and
    /// sequential paths, so the queue-wait histogram is populated on every
    /// backend.
    pub fn task_started(&self, obs: &Obs) {
        let lane = thread_lane();
        {
            let mut lanes = self.lanes.lock().unwrap();
            if !lanes.contains(&lane) {
                lanes.push(lane);
            }
        }
        if !self.started.swap(true, Ordering::Relaxed) {
            let admission = self.admission_nanos.load(Ordering::Relaxed);
            let wait = self.elapsed_nanos().saturating_sub(admission);
            self.queue_wait_nanos.store(wait, Ordering::Relaxed);
            obs.queue_wait.record(wait);
            obs.span("queue_wait", self.query, None, self.admitted_nanos + admission, wait);
        }
    }

    /// Queue wait stamped by the first scan task (0 until one starts).
    pub fn queue_wait_nanos(&self) -> u64 {
        self.queue_wait_nanos.load(Ordering::Relaxed)
    }

    /// Distinct lanes that ran this query's scan tasks, sorted.
    pub fn lanes(&self) -> Vec<u32> {
        let mut lanes = self.lanes.lock().unwrap().clone();
        lanes.sort_unstable();
        lanes
    }
}

/// Per-query stage breakdown, returned alongside the scores when
/// [`BackendConfig::metrics`](crate::valuation::BackendConfig) is set
/// (via `PendingScores::wait_with_report` or
/// `Valuator::query_with_report`). All times are wall-clock nanoseconds;
/// the stages partition `total_nanos` (admission + queue wait + scan +
/// merge + rescore ≈ total, up to clock-read jitter).
#[derive(Clone, Debug)]
pub struct QueryReport {
    /// Observability query id (matches the trace's `query` arg).
    pub query_id: u64,
    /// Serving backend name (`"sequential"`, `"parallel-f32"`,
    /// `"two-stage"`, `"ivf"`).
    pub backend: &'static str,
    /// Shards fanned out over.
    pub shards: u32,
    /// Rows covered by the (stage-1) scan. On the IVF backend this is the
    /// PROBED row count — below the corpus row count when the index
    /// prunes.
    pub rows_scanned: u64,
    /// Rows rescored at exact precision (two-stage only; 0 elsewhere).
    pub candidates_rescored: u64,
    /// Admission work: validation, preconditioning, RelatIF cache.
    pub admission_nanos: u64,
    /// Admission-done to first scan task starting.
    pub queue_wait_nanos: u64,
    /// First scan task start to last shard result available.
    pub scan_nanos: u64,
    /// Deterministic heap merge.
    pub merge_nanos: u64,
    /// Two-stage exact rescore (0 on exact backends).
    pub rescore_nanos: u64,
    /// Admission to results.
    pub total_nanos: u64,
    /// Distinct worker lanes that ran scan tasks (worker spread).
    pub workers: Vec<u32>,
}

impl QueryReport {
    /// Human-readable multi-line stage breakdown (what `logra query`
    /// prints).
    pub fn render(&self) -> String {
        let ms = |n: u64| n as f64 / 1e6;
        let mut s = format!(
            "query {} via {} ({} shards, {} rows, {} workers)\n",
            self.query_id,
            self.backend,
            self.shards,
            self.rows_scanned,
            self.workers.len().max(1)
        );
        s.push_str(&format!("  admission  {:9.3} ms\n", ms(self.admission_nanos)));
        s.push_str(&format!("  queue wait {:9.3} ms\n", ms(self.queue_wait_nanos)));
        s.push_str(&format!("  scan       {:9.3} ms\n", ms(self.scan_nanos)));
        s.push_str(&format!("  merge      {:9.3} ms\n", ms(self.merge_nanos)));
        if self.candidates_rescored > 0 {
            s.push_str(&format!(
                "  rescore    {:9.3} ms ({} candidates)\n",
                ms(self.rescore_nanos),
                self.candidates_rescored
            ));
        }
        s.push_str(&format!("  total      {:9.3} ms\n", ms(self.total_nanos)));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_obs_stamps_queue_wait_once() {
        let obs = Obs::default();
        let so = ScanObs::new(&obs);
        assert_eq!(so.queue_wait_nanos(), 0);
        so.admission_done(&obs);
        so.task_started(&obs);
        let first = so.queue_wait_nanos();
        so.task_started(&obs);
        assert_eq!(so.queue_wait_nanos(), first, "only the first task stamps the wait");
        assert_eq!(so.lanes().len(), 1);
        assert_eq!(obs.queue_wait.snapshot().count, 1);
        // admission + queue_wait spans recorded.
        assert_eq!(obs.trace.recorded(), 2);
    }

    #[test]
    fn query_ids_are_unique() {
        let obs = Obs::default();
        let a = ScanObs::new(&obs).query();
        let b = ScanObs::new(&obs).query();
        assert_ne!(a, b);
    }

    #[test]
    fn report_renders_every_stage() {
        let r = QueryReport {
            query_id: 3,
            backend: "two-stage",
            shards: 4,
            rows_scanned: 1000,
            candidates_rescored: 40,
            admission_nanos: 1_000_000,
            queue_wait_nanos: 500_000,
            scan_nanos: 8_000_000,
            merge_nanos: 100_000,
            rescore_nanos: 2_000_000,
            total_nanos: 11_600_000,
            workers: vec![1, 2],
        };
        let text = r.render();
        for needle in ["two-stage", "admission", "queue wait", "scan", "merge", "rescore", "total"]
        {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }
}
