//! Data substrates: synthetic labelled corpora (OpenWebText / WikiText /
//! FMNIST / CIFAR stand-ins per DESIGN.md §1) and fixed-shape batching.

pub mod batcher;
pub mod corpus;
pub mod images;

pub use batcher::{epoch_order, image_batches, token_batches, ImageBatch, TokenBatch};
pub use corpus::{Corpus, CorpusSpec, VocabLayout, N_TOPICS, TOPIC_NAMES};
pub use images::{ImageSet, ImageSpec};
