//! Synthetic topic-mixture corpus (OpenWebText stand-in, DESIGN.md §1).
//!
//! Token-level generative model with *ground-truth topic labels* so the
//! paper's qualitative-accuracy experiment (Fig. 5: "do the most valuable
//! train docs resemble the query?") becomes measurable: we report the
//! topic-match rate of the top-k valued documents instead of eyeballing
//! web text.
//!
//! Model per document: draw a topic z; each position emits
//!   - with p_bg: a shared background token ~ Zipf (function words),
//!   - else: a token from topic z's exclusive vocabulary slice ~ Zipf,
//!     and with p_phrase the NEXT token continues a topic "phrase"
//!     (tok+1 in-slice), giving learnable local structure.
//! Token 0 is reserved as BOS.

use crate::util::rng::Pcg32;

pub const N_TOPICS: usize = 8;

pub const TOPIC_NAMES: [&str; N_TOPICS] = [
    "space", "finance", "cooking", "sports", "medicine", "music", "law", "gaming",
];

/// Corpus generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub seq_len: usize,
    pub n_docs: usize,
    /// Probability of emitting a shared background token.
    pub p_background: f64,
    /// Probability of continuing a topic phrase.
    pub p_phrase: f64,
    pub seed: u64,
}

impl CorpusSpec {
    pub fn new(vocab: usize, seq_len: usize, n_docs: usize, seed: u64) -> Self {
        CorpusSpec { vocab, seq_len, n_docs, p_background: 0.45, p_phrase: 0.35, seed }
    }
}

/// Vocabulary partition: background slice + per-topic exclusive slices.
#[derive(Clone, Debug)]
pub struct VocabLayout {
    pub vocab: usize,
    pub bg_start: usize,
    pub bg_len: usize,
    pub topic_len: usize,
}

impl VocabLayout {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab >= 1 + N_TOPICS * 4, "vocab too small");
        let usable = vocab - 1; // token 0 = BOS
        let bg_len = usable / 4;
        let topic_len = (usable - bg_len) / N_TOPICS;
        VocabLayout { vocab, bg_start: 1, bg_len, topic_len }
    }

    pub fn topic_start(&self, topic: usize) -> usize {
        self.bg_start + self.bg_len + topic * self.topic_len
    }

    /// Which topic's exclusive slice a token belongs to (None = BOS/bg or
    /// leftover tail tokens).
    pub fn topic_of_token(&self, tok: i32) -> Option<usize> {
        let t = tok as usize;
        let first = self.bg_start + self.bg_len;
        if t < first {
            return None;
        }
        let idx = (t - first) / self.topic_len;
        if idx < N_TOPICS {
            Some(idx)
        } else {
            None
        }
    }

    /// Human-readable pseudo-word for a token (qualitative displays).
    pub fn word(&self, tok: i32) -> String {
        if tok == 0 {
            return "<bos>".into();
        }
        match self.topic_of_token(tok) {
            Some(t) => {
                let start = self.topic_start(t);
                format!("{}{}", TOPIC_NAMES[t], tok as usize - start)
            }
            None => format!("the{}", tok),
        }
    }
}

/// One generated document.
#[derive(Clone, Debug)]
pub struct Doc {
    pub id: u64,
    pub topic: usize,
    pub tokens: Vec<i32>,
}

/// The full labelled corpus.
pub struct Corpus {
    pub layout: VocabLayout,
    pub docs: Vec<Doc>,
    pub seq_len: usize,
}

/// Zipf-ish sample in [0, n): index floor(n * u^alpha) with alpha > 1
/// concentrating mass on small indices.
fn zipfish(rng: &mut Pcg32, n: usize) -> usize {
    let u = rng.uniform();
    ((u * u * u) * n as f64) as usize % n.max(1)
}

pub fn generate(spec: CorpusSpec) -> Corpus {
    let layout = VocabLayout::new(spec.vocab);
    let mut rng = Pcg32::new(spec.seed, 17);
    let mut docs = Vec::with_capacity(spec.n_docs);
    for id in 0..spec.n_docs {
        let topic = rng.below_usize(N_TOPICS);
        let tokens = generate_doc(&layout, &spec, &mut rng, topic);
        docs.push(Doc { id: id as u64, topic, tokens });
    }
    Corpus { layout, docs, seq_len: spec.seq_len }
}

/// Generate a single document for a given topic (also used for queries).
pub fn generate_doc(
    layout: &VocabLayout,
    spec: &CorpusSpec,
    rng: &mut Pcg32,
    topic: usize,
) -> Vec<i32> {
    let mut toks = Vec::with_capacity(spec.seq_len);
    toks.push(0); // BOS
    let tstart = layout.topic_start(topic);
    let mut phrase_prev: Option<usize> = None;
    while toks.len() < spec.seq_len {
        if let Some(prev) = phrase_prev {
            // Continue the phrase: next in-slice token.
            let next = tstart + (prev - tstart + 1) % layout.topic_len;
            toks.push(next as i32);
            phrase_prev =
                if rng.uniform() < spec.p_phrase { Some(next) } else { None };
            continue;
        }
        if rng.uniform() < spec.p_background {
            toks.push((layout.bg_start + zipfish(rng, layout.bg_len)) as i32);
        } else {
            let t = tstart + zipfish(rng, layout.topic_len);
            toks.push(t as i32);
            if rng.uniform() < spec.p_phrase {
                phrase_prev = Some(t);
            }
        }
    }
    toks
}

impl Corpus {
    /// Majority-topic guess for an arbitrary token sequence (used to label
    /// model-generated queries and to score topic-match of retrievals).
    pub fn infer_topic(&self, tokens: &[i32]) -> Option<usize> {
        let mut counts = [0usize; N_TOPICS];
        for &t in tokens {
            if let Some(z) = self.layout.topic_of_token(t) {
                counts[z] += 1;
            }
        }
        let (best, &cnt) =
            counts.iter().enumerate().max_by_key(|(_, &c)| c).unwrap();
        if cnt == 0 {
            None
        } else {
            Some(best)
        }
    }

    /// Render a token sequence as pseudo-words.
    pub fn render(&self, tokens: &[i32]) -> String {
        tokens.iter().map(|&t| self.layout.word(t)).collect::<Vec<_>>().join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CorpusSpec {
        CorpusSpec::new(256, 32, 200, 42)
    }

    #[test]
    fn generates_requested_shape() {
        let c = generate(spec());
        assert_eq!(c.docs.len(), 200);
        for d in &c.docs {
            assert_eq!(d.tokens.len(), 32);
            assert_eq!(d.tokens[0], 0);
            assert!(d.tokens.iter().all(|&t| (t as usize) < 256));
            assert!(d.topic < N_TOPICS);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(spec());
        let b = generate(spec());
        assert_eq!(a.docs[7].tokens, b.docs[7].tokens);
        let mut s2 = spec();
        s2.seed = 43;
        let c = generate(s2);
        assert_ne!(a.docs[7].tokens, c.docs[7].tokens);
    }

    #[test]
    fn topic_slices_disjoint_and_inferable() {
        let c = generate(spec());
        let mut correct = 0;
        for d in &c.docs {
            // Tokens from OTHER topics' slices must not appear.
            for &t in &d.tokens {
                if let Some(z) = c.layout.topic_of_token(t) {
                    assert_eq!(z, d.topic, "cross-topic token leak");
                }
            }
            if c.infer_topic(&d.tokens) == Some(d.topic) {
                correct += 1;
            }
        }
        assert!(correct >= 195, "topic inference too weak: {correct}/200");
    }

    #[test]
    fn all_topics_represented() {
        let c = generate(spec());
        let mut seen = [false; N_TOPICS];
        for d in &c.docs {
            seen[d.topic] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn words_render_topics() {
        let c = generate(spec());
        let layout = &c.layout;
        let t3 = layout.topic_start(3) as i32;
        assert!(layout.word(t3).starts_with(TOPIC_NAMES[3]));
        assert_eq!(layout.word(0), "<bos>");
        let rendered = c.render(&c.docs[0].tokens);
        assert!(rendered.contains(' '));
    }

    #[test]
    fn background_tokens_shared_across_topics() {
        let c = generate(spec());
        let mut bg_seen_in = [false; N_TOPICS];
        for d in &c.docs {
            if d.tokens.iter().any(|&t| {
                (t as usize) >= c.layout.bg_start
                    && (t as usize) < c.layout.bg_start + c.layout.bg_len
            }) {
                bg_seen_in[d.topic] = true;
            }
        }
        assert!(bg_seen_in.iter().all(|&s| s));
    }
}
