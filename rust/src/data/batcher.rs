//! Fixed-shape batching over datasets.
//!
//! AOT artifacts are closed over a static batch size, so the batcher pads
//! ragged tails by repeating the last real example and reports `real` so
//! downstream stages (store writer, Hessian accumulation) skip pad rows —
//! no example is ever dropped or double-counted.

use super::corpus::Corpus;
use super::images::ImageSet;
use crate::util::rng::Pcg32;

/// One batch of LM sequences.
#[derive(Clone, Debug)]
pub struct TokenBatch {
    pub ids: Vec<u64>,
    /// Row-major [batch, seq_len] i32.
    pub tokens: Vec<i32>,
    /// Number of non-pad rows (<= batch).
    pub real: usize,
}

/// One batch of images.
#[derive(Clone, Debug)]
pub struct ImageBatch {
    pub ids: Vec<u64>,
    /// Row-major [batch, dim] f32.
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
    pub real: usize,
}

/// Iterate a subset of corpus docs (by index) in fixed-size batches.
pub fn token_batches(corpus: &Corpus, indices: &[usize], batch: usize) -> Vec<TokenBatch> {
    let seq = corpus.seq_len;
    let mut out = Vec::new();
    let mut at = 0;
    while at < indices.len() {
        let real = (indices.len() - at).min(batch);
        let mut ids = Vec::with_capacity(batch);
        let mut tokens = Vec::with_capacity(batch * seq);
        for row in 0..batch {
            let src = indices[at + row.min(real - 1)];
            let doc = &corpus.docs[src];
            ids.push(doc.id);
            tokens.extend_from_slice(&doc.tokens[..seq]);
        }
        out.push(TokenBatch { ids, tokens, real });
        at += real;
    }
    out
}

/// Iterate an image subset in fixed-size batches.
pub fn image_batches(set: &ImageSet, indices: &[usize], batch: usize) -> Vec<ImageBatch> {
    let dim = set.dim;
    let mut out = Vec::new();
    let mut at = 0;
    while at < indices.len() {
        let real = (indices.len() - at).min(batch);
        let mut ids = Vec::with_capacity(batch);
        let mut features = Vec::with_capacity(batch * dim);
        let mut labels = Vec::with_capacity(batch);
        for row in 0..batch {
            let src = indices[at + row.min(real - 1)];
            ids.push(set.ids[src]);
            features.extend_from_slice(set.feature_row(src));
            labels.push(set.labels[src]);
        }
        out.push(ImageBatch { ids, features, labels, real });
        at += real;
    }
    out
}

/// Shuffled epoch order over `n` examples.
pub fn epoch_order(n: usize, rng: &mut Pcg32) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{generate, CorpusSpec};
    use crate::data::images::{generate as gen_images, ImageSpec};

    #[test]
    fn token_batches_cover_exactly_once() {
        let c = generate(CorpusSpec::new(256, 16, 37, 1));
        let indices: Vec<usize> = (0..37).collect();
        let batches = token_batches(&c, &indices, 8);
        assert_eq!(batches.len(), 5);
        let mut seen = Vec::new();
        for b in &batches {
            assert_eq!(b.ids.len(), 8);
            assert_eq!(b.tokens.len(), 8 * 16);
            seen.extend_from_slice(&b.ids[..b.real]);
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(seen.len(), 37);
        assert_eq!(sorted.len(), 37);
    }

    #[test]
    fn pad_rows_repeat_last_real() {
        let c = generate(CorpusSpec::new(256, 16, 10, 2));
        let indices: Vec<usize> = (0..10).collect();
        let batches = token_batches(&c, &indices, 8);
        let last = &batches[1];
        assert_eq!(last.real, 2);
        // Rows 2..8 repeat row index 1's doc.
        for r in 2..8 {
            assert_eq!(
                &last.tokens[r * 16..(r + 1) * 16],
                &last.tokens[16..32]
            );
        }
    }

    #[test]
    fn image_batches_shapes() {
        let s = gen_images(ImageSpec::fmnist_like(12, 3, 20, 5));
        let idx: Vec<usize> = (0..20).collect();
        let batches = image_batches(&s, &idx, 16);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].features.len(), 16 * 12);
        assert_eq!(batches[1].real, 4);
    }

    #[test]
    fn property_batching_never_drops_or_dups() {
        crate::util::proptest::check("batcher-cover", 30, |g| {
            let n = 1 + g.int_in(0, 100);
            let batch = 1 + g.int_in(0, 16);
            let c = generate(CorpusSpec::new(256, 8, n, g.rng.next_u64()));
            let mut indices: Vec<usize> = (0..n).collect();
            g.rng.shuffle(&mut indices);
            let batches = token_batches(&c, &indices, batch);
            let mut seen: Vec<u64> =
                batches.iter().flat_map(|b| b.ids[..b.real].to_vec()).collect();
            crate::prop_assert!(seen.len() == n, "saw {} of {n}", seen.len());
            seen.sort_unstable();
            seen.dedup();
            crate::prop_assert!(seen.len() == n, "dups: {} unique of {n}", seen.len());
            for b in &batches {
                crate::prop_assert!(b.ids.len() == batch, "ragged batch");
                crate::prop_assert!(b.real >= 1 && b.real <= batch, "bad real");
            }
            Ok(())
        });
    }

    #[test]
    fn epoch_order_is_permutation() {
        let mut rng = Pcg32::seeded(1);
        let o = epoch_order(50, &mut rng);
        let mut s = o.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
