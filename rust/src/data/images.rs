//! Synthetic class-prototype image generator (FMNIST/CIFAR stand-in).
//!
//! Each class c gets a fixed prototype vector; a sample is
//! `normalize(prototype + nuisance + sigma * noise)` where the nuisance is
//! a shared low-rank component (class-uninformative structure, so the
//! model cannot solve the task with a single linear probe direction).
//! A configurable fraction of labels is flipped — mislabeled points are
//! exactly the high-influence examples the brittleness test should find.

use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug)]
pub struct ImageSpec {
    pub dim: usize,
    pub classes: usize,
    pub n: usize,
    /// Per-sample isotropic noise scale. FMNIST-like ~0.6 (separable),
    /// CIFAR-like ~1.1 (harder).
    pub sigma: f32,
    /// Rank of the shared nuisance subspace.
    pub nuisance_rank: usize,
    /// Fraction of flipped labels.
    pub label_noise: f64,
    pub seed: u64,
}

impl ImageSpec {
    pub fn fmnist_like(dim: usize, classes: usize, n: usize, seed: u64) -> Self {
        ImageSpec { dim, classes, n, sigma: 0.6, nuisance_rank: 4, label_noise: 0.02, seed }
    }

    pub fn cifar_like(dim: usize, classes: usize, n: usize, seed: u64) -> Self {
        ImageSpec { dim, classes, n, sigma: 1.1, nuisance_rank: 8, label_noise: 0.04, seed }
    }
}

/// A labelled vision dataset (features flattened).
pub struct ImageSet {
    pub dim: usize,
    pub classes: usize,
    /// Row-major [n, dim].
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
    /// True (pre-flip) labels, for analysis.
    pub clean_labels: Vec<i32>,
    pub ids: Vec<u64>,
}

impl ImageSet {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn feature_row(&self, i: usize) -> &[f32] {
        &self.features[i * self.dim..(i + 1) * self.dim]
    }

    pub fn is_mislabeled(&self, i: usize) -> bool {
        self.labels[i] != self.clean_labels[i]
    }
}

pub fn generate(spec: ImageSpec) -> ImageSet {
    // Prototypes and nuisance basis depend only on (seed, dim, classes):
    // train and test sets generated with different `n`/stream share them.
    let mut proto_rng = Pcg32::new(spec.seed, 101);
    let mut prototypes = vec![0.0f32; spec.classes * spec.dim];
    proto_rng.fill_normal(&mut prototypes, 1.0);
    let mut nuisance = vec![0.0f32; spec.nuisance_rank * spec.dim];
    proto_rng.fill_normal(&mut nuisance, 1.0);

    let mut rng = Pcg32::new(spec.seed, 202);
    let mut features = vec![0.0f32; spec.n * spec.dim];
    let mut labels = Vec::with_capacity(spec.n);
    let mut clean = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let c = rng.below_usize(spec.classes);
        clean.push(c as i32);
        let y = if rng.uniform() < spec.label_noise {
            // Flip to a different class.
            let mut alt = rng.below_usize(spec.classes);
            if alt == c {
                alt = (alt + 1) % spec.classes;
            }
            alt as i32
        } else {
            c as i32
        };
        labels.push(y);
        let row = &mut features[i * spec.dim..(i + 1) * spec.dim];
        let proto = &prototypes[c * spec.dim..(c + 1) * spec.dim];
        // Low-rank nuisance with random per-sample coefficients.
        let mut coeffs = vec![0.0f32; spec.nuisance_rank];
        rng.fill_normal(&mut coeffs, 0.8);
        for (d, out) in row.iter_mut().enumerate() {
            let mut v = proto[d];
            for (r, &cf) in coeffs.iter().enumerate() {
                v += cf * nuisance[r * spec.dim + d];
            }
            v += rng.normal_f32() * spec.sigma;
            *out = v / (spec.dim as f32).sqrt() * 4.0; // keep features O(1)
        }
    }
    ImageSet {
        dim: spec.dim,
        classes: spec.classes,
        features,
        labels,
        clean_labels: clean,
        ids: (0..spec.n as u64).collect(),
    }
}

/// Generate an i.i.d. evaluation split that shares prototypes with `spec`
/// (same seed) but uses an independent sample stream and no label noise.
pub fn generate_eval(mut spec: ImageSpec, n: usize) -> ImageSet {
    spec.n = n;
    spec.label_noise = 0.0;
    let mut set = generate(ImageSpec { seed: spec.seed, ..spec });
    // Re-draw with a shifted sample stream so eval != train rows.
    let mut rng = Pcg32::new(spec.seed, 909);
    let mut proto_rng = Pcg32::new(spec.seed, 101);
    let mut prototypes = vec![0.0f32; spec.classes * spec.dim];
    proto_rng.fill_normal(&mut prototypes, 1.0);
    let mut nuisance = vec![0.0f32; spec.nuisance_rank * spec.dim];
    proto_rng.fill_normal(&mut nuisance, 1.0);
    for i in 0..n {
        let c = rng.below_usize(spec.classes);
        set.labels[i] = c as i32;
        set.clean_labels[i] = c as i32;
        let row = &mut set.features[i * spec.dim..(i + 1) * spec.dim];
        let proto = &prototypes[c * spec.dim..(c + 1) * spec.dim];
        let mut coeffs = vec![0.0f32; spec.nuisance_rank];
        rng.fill_normal(&mut coeffs, 0.8);
        for (d, out) in row.iter_mut().enumerate() {
            let mut v = proto[d];
            for (r, &cf) in coeffs.iter().enumerate() {
                v += cf * nuisance[r * spec.dim + d];
            }
            v += rng.normal_f32() * spec.sigma;
            *out = v / (spec.dim as f32).sqrt() * 4.0;
        }
    }
    set
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cosine;

    #[test]
    fn shapes_and_determinism() {
        let spec = ImageSpec::fmnist_like(64, 10, 100, 1);
        let a = generate(spec);
        let b = generate(spec);
        assert_eq!(a.len(), 100);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
        assert!(a.labels.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn same_class_more_similar_than_cross_class() {
        let spec = ImageSpec::fmnist_like(128, 4, 400, 7);
        let s = generate(spec);
        let (mut same, mut cross) = (Vec::new(), Vec::new());
        for i in 0..80 {
            for j in (i + 1)..80 {
                let c = cosine(s.feature_row(i), s.feature_row(j)) as f64;
                if s.clean_labels[i] == s.clean_labels[j] {
                    same.push(c);
                } else {
                    cross.push(c);
                }
            }
        }
        let m_same = crate::util::stats::mean(&same);
        let m_cross = crate::util::stats::mean(&cross);
        assert!(m_same > m_cross + 0.1, "same={m_same} cross={m_cross}");
    }

    #[test]
    fn label_noise_rate_close_to_spec() {
        let spec = ImageSpec { label_noise: 0.1, ..ImageSpec::fmnist_like(32, 10, 4000, 3) };
        let s = generate(spec);
        let flipped = (0..s.len()).filter(|&i| s.is_mislabeled(i)).count();
        let rate = flipped as f64 / s.len() as f64;
        assert!((rate - 0.1).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn eval_split_differs_but_same_prototypes() {
        let spec = ImageSpec::fmnist_like(64, 4, 50, 9);
        let train = generate(spec);
        let eval = generate_eval(spec, 50);
        assert_ne!(train.features, eval.features);
        // Eval class means should correlate with train class means.
        for c in 0..4 {
            let mean_of = |s: &ImageSet| {
                let mut m = vec![0.0f32; s.dim];
                let mut n = 0;
                for i in 0..s.len() {
                    if s.clean_labels[i] == c as i32 {
                        for (d, v) in s.feature_row(i).iter().enumerate() {
                            m[d] += v;
                        }
                        n += 1;
                    }
                }
                for v in m.iter_mut() {
                    *v /= n.max(1) as f32;
                }
                m
            };
            let sim = cosine(&mean_of(&train), &mean_of(&eval));
            assert!(sim > 0.5, "class {c}: {sim}");
        }
    }
}
