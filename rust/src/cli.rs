//! Command-line argument parser (offline stand-in for `clap`).
//!
//! Supports `program SUBCOMMAND --flag value --switch positional...` with
//! typed accessors, defaults, and an auto-generated usage string.

use std::collections::BTreeMap;

/// Declarative flag spec used for usage text and validation.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

/// Parse `argv[1..]`. Flags listed in `value_flags` consume the following
/// token; every other `--x` is a boolean switch.
pub fn parse(argv: &[String], value_flags: &[&str]) -> anyhow::Result<Args> {
    let mut out = Args::default();
    let mut i = 0;
    if i < argv.len() && !argv[i].starts_with("--") {
        out.subcommand = argv[i].clone();
        i += 1;
    }
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(name) = tok.strip_prefix("--") {
            // Support --name=value too.
            if let Some((n, v)) = name.split_once('=') {
                out.flags.insert(n.to_string(), v.to_string());
            } else if value_flags.contains(&name) {
                i += 1;
                let v = argv.get(i).ok_or_else(|| {
                    anyhow::anyhow!("flag --{name} expects a value")
                })?;
                out.flags.insert(name.to_string(), v.clone());
            } else {
                out.switches.push(name.to_string());
            }
        } else {
            out.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(out)
}

/// The shared `--backend / --nprobe / --rescore-factor / --workers`
/// quartet that `logra query`, `trace`, and `serve` all accept — parsed
/// once, resolved against the store fabric's auto-detected kind so the
/// three subcommands cannot drift apart in how they spell backend
/// selection.
#[derive(Clone, Debug)]
pub struct BackendArgs {
    /// Wire name: `auto | exact | quantized | ann`.
    pub backend: String,
    /// IVF stage-0 clusters probed per shard.
    pub nprobe: usize,
    /// Stage-1 candidate pool multiplier (two-stage / IVF).
    pub rescore_factor: usize,
    /// Scan workers (0 = auto).
    pub workers: usize,
}

impl BackendArgs {
    pub fn from_args(args: &Args) -> anyhow::Result<BackendArgs> {
        Ok(BackendArgs {
            backend: args.flag_or("backend", "auto"),
            nprobe: args.usize_or("nprobe", 4)?,
            rescore_factor: args.usize_or("rescore-factor", 4)?,
            workers: args.usize_or("workers", 0)?,
        })
    }

    /// Resolve the wire name to a [`Backend`](crate::valuation::Backend),
    /// spelling `auto` out against what the fabric would auto-select so
    /// `--rescore-factor` / `--nprobe` are honored instead of silently
    /// falling back to the builder defaults.
    pub fn resolve(
        &self,
        auto_kind: crate::valuation::BackendKind,
    ) -> anyhow::Result<crate::valuation::Backend> {
        use crate::valuation::{Backend, BackendKind};
        match self.backend.as_str() {
            "auto" => Ok(match auto_kind {
                BackendKind::TwoStage => {
                    Backend::Quantized { rescore_factor: self.rescore_factor }
                }
                BackendKind::Ivf => Backend::Ann {
                    nprobe: self.nprobe,
                    rescore_factor: self.rescore_factor,
                },
                _ => Backend::Auto,
            }),
            "exact" => Ok(Backend::Exact),
            "quantized" => Ok(Backend::Quantized { rescore_factor: self.rescore_factor }),
            "ann" => Ok(Backend::Ann {
                nprobe: self.nprobe,
                rescore_factor: self.rescore_factor,
            }),
            other => Err(anyhow::anyhow!(
                "unknown backend {other:?}; try auto|exact|quantized|ann"
            )),
        }
    }
}

/// Render a usage block for `--help`.
pub fn usage(program: &str, subcommands: &[(&str, &str)], flags: &[FlagSpec]) -> String {
    let mut s = format!("usage: {program} <command> [flags]\n\ncommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<18} {help}\n"));
    }
    s.push_str("\nflags:\n");
    for f in flags {
        let arg = if f.takes_value { format!("--{} <v>", f.name) } else { format!("--{}", f.name) };
        let def = f.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  {arg:<22} {}{def}\n", f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse(
            &v(&["log", "--config", "c.toml", "--verbose", "extra"]),
            &["config"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "log");
        assert_eq!(a.flag("config"), Some("c.toml"));
        assert!(a.has_switch("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse(&v(&["run", "--n=42"]), &[]).unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&v(&["run", "--config"]), &["config"]).is_err());
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&v(&["x", "--k=8", "--damp=0.1"]), &[]).unwrap();
        assert_eq!(a.usize_or("k", 1).unwrap(), 8);
        assert!((a.f64_or("damp", 0.0).unwrap() - 0.1).abs() < 1e-12);
        assert_eq!(a.usize_or("absent", 7).unwrap(), 7);
        assert!(a.usize_or("damp", 1).is_err());
    }

    #[test]
    fn backend_args_resolve_against_the_fabric() {
        use crate::valuation::{Backend, BackendKind};
        let a = parse(
            &v(&["query", "--backend", "ann", "--nprobe", "3", "--rescore-factor", "7"]),
            &["backend", "nprobe", "rescore-factor"],
        )
        .unwrap();
        let ba = BackendArgs::from_args(&a).unwrap();
        assert_eq!(ba.workers, 0);
        assert_eq!(
            ba.resolve(BackendKind::Sequential).unwrap(),
            Backend::Ann { nprobe: 3, rescore_factor: 7 }
        );

        // `auto` spells out what the fabric would pick, carrying the
        // tuning flags along.
        let auto = BackendArgs::from_args(&parse(&v(&["query"]), &[]).unwrap()).unwrap();
        assert_eq!(auto.resolve(BackendKind::Parallel).unwrap(), Backend::Auto);
        assert_eq!(
            auto.resolve(BackendKind::TwoStage).unwrap(),
            Backend::Quantized { rescore_factor: 4 }
        );
        assert_eq!(
            auto.resolve(BackendKind::Ivf).unwrap(),
            Backend::Ann { nprobe: 4, rescore_factor: 4 }
        );

        let bogus = BackendArgs { backend: "bogus".into(), ..auto };
        assert!(bogus.resolve(BackendKind::Parallel).is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage(
            "logra",
            &[("log", "run logging phase")],
            &[FlagSpec { name: "config", help: "config path", takes_value: true, default: Some("configs/lm_tiny.toml") }],
        );
        assert!(u.contains("log"));
        assert!(u.contains("--config"));
        assert!(u.contains("default"));
    }
}
