//! Multi-stage valuation sessions: one query scored across many
//! checkpoints over ONE shared scan pool.
//!
//! The paper's pipeline is single-checkpoint, but the question users
//! actually ask — "which *pretraining* data mattered for this *finetuned*
//! behavior?" — spans stages. "Scalable Multi-Stage Influence Function
//! for LLMs" (PAPERS.md) gives the recipe this module executes: per-stage
//! influence with a per-stage (optionally EKFAC-parameterized)
//! preconditioner, combined across checkpoints. A [`Session`] opens
//! SEVERAL gradient stores — different checkpoints, or pretrain +
//! finetune stages — as named stages from one `session.json` manifest,
//! builds one [`Valuator`] per stage over
//! [`PoolMode::Shared`](crate::valuation::PoolMode), and fans a single
//! [`QueryRequest`] out through the existing `query_async` seam so every
//! stage's shard tasks interleave on the SAME warm workers (the pool's
//! worker count does not grow with the stage count).
//!
//! # `session.json`
//!
//! ```text
//! {
//!   "version": 1,
//!   "stages": [
//!     {"name": "pretrain", "dir": "stage-pt", "weight": 1.0},
//!     {"name": "finetune", "dir": "stage-ft", "weight": 0.5,
//!      "backend": "auto", "damping": 0.1,
//!      "preconditioner": "ekfac", "norm": "none"}
//!   ]
//! }
//! ```
//!
//! Per stage: `name` + `dir` (relative dirs resolve against the session
//! directory) are required; `weight` defaults to 1.0; `backend`
//! (`auto|exact|quantized|ann`) picks the per-request route the stage's
//! queries default to, validated against the stage's fabric at open;
//! `damping` (default 0.1) feeds the store-side preconditioner fit;
//! `preconditioner` is `fisher` (default) or `ekfac`
//! ([`ValuatorBuilder::fit_ekfac_from_store`](crate::valuation::ValuatorBuilder::fit_ekfac_from_store));
//! `norm` is `none` (default) or `relatif`. Unknown fields — top-level or
//! per-stage — are rejected with typed [`SessionError`]s, not silently
//! ignored: a manifest field the reader does not understand could change
//! scoring semantics.
//!
//! # Combining stages
//!
//! [`Combine`] picks how per-stage rankings merge into the combined one:
//! weighted score sums ([`Combine::WeightedSum`] — only defined when
//! every stage shares one normalization, validated at open, since raw
//! influence and ℓ-RelatIF scores are not on a common scale), Borda rank
//! aggregation ([`Combine::RankAggregation`] — scale-free, so
//! mixed-normalization sessions can still combine), or none
//! ([`Combine::PerStageOnly`]). Zero-weight stages still report their
//! per-stage top-k but contribute nothing to the combined ranking — with
//! weights `{1.0, 0.0}` the combined ranking IS stage 0's, bit-identical
//! (`rust/tests/session.rs`).
//!
//! Each stage keeps its own codec auto-detection, generation, and
//! quarantine state; the serve layer (`logra serve --session`) pins each
//! stage to its own generation snapshot at admission and reloads stages
//! independently via the existing `Slot` machinery.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::obs::QueryReport;
use crate::util::json::{self, Json};
use crate::valuation::{
    Backend, BackendChoice, Normalization, PendingScores, PoolMode, QueryRequest, QueryResult,
    ScanBackend, ScanPool, ValuationError, Valuator,
};

/// Manifest file name inside a session directory.
pub const SESSION_MANIFEST: &str = "session.json";

/// The one manifest version this reader understands.
pub const SESSION_VERSION: u64 = 1;

// ------------------------------------------------------------------ errors

/// Typed error for the session API, split by who must act.
#[derive(Clone, Debug)]
pub enum SessionError {
    /// `session.json` is missing, unreadable, or structurally malformed
    /// (including unknown fields); fix the manifest.
    Manifest { dir: PathBuf, message: String },
    /// The manifest parsed but the session can never serve (duplicate
    /// stage names, mixed normalization under weighted-sum, mismatched
    /// gradient widths); fix the configuration.
    InvalidConfig(String),
    /// One stage failed to open or to serve a query; the error names it.
    Stage { stage: String, source: ValuationError },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Manifest { dir, message } => {
                write!(f, "session manifest {}: {message}", dir.join(SESSION_MANIFEST).display())
            }
            SessionError::InvalidConfig(m) => write!(f, "invalid session config: {m}"),
            SessionError::Stage { stage, source } => {
                write!(f, "session stage {stage:?}: {source}")
            }
        }
    }
}

impl std::error::Error for SessionError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SessionError::Stage { source, .. } => Some(source),
            _ => None,
        }
    }
}

fn manifest_err(dir: &Path, message: impl Into<String>) -> SessionError {
    SessionError::Manifest { dir: dir.to_path_buf(), message: message.into() }
}

// ----------------------------------------------------------------- combine

/// Rank-aggregation rule for [`Combine::RankAggregation`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankRule {
    /// Borda count: rank `r` (0-based) in a stage's top-`K` list earns
    /// `K - r` points, scaled by the stage weight; absent ids earn 0.
    Borda,
}

/// How per-stage rankings merge into the session's combined ranking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Combine {
    /// Per data id, the weighted sum of its per-stage influence scores
    /// over the stages whose top-k lists contain it (positive-weight
    /// stages only). Only defined when every stage shares one
    /// normalization — validated at [`Session::open`].
    WeightedSum,
    /// Scale-free rank aggregation over the per-stage top-k lists.
    RankAggregation(RankRule),
    /// No combined ranking: per-stage results only.
    PerStageOnly,
}

impl Combine {
    /// Parse the CLI/wire name: `weighted-sum | borda | per-stage`.
    pub fn parse(s: &str) -> Option<Combine> {
        match s {
            "weighted-sum" => Some(Combine::WeightedSum),
            "borda" => Some(Combine::RankAggregation(RankRule::Borda)),
            "per-stage" => Some(Combine::PerStageOnly),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Combine::WeightedSum => "weighted-sum",
            Combine::RankAggregation(RankRule::Borda) => "borda",
            Combine::PerStageOnly => "per-stage",
        }
    }
}

// ---------------------------------------------------------------- manifest

/// Which store-side preconditioner fit a stage uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondKind {
    /// Exact projected Fisher from the stored rows (the default).
    Fisher,
    /// Fisher eigenbasis with EKFAC-corrected eigenvalues
    /// (`ValuatorBuilder::fit_ekfac_from_store`).
    Ekfac,
}

impl PrecondKind {
    pub fn parse(s: &str) -> Option<PrecondKind> {
        match s {
            "fisher" => Some(PrecondKind::Fisher),
            "ekfac" => Some(PrecondKind::Ekfac),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PrecondKind::Fisher => "fisher",
            PrecondKind::Ekfac => "ekfac",
        }
    }
}

/// One stage entry of `session.json`.
#[derive(Clone, Debug)]
pub struct StageSpec {
    /// Stage name — the key per-request `"stages"` subsets and metric
    /// labels use. Unique within the session.
    pub name: String,
    /// Store directory; relative paths resolve against the session dir.
    pub dir: PathBuf,
    /// Combined-ranking weight (>= 0, finite; default 1.0). Weight 0
    /// excludes the stage from combined rankings without dropping its
    /// per-stage results.
    pub weight: f64,
    /// Default per-request backend route for this stage's queries
    /// (`None` = the stage valuator's auto resolution).
    pub backend: Option<BackendChoice>,
    /// Damping factor for the store-side preconditioner fit.
    pub damping: f32,
    /// Store-side preconditioner flavor.
    pub preconditioner: PrecondKind,
    /// Stage default normalization.
    pub norm: Normalization,
}

impl StageSpec {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            ("dir".to_string(), Json::Str(self.dir.to_string_lossy().into_owned())),
            ("weight".to_string(), Json::Float(self.weight)),
        ];
        if let Some(b) = self.backend {
            pairs.push(("backend".to_string(), Json::Str(b.name().to_string())));
        }
        pairs.push(("damping".to_string(), Json::Float(self.damping as f64)));
        if self.preconditioner != PrecondKind::Fisher {
            pairs.push((
                "preconditioner".to_string(),
                Json::Str(self.preconditioner.name().to_string()),
            ));
        }
        if self.norm != Normalization::None {
            pairs.push(("norm".to_string(), Json::Str("relatif".to_string())));
        }
        Json::Obj(pairs)
    }
}

/// Convenience constructor for the common "name + dir, defaults for the
/// rest" stage entry (tests, offline CI sessions).
pub fn stage_spec(name: &str, dir: impl Into<PathBuf>) -> StageSpec {
    StageSpec {
        name: name.to_string(),
        dir: dir.into(),
        weight: 1.0,
        backend: None,
        damping: 0.1,
        preconditioner: PrecondKind::Fisher,
        norm: Normalization::None,
    }
}

/// Parsed `session.json`.
#[derive(Clone, Debug)]
pub struct SessionManifest {
    pub version: u64,
    pub stages: Vec<StageSpec>,
}

impl SessionManifest {
    /// Parse the manifest text. Unknown fields anywhere are rejected: a
    /// field this reader does not understand could change scoring
    /// semantics, and silently ignoring it would misreport results.
    pub fn parse(dir: &Path, text: &str) -> Result<SessionManifest, SessionError> {
        let v = json::parse(text).map_err(|e| manifest_err(dir, format!("{e:#}")))?;
        let Json::Obj(pairs) = &v else {
            return Err(manifest_err(dir, "top level must be an object"));
        };
        for (key, _) in pairs {
            if key != "version" && key != "stages" {
                return Err(manifest_err(dir, format!("unknown top-level field {key:?}")));
            }
        }
        let version = v
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| manifest_err(dir, "missing numeric \"version\""))?;
        if version != SESSION_VERSION {
            return Err(manifest_err(
                dir,
                format!("version {version} unsupported (this reader understands {SESSION_VERSION})"),
            ));
        }
        let stages_json = v
            .get("stages")
            .and_then(Json::as_arr)
            .ok_or_else(|| manifest_err(dir, "missing \"stages\" array"))?;
        if stages_json.is_empty() {
            return Err(manifest_err(dir, "\"stages\" must name at least one stage"));
        }
        let mut stages = Vec::with_capacity(stages_json.len());
        for (i, sj) in stages_json.iter().enumerate() {
            stages.push(parse_stage(dir, i, sj)?);
        }
        for (i, s) in stages.iter().enumerate() {
            if stages[..i].iter().any(|p| p.name == s.name) {
                return Err(manifest_err(dir, format!("duplicate stage name {:?}", s.name)));
            }
        }
        Ok(SessionManifest { version, stages })
    }

    /// Load `<dir>/session.json`.
    pub fn load(dir: &Path) -> Result<SessionManifest, SessionError> {
        let path = dir.join(SESSION_MANIFEST);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| manifest_err(dir, format!("read: {e}")))?;
        SessionManifest::parse(dir, &text)
    }

    /// Render back to manifest JSON.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("version".to_string(), Json::Num(self.version)),
            (
                "stages".to_string(),
                Json::Arr(self.stages.iter().map(StageSpec::to_json).collect()),
            ),
        ])
    }

    /// Write `<dir>/session.json` (what the offline CI fixture and tests
    /// use to author sessions).
    pub fn save(&self, dir: &Path) -> Result<(), SessionError> {
        std::fs::create_dir_all(dir).map_err(|e| manifest_err(dir, format!("mkdir: {e}")))?;
        std::fs::write(dir.join(SESSION_MANIFEST), self.to_json().render())
            .map_err(|e| manifest_err(dir, format!("write: {e}")))
    }
}

const STAGE_FIELDS: [&str; 7] =
    ["name", "dir", "weight", "backend", "damping", "preconditioner", "norm"];

fn parse_stage(dir: &Path, i: usize, sj: &Json) -> Result<StageSpec, SessionError> {
    let Json::Obj(pairs) = sj else {
        return Err(manifest_err(dir, format!("stage {i} must be an object")));
    };
    for (key, _) in pairs {
        if !STAGE_FIELDS.contains(&key.as_str()) {
            return Err(manifest_err(dir, format!("stage {i}: unknown field {key:?}")));
        }
    }
    let name = sj
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| manifest_err(dir, format!("stage {i}: missing string \"name\"")))?;
    if name.is_empty() {
        return Err(manifest_err(dir, format!("stage {i}: \"name\" must be non-empty")));
    }
    let sdir = sj
        .get("dir")
        .and_then(Json::as_str)
        .ok_or_else(|| manifest_err(dir, format!("stage {i}: missing string \"dir\"")))?;
    let weight = match sj.get("weight") {
        None => 1.0,
        Some(w) => w.as_f64().ok_or_else(|| {
            manifest_err(dir, format!("stage {name:?}: \"weight\" must be a number"))
        })?,
    };
    if !weight.is_finite() || weight < 0.0 {
        return Err(manifest_err(
            dir,
            format!("stage {name:?}: \"weight\" must be finite and >= 0, got {weight}"),
        ));
    }
    let backend = match sj.get("backend") {
        None => None,
        Some(b) => {
            let s = b.as_str().ok_or_else(|| {
                manifest_err(dir, format!("stage {name:?}: \"backend\" must be a string"))
            })?;
            Some(BackendChoice::parse(s).ok_or_else(|| {
                manifest_err(
                    dir,
                    format!("stage {name:?}: unknown backend {s:?}; try auto|exact|quantized|ann"),
                )
            })?)
        }
    };
    let damping = match sj.get("damping") {
        None => 0.1f32,
        Some(d) => {
            let d = d.as_f64().ok_or_else(|| {
                manifest_err(dir, format!("stage {name:?}: \"damping\" must be a number"))
            })? as f32;
            if !d.is_finite() || d <= 0.0 {
                return Err(manifest_err(
                    dir,
                    format!("stage {name:?}: \"damping\" must be finite and > 0"),
                ));
            }
            d
        }
    };
    let preconditioner = match sj.get("preconditioner") {
        None => PrecondKind::Fisher,
        Some(p) => {
            let s = p.as_str().ok_or_else(|| {
                manifest_err(dir, format!("stage {name:?}: \"preconditioner\" must be a string"))
            })?;
            PrecondKind::parse(s).ok_or_else(|| {
                manifest_err(
                    dir,
                    format!("stage {name:?}: unknown preconditioner {s:?}; try fisher|ekfac"),
                )
            })?
        }
    };
    let norm = match sj.get("norm") {
        None => Normalization::None,
        Some(n) => {
            let s = n.as_str().ok_or_else(|| {
                manifest_err(dir, format!("stage {name:?}: \"norm\" must be a string"))
            })?;
            Normalization::parse(s).map_err(|e| manifest_err(dir, format!("stage {name:?}: {e}")))?
        }
    };
    Ok(StageSpec {
        name: name.to_string(),
        dir: PathBuf::from(sdir),
        weight,
        backend,
        damping,
        preconditioner,
        norm,
    })
}

// ----------------------------------------------------------------- session

/// Session construction knobs.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// How per-stage rankings merge (validated against the manifest's
    /// normalizations at open).
    pub combine: Combine,
    /// Shared scan-pool workers (0 = one per core, capped at 16). One
    /// pool serves every stage — adding stages does not add workers.
    pub workers: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { combine: Combine::WeightedSum, workers: 0 }
    }
}

/// One opened stage: its spec, its valuator snapshot, and its own
/// [`Metrics`] instance (per-stage histograms, trace ring, and counters —
/// the `stage` axis of the session's observability).
pub struct SessionStage {
    spec: StageSpec,
    /// Absolute store directory (spec dir resolved against the session
    /// dir) — what a reloader probes for new generations.
    store_dir: PathBuf,
    valuator: Arc<Valuator>,
    metrics: Arc<Metrics>,
}

impl SessionStage {
    pub fn spec(&self) -> &StageSpec {
        &self.spec
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Resolved store directory on disk.
    pub fn store_dir(&self) -> &Path {
        &self.store_dir
    }

    pub fn valuator(&self) -> &Arc<Valuator> {
        &self.valuator
    }

    /// This stage's own metrics instance.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Dismantle into (spec, resolved store dir, valuator, metrics) — the
    /// serve layer re-homes these into per-stage reload slots.
    pub fn into_parts(self) -> (StageSpec, PathBuf, Arc<Valuator>, Arc<Metrics>) {
        (self.spec, self.store_dir, self.valuator, self.metrics)
    }
}

/// Build one stage's valuator over the shared pool — the single
/// construction recipe [`Session::open`] and the serve layer's per-stage
/// reloader share, so a reloaded stage is configured exactly like the
/// originally opened one.
pub fn build_stage_valuator(
    spec: &StageSpec,
    store_dir: &Path,
    pool: &Arc<ScanPool>,
    workers: usize,
    metrics: &Arc<Metrics>,
) -> Result<Valuator, ValuationError> {
    let mut b = Valuator::open_degraded(store_dir)?
        .backend(Backend::Auto)
        .pool(PoolMode::Shared(pool.clone()))
        .workers(workers)
        .normalization(spec.norm)
        .metrics(metrics.clone());
    b = match spec.preconditioner {
        PrecondKind::Fisher => b.fit_from_store(spec.damping),
        PrecondKind::Ekfac => b.fit_ekfac_from_store(spec.damping),
    };
    let v = b.build()?;
    // The spec's backend route must be servable by this fabric — surface
    // the mismatch at open, not on the first query.
    v.resolved_kind(spec.backend)?;
    Ok(v)
}

/// A multi-stage valuation session: several store fabrics, one shared
/// scan pool, one query fan-out. See the module docs for the manifest
/// format and combine semantics.
pub struct Session {
    dir: PathBuf,
    stages: Vec<SessionStage>,
    pool: Arc<ScanPool>,
    combine: Combine,
}

impl Session {
    /// Load `<dir>/session.json`, spawn ONE shared pool, and build every
    /// stage's valuator over it. All manifest and cross-stage validation
    /// happens here: unknown fields, duplicate names, per-stage backend
    /// servability, gradient-width agreement, and the weighted-sum
    /// normalization constraint.
    pub fn open(dir: impl AsRef<Path>, cfg: SessionConfig) -> Result<Session, SessionError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = SessionManifest::load(&dir)?;
        Session::from_manifest(dir, manifest, cfg)
    }

    /// [`Session::open`] over an already-parsed manifest.
    pub fn from_manifest(
        dir: PathBuf,
        manifest: SessionManifest,
        cfg: SessionConfig,
    ) -> Result<Session, SessionError> {
        if cfg.combine == Combine::WeightedSum {
            let norm0 = manifest.stages[0].norm;
            if let Some(odd) = manifest.stages.iter().find(|s| s.norm != norm0) {
                return Err(SessionError::InvalidConfig(format!(
                    "weighted-sum combining needs one shared normalization, but stage {:?} \
                     uses a different norm than stage {:?}; use borda (rank aggregation is \
                     scale-free) or per-stage",
                    odd.name, manifest.stages[0].name
                )));
            }
        }
        let pool = Arc::new(ScanPool::spawn(cfg.workers));
        let mut stages = Vec::with_capacity(manifest.stages.len());
        for spec in manifest.stages {
            let store_dir =
                if spec.dir.is_relative() { dir.join(&spec.dir) } else { spec.dir.clone() };
            let metrics = Arc::new(Metrics::default());
            let valuator = build_stage_valuator(&spec, &store_dir, &pool, cfg.workers, &metrics)
                .map_err(|source| SessionError::Stage { stage: spec.name.clone(), source })?;
            stages.push(SessionStage {
                spec,
                store_dir,
                valuator: Arc::new(valuator),
                metrics,
            });
        }
        // One query fans out to every stage, so the stages must agree on
        // the projected gradient width.
        let k0 = stages[0].valuator.k();
        if let Some(odd) = stages.iter().find(|s| s.valuator.k() != k0) {
            return Err(SessionError::InvalidConfig(format!(
                "stage {:?} serves k={} but stage {:?} serves k={k0}; a session fans ONE \
                 query gradient out to every stage, so all stages must share k",
                odd.name(),
                odd.valuator.k(),
                stages[0].name()
            )));
        }
        Ok(Session { dir, stages, pool, combine: cfg.combine })
    }

    /// Session directory (where `session.json` lives).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Stages in manifest order.
    pub fn stages(&self) -> &[SessionStage] {
        &self.stages
    }

    /// Stage by name.
    pub fn stage(&self, name: &str) -> Option<&SessionStage> {
        self.stages.iter().find(|s| s.name() == name)
    }

    /// The ONE shared scan pool every stage runs on.
    pub fn pool(&self) -> &Arc<ScanPool> {
        &self.pool
    }

    /// Shared-pool worker count — constant in the number of stages.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The session-level combine rule.
    pub fn combine(&self) -> Combine {
        self.combine
    }

    /// Raw stored gradient row `i` of the FIRST stage (the session's
    /// reference row space for `--row` / `{"row": N}` queries).
    pub fn gradient_row(&self, i: usize) -> Option<Vec<f32>> {
        self.stages[0].valuator.gradient_row(i)
    }

    /// Score `req` against every stage. See
    /// [`query_stages`](Self::query_stages).
    pub fn query(&self, req: QueryRequest) -> Result<SessionReport, SessionError> {
        self.query_stages(req, None)
    }

    /// Score `req` against the named subset of stages (`None` = all, in
    /// manifest order). The request is admitted to every selected stage
    /// via `query_async` FIRST, then waited — the stages' shard tasks
    /// interleave on the shared pool instead of running back-to-back.
    /// A request-level backend override beats the per-stage spec default.
    pub fn query_stages(
        &self,
        req: QueryRequest,
        subset: Option<&[String]>,
    ) -> Result<SessionReport, SessionError> {
        let selected = self.select(subset)?;
        let mut pending: Vec<(&SessionStage, PendingScores)> =
            Vec::with_capacity(selected.len());
        for stage in &selected {
            let mut r = req.clone();
            if r.backend.is_none() {
                r.backend = stage.spec.backend;
            }
            let p = stage.valuator.query_async(r).map_err(|source| SessionError::Stage {
                stage: stage.name().to_string(),
                source,
            })?;
            pending.push((stage, p));
        }
        let mut reports = Vec::with_capacity(pending.len());
        for (stage, p) in pending {
            let (results, report) =
                p.wait_with_report().map_err(|source| SessionError::Stage {
                    stage: stage.name().to_string(),
                    source,
                })?;
            reports.push(StageReport {
                name: stage.name().to_string(),
                weight: stage.spec.weight,
                generation: stage.valuator.generation(),
                quarantined_shards: stage.valuator.quarantined().len(),
                results,
                report,
            });
        }
        let combined = combine_rankings(self.combine, &reports, req.topk.max(1));
        Ok(SessionReport { combine: self.combine, stages: reports, combined })
    }

    fn select(&self, subset: Option<&[String]>) -> Result<Vec<&SessionStage>, SessionError> {
        match subset {
            None => Ok(self.stages.iter().collect()),
            Some(names) => {
                if names.is_empty() {
                    return Err(SessionError::InvalidConfig(
                        "empty \"stages\" subset: name at least one stage".into(),
                    ));
                }
                // Manifest order, not request order, so a subset never
                // reorders the fan-out (and duplicates collapse).
                let mut sel = Vec::new();
                for name in names {
                    if self.stage(name).is_none() {
                        let known: Vec<&str> =
                            self.stages.iter().map(SessionStage::name).collect();
                        return Err(SessionError::InvalidConfig(format!(
                            "unknown stage {name:?}; this session has {known:?}"
                        )));
                    }
                }
                for stage in &self.stages {
                    if names.iter().any(|n| n == stage.name()) {
                        sel.push(stage);
                    }
                }
                Ok(sel)
            }
        }
    }

    /// Drain the shared pool and stop its workers. The session owns the
    /// pool (each stage attached via `PoolMode::Shared`), so this is the
    /// one shutdown point; dropping the session does the same.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }

    /// Dismantle into (stages, shared pool, combine) — how
    /// `logra serve --session` takes ownership of an opened session.
    pub fn into_parts(self) -> (Vec<SessionStage>, Arc<ScanPool>, Combine) {
        (self.stages, self.pool, self.combine)
    }
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Session")
            .field("dir", &self.dir)
            .field("stages", &self.stages.len())
            .field("workers", &self.workers())
            .field("combine", &self.combine.name())
            .finish()
    }
}

// ------------------------------------------------------------------ report

/// One stage's slice of a [`SessionReport`].
#[derive(Clone, Debug)]
pub struct StageReport {
    pub name: String,
    pub weight: f64,
    /// Manifest generation the stage's snapshot was opened at.
    pub generation: u64,
    /// Shards a degraded open excluded from this stage's fabric.
    pub quarantined_shards: usize,
    /// Per-test-row top-k, exactly what a standalone [`Valuator`] over
    /// the same store returns (bit-identical; `rust/tests/session.rs`).
    pub results: Vec<QueryResult>,
    /// Per-stage stage breakdown (always present: every stage carries its
    /// own metrics instance).
    pub report: Option<QueryReport>,
}

/// The merged answer of one session query: per-stage top-k plus the
/// combined rankings (one per test row; `None` under
/// [`Combine::PerStageOnly`]).
#[derive(Clone, Debug)]
pub struct SessionReport {
    pub combine: Combine,
    /// Selected stages, in manifest order.
    pub stages: Vec<StageReport>,
    pub combined: Option<Vec<QueryResult>>,
}

/// Merge per-stage rankings. Candidates are the union of the selected
/// positive-weight stages' top-k ids per test row; sorting uses the same
/// total order as [`TopK::into_sorted`](crate::util::topk::TopK) (score
/// descending, ties to the smaller id) so combined rankings are a pure
/// function of the per-stage results. Public so the serve layer can
/// combine over whichever stages SUCCEEDED on a partially-failed request.
pub fn combine_rankings(
    combine: Combine,
    stages: &[StageReport],
    topk: usize,
) -> Option<Vec<QueryResult>> {
    if matches!(combine, Combine::PerStageOnly) {
        return None;
    }
    let nt = stages.iter().map(|s| s.results.len()).max().unwrap_or(0);
    let mut out = Vec::with_capacity(nt);
    for t in 0..nt {
        // (id -> accumulated score), insertion-ordered then sorted — a
        // Vec beats a map at top-k scale and keeps iteration
        // deterministic.
        let mut acc: Vec<(u64, f64)> = Vec::new();
        for stage in stages {
            if stage.weight == 0.0 {
                continue;
            }
            let Some(result) = stage.results.get(t) else { continue };
            for (rank, &(score, id)) in result.top.iter().enumerate() {
                let points = match combine {
                    Combine::WeightedSum => stage.weight * score,
                    Combine::RankAggregation(RankRule::Borda) => {
                        stage.weight * (result.top.len() - rank) as f64
                    }
                    Combine::PerStageOnly => unreachable!(),
                };
                match acc.iter_mut().find(|(i, _)| *i == id) {
                    Some((_, s)) => *s += points,
                    None => acc.push((id, points)),
                }
            }
        }
        let mut top: Vec<(f64, u64)> = acc.into_iter().map(|(id, s)| (s, id)).collect();
        top.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        top.truncate(topk);
        out.push(QueryResult { top });
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> PathBuf {
        PathBuf::from("/tmp/logra-session-unit")
    }

    #[test]
    fn manifest_roundtrips() {
        let man = SessionManifest {
            version: SESSION_VERSION,
            stages: vec![
                stage_spec("pretrain", "stage-pt"),
                StageSpec {
                    weight: 0.5,
                    backend: Some(BackendChoice::Exact),
                    preconditioner: PrecondKind::Ekfac,
                    norm: Normalization::RelatIf,
                    ..stage_spec("finetune", "stage-ft")
                },
            ],
        };
        let text = man.to_json().render();
        let back = SessionManifest::parse(&dir(), &text).unwrap();
        assert_eq!(back.stages.len(), 2);
        assert_eq!(back.stages[0].name, "pretrain");
        assert_eq!(back.stages[0].weight, 1.0);
        assert_eq!(back.stages[0].preconditioner, PrecondKind::Fisher);
        assert_eq!(back.stages[1].weight, 0.5);
        assert_eq!(back.stages[1].backend, Some(BackendChoice::Exact));
        assert_eq!(back.stages[1].preconditioner, PrecondKind::Ekfac);
        assert_eq!(back.stages[1].norm, Normalization::RelatIf);
    }

    #[test]
    fn unknown_fields_rejected() {
        for text in [
            r#"{"version": 1, "stages": [{"name":"a","dir":"d"}], "extra": 1}"#,
            r#"{"version": 1, "stages": [{"name":"a","dir":"d","surprise":"x"}]}"#,
        ] {
            let err = SessionManifest::parse(&dir(), text).unwrap_err();
            assert!(
                matches!(err, SessionError::Manifest { .. }),
                "expected Manifest error, got {err}"
            );
            assert!(err.to_string().contains("unknown"), "{err}");
        }
    }

    #[test]
    fn bad_values_rejected() {
        for (text, needle) in [
            (r#"{"version": 2, "stages": [{"name":"a","dir":"d"}]}"#, "version"),
            (r#"{"version": 1, "stages": []}"#, "at least one"),
            (r#"{"version": 1, "stages": [{"name":"a","dir":"d","weight":-1.0}]}"#, "weight"),
            (r#"{"version": 1, "stages": [{"name":"a","dir":"d","backend":"warp"}]}"#, "backend"),
            (
                r#"{"version": 1, "stages": [{"name":"a","dir":"d","preconditioner":"kfac"}]}"#,
                "preconditioner",
            ),
            (
                r#"{"version": 1, "stages": [{"name":"a","dir":"d"},{"name":"a","dir":"e"}]}"#,
                "duplicate",
            ),
            (r#"{"version": 1, "stages": [{"name":"a","dir":"d","damping":0.0}]}"#, "damping"),
        ] {
            let err = SessionManifest::parse(&dir(), text).unwrap_err().to_string();
            assert!(err.contains(needle), "expected {needle:?} in: {err}");
        }
    }

    #[test]
    fn combine_parse_roundtrips() {
        for c in [
            Combine::WeightedSum,
            Combine::RankAggregation(RankRule::Borda),
            Combine::PerStageOnly,
        ] {
            assert_eq!(Combine::parse(c.name()), Some(c));
        }
        assert_eq!(Combine::parse("mean"), None);
    }

    #[test]
    fn weighted_sum_ignores_zero_weight_stages() {
        let s0 = StageReport {
            name: "a".into(),
            weight: 1.0,
            generation: 0,
            quarantined_shards: 0,
            results: vec![QueryResult { top: vec![(2.0, 7), (-1.0, 3)] }],
            report: None,
        };
        let s1 = StageReport {
            name: "b".into(),
            weight: 0.0,
            generation: 0,
            quarantined_shards: 0,
            results: vec![QueryResult { top: vec![(9.0, 42), (8.0, 43)] }],
            report: None,
        };
        let combined =
            combine_rankings(Combine::WeightedSum, &[s0.clone(), s1], 2).unwrap();
        // Weight-0 stage contributes nothing — even its id 42 with score
        // 9.0 must not outrank stage a's negative tail.
        assert_eq!(combined[0].top, s0.results[0].top);
    }

    #[test]
    fn borda_ranks_scale_free() {
        let mk = |top: Vec<(f64, u64)>| StageReport {
            name: "s".into(),
            weight: 1.0,
            generation: 0,
            quarantined_shards: 0,
            results: vec![QueryResult { top }],
            report: None,
        };
        // Stage scores on wildly different scales; id 5 is ranked first
        // by both stages, id 9 second by both.
        let s0 = mk(vec![(1e9, 5), (2.0, 9), (1.0, 1)]);
        let s1 = mk(vec![(0.03, 5), (0.02, 9), (0.01, 2)]);
        let combined =
            combine_rankings(Combine::RankAggregation(RankRule::Borda), &[s0, s1], 3).unwrap();
        let ids: Vec<u64> = combined[0].top.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids[0], 5);
        assert_eq!(ids[1], 9);
        // Borda points: 3+3=6 for id 5, 2+2=4 for id 9.
        assert_eq!(combined[0].top[0].0, 6.0);
        assert_eq!(combined[0].top[1].0, 4.0);
    }

    #[test]
    fn per_stage_only_yields_no_combined() {
        assert!(combine_rankings(Combine::PerStageOnly, &[], 5).is_none());
    }
}
