//! Logging phase: one pass over the training set producing (a) the
//! on-disk projected-gradient store and (b) the projected Fisher blocks —
//! Figure 1 (left bottom) of the paper.
//!
//! Pipeline: batcher -> `logra_log` artifact -> {background store writer,
//! inline Hessian accumulation}. Disk writes overlap the next batch's
//! execution through the bounded writer queue (§E.2); a slow disk
//! backpressures the executor instead of growing memory.

use std::path::Path;

use anyhow::Result;

use crate::hessian::{BlockHessian, KfacFactors};
use crate::model::dataset::Dataset;
use crate::runtime::literal::{f32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::store::{BackgroundWriter, GradStore};
use crate::util::memory::peak_rss_bytes;
use crate::util::Timer;

/// Options for a logging run.
#[derive(Clone, Debug)]
pub struct LoggingOptions {
    /// Bound on in-flight write batches (backpressure depth).
    pub queue_cap: usize,
    /// Accumulate the projected Fisher inline (true for LoGra; the
    /// gradient-dot baseline sets false).
    pub fit_hessian: bool,
}

impl Default for LoggingOptions {
    fn default() -> Self {
        LoggingOptions { queue_cap: 4, fit_hessian: true }
    }
}

/// Measured report of a logging run (Table-1 left half).
#[derive(Clone, Debug)]
pub struct LoggingReport {
    pub rows: u64,
    pub seconds: f64,
    pub tokens_per_sec: f64,
    pub examples_per_sec: f64,
    pub peak_rss_bytes: u64,
    pub storage_bytes: u64,
}

/// Run the logging phase: write projected gradients for every example of
/// `ds` to `store_dir` and (optionally) fit the projected Fisher.
pub fn run_logging(
    rt: &Runtime,
    ds: &Dataset,
    params: &[f32],
    proj_flat: &[f32],
    store_dir: &Path,
    opts: &LoggingOptions,
) -> Result<(GradStore, Option<BlockHessian>, LoggingReport)> {
    let man = &rt.manifest;
    let k = man.k_total;
    let n = man.n_params;
    let timer = Timer::start();

    let writer = BackgroundWriter::spawn(store_dir, k, opts.queue_cap)?;
    let mut hessian = opts.fit_hessian.then(|| BlockHessian::new(man));

    let params_lit = f32_lit(&[n], params)?;
    let proj_lit = f32_lit(&[man.proj_len], proj_flat)?;
    let mut examples = 0u64;
    for batch in ds.all_batches(man.log_batch) {
        let batch_lits = batch.literals(man)?;
        let mut args: Vec<&xla::Literal> = vec![&params_lit, &proj_lit];
        args.extend(batch_lits.iter());
        let out = rt.run_ref("logra_log", &args)?;
        let g = to_f32_vec(&out[0])?; // [B, K]
        let real = batch.real();
        if let Some(h) = hessian.as_mut() {
            h.accumulate(&g, real);
        }
        // Hand only the real rows to the writer.
        writer.submit(batch.ids()[..real].to_vec(), g[..real * k].to_vec())?;
        examples += real as u64;
    }
    let rows = writer.finish()?;
    debug_assert_eq!(rows, examples);

    let store = GradStore::open(store_dir)?;
    let seconds = timer.seconds();
    let tokens = examples as f64 * ds.tokens_per_example() as f64;
    let report = LoggingReport {
        rows,
        seconds,
        tokens_per_sec: tokens / seconds,
        examples_per_sec: examples as f64 / seconds,
        peak_rss_bytes: peak_rss_bytes(),
        storage_bytes: store.storage_bytes(),
    };
    Ok((store, hessian, report))
}

/// Fit KFAC activation covariances over (a sample of) the dataset —
/// the pre-pass behind LoGra-PCA initialization and the EKFAC baseline.
/// Only full batches contribute (the cov artifact can't mask pad rows).
pub fn fit_kfac(
    rt: &Runtime,
    ds: &Dataset,
    params: &[f32],
    max_batches: usize,
) -> Result<KfacFactors> {
    let man = &rt.manifest;
    let params_lit = f32_lit(&[man.n_params], params)?;
    let mut kf = KfacFactors::new(man);
    let mut used = 0usize;
    for batch in ds.all_batches(man.log_batch) {
        if batch.real() != batch.size() {
            continue; // skip ragged tail
        }
        let batch_lits = batch.literals(man)?;
        let mut args: Vec<&xla::Literal> = vec![&params_lit];
        args.extend(batch_lits.iter());
        let out = rt.run_ref("cov_stats", &args)?;
        let cov = to_f32_vec(&out[0])?;
        // LM rows = B*T activations; MLP rows = B. Row count only scales
        // the mean, which eigh is invariant to — use batch examples.
        kf.accumulate(man, &cov, batch.real() as u64)?;
        used += 1;
        if used >= max_batches {
            break;
        }
    }
    anyhow::ensure!(used > 0, "no full batches available for KFAC fitting");
    Ok(kf)
}

/// Compute RAW projected gradients for a set of examples (query-side
/// logging; also used by evals). Returns row-major [indices.len(), K]
/// plus per-example losses.
pub fn projected_grads(
    rt: &Runtime,
    ds: &Dataset,
    indices: &[usize],
    params: &[f32],
    proj_flat: &[f32],
) -> Result<(Vec<f32>, Vec<f32>)> {
    let man = &rt.manifest;
    let k = man.k_total;
    let params_lit = f32_lit(&[man.n_params], params)?;
    let proj_lit = f32_lit(&[man.proj_len], proj_flat)?;
    let mut rows = Vec::with_capacity(indices.len() * k);
    let mut losses = Vec::with_capacity(indices.len());
    for batch in ds.batches(indices, man.log_batch) {
        let batch_lits = batch.literals(man)?;
        let mut args: Vec<&xla::Literal> = vec![&params_lit, &proj_lit];
        args.extend(batch_lits.iter());
        let out = rt.run_ref("logra_log", &args)?;
        let g = to_f32_vec(&out[0])?;
        let l = to_f32_vec(&out[1])?;
        rows.extend_from_slice(&g[..batch.real() * k]);
        losses.extend_from_slice(&l[..batch.real()]);
    }
    Ok((rows, losses))
}
