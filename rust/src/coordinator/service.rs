//! Valuation service: dynamic request batching over the query side —
//! the serving face of Figure 1 (left top + right).
//!
//! PJRT handles are not `Send`, so the service keeps runtime warmup and
//! gradient extraction inside one worker thread; callers talk to it
//! through bounded channels. Requests are coalesced up to the artifact's
//! static `test_batch` shape or until `max_wait` expires — classic dynamic
//! batching: one `logra_log` artifact call amortizes its fixed cost over
//! every query in the batch.
//!
//! Scanning goes through ONE seam: a [`Valuator`] built at `spawn` time
//! (before the worker exists). The facade opens the store fabric once,
//! auto-pairs the quantized copy with its exact rescore substrate, spawns
//! the persistent scan pool when the backend fans out, and validates the
//! whole configuration with typed [`ValuationError`]s — a bad
//! `ServiceConfig` fails `spawn`, never a worker thread. The worker
//! extracts a batch's gradients, admits them with
//! [`Valuator::query_async`], and immediately returns to batching; up to
//! `max_in_flight` query batches interleave their shard tasks on the
//! pool's warm workers while a responder thread completes scans (one
//! shared [`PendingScores`] handle per batch) in admission order. Results
//! stay bit-identical to the sequential native scan for every
//! interleaving (the pool's shard-slot merge discipline; see
//! `valuation::pool`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::metrics::Metrics;
use crate::hessian::BlockHessian;
use crate::runtime::literal::{f32_lit, i32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::util::pipeline::{bounded, Sender};
use crate::valuation::{
    Backend, BackendKind, Normalization, PendingScores, PoolMode, QueryRequest, QueryResult,
    ScanBackend, ScanPool, ValuationError, Valuator,
};

/// Service construction parameters (everything `Send`).
pub struct ServiceConfig {
    pub artifact_dir: PathBuf,
    pub store_dir: PathBuf,
    pub params: Vec<f32>,
    pub proj_flat: Vec<f32>,
    /// Pre-fitted Fisher blocks (from the logging phase).
    pub hessian: BlockHessian,
    pub damping: f32,
    pub norm: Normalization,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Scan-pool worker threads (0 = one per core, capped at 16; N = fixed
    /// count). The pool the `Valuator` spawns is the single authority —
    /// `Metrics::pool_workers` reports the resolved count. Unsharded f32
    /// stores serve sequentially — one shard has nothing to fan out over.
    pub scan_workers: usize,
    /// Scan backend the service serves through ([`Backend::Auto`] picks
    /// from `store_dir`'s codec: exact engines on f32 fabrics, two-stage
    /// on int8, IVF when the int8 manifest advertises a `logra store
    /// index` sidecar). Point `store_dir` at the quantized copy for
    /// [`Backend::Quantized`] / [`Backend::Ann`] — its manifest records
    /// the f32 rescore companion.
    pub backend: Backend,
    /// Completion-queue depth for admitted query batches (must be ≥ 1) —
    /// the batcher blocks once this many completed admissions are waiting
    /// on the responder. A throttle, not an exact bound: one further batch
    /// can sit in the responder and one in the batcher, so up to
    /// `max_in_flight + 2` batches may interleave shard tasks on the
    /// pool. Higher values overlap gradient extraction of batch N+1 with
    /// the scan of batch N.
    pub max_in_flight: usize,
}

/// One LM valuation request: value this token sequence against the store.
struct ServiceRequest {
    tokens: Vec<i32>,
    topk: usize,
    resp: Sender<QueryResult>,
}

/// A query batch admitted by the worker, completed by the responder.
struct InFlight {
    reqs: Vec<ServiceRequest>,
    /// The one shared completion handle every backend returns.
    pending: PendingScores,
    /// False when the backend scanned eagerly at admission (sequential
    /// path) — its scan time was recorded by the worker already.
    timed: bool,
    submitted: Instant,
    /// rows_scanned delta to record once the scan succeeds.
    rows: u64,
}

/// Client handle; cloneable across threads (wrap in `Arc`).
pub struct ValuationService {
    tx: Option<Sender<ServiceRequest>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    responder: Option<std::thread::JoinHandle<()>>,
    valuator: Option<Arc<Valuator>>,
    pub metrics: Arc<Metrics>,
    seq_len: usize,
}

impl ValuationService {
    /// Reject configurations that can never serve BEFORE touching disk or
    /// spawning threads — the typed twin of the validation the `Valuator`
    /// builder performs on the store side. The returned error downcasts
    /// from the `anyhow` chain as a [`ValuationError`].
    fn validate(cfg: &ServiceConfig) -> std::result::Result<(), ValuationError> {
        if cfg.max_in_flight == 0 {
            return Err(ValuationError::InvalidConfig(
                "max_in_flight must be ≥ 1 (completion-queue depth for admitted batches)"
                    .into(),
            ));
        }
        match cfg.backend {
            Backend::Quantized { rescore_factor: 0 }
            | Backend::Ann { rescore_factor: 0, .. } => {
                return Err(ValuationError::InvalidConfig(
                    "rescore_factor must be ≥ 1 (stage-1 candidate pool multiplier)"
                        .into(),
                ));
            }
            Backend::Ann { nprobe: 0, .. } => {
                return Err(ValuationError::InvalidConfig(
                    "nprobe must be ≥ 1 (clusters probed per shard)".into(),
                ));
            }
            _ => {}
        }
        Ok(())
    }

    /// Validate the config, build the `Valuator` (store fabric + scan
    /// pool), and spawn the worker. Configuration and store errors surface
    /// here, typed; artifact errors surface before the first query is
    /// accepted (the worker signals readiness only after warmup).
    pub fn spawn(cfg: ServiceConfig) -> Result<Self> {
        Self::validate(&cfg)?;
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (tx, rx) = bounded::<ServiceRequest>(64);
        // Probe seq_len from the manifest before moving cfg.
        let man = crate::runtime::Manifest::load(&cfg.artifact_dir)?;
        let seq_len = man.seq_len;
        anyhow::ensure!(man.is_lm(), "valuation service currently serves LM queries");

        // ONE facade call replaces the old store-open / engine-enum /
        // pool-spawn choreography: the facade opens whatever fabric
        // `store_dir` holds (f32 or quantized-with-companion), resolves
        // `cfg.backend` against it, and rejects unservable pairings with a
        // typed error. The eigendecomposition happens here, at spawn, like
        // before.
        let precond = Arc::new(cfg.hessian.preconditioner(cfg.damping)?);
        let valuator = Arc::new(
            Valuator::open(&cfg.store_dir)?
                .backend(cfg.backend)
                .preconditioner(precond)
                .normalization(cfg.norm)
                .workers(cfg.scan_workers)
                .metrics(m2.clone())
                .pool(PoolMode::Auto)
                .build()?,
        );

        // Responder: completes admitted scans in admission order and
        // dispatches responses — the other half of pipelined admission.
        let (done_tx, done_rx) = bounded::<InFlight>(cfg.max_in_flight);
        let m3 = metrics.clone();
        let responder = std::thread::Builder::new()
            .name("valuation-responder".into())
            .spawn(move || {
                while let Some(inflight) = done_rx.recv() {
                    let InFlight { reqs, pending, timed, submitted, rows } = inflight;
                    match pending.wait() {
                        Ok(results) => {
                            if timed {
                                // Admission-to-completion wall time; with
                                // overlapping batches these sum past wall
                                // clock, like shard_scan_nanos.
                                Metrics::add_seconds(
                                    &m3.scan_nanos,
                                    submitted.elapsed().as_secs_f64(),
                                );
                            }
                            m3.rows_scanned.fetch_add(rows, std::sync::atomic::Ordering::Relaxed);
                            for (i, req) in reqs.into_iter().enumerate() {
                                let mut r = results[i].clone();
                                r.top.truncate(req.topk);
                                let _ = req.resp.send(r);
                            }
                        }
                        Err(e) => {
                            // Per-batch error isolation: dropping `reqs`
                            // closes the response channels (callers see an
                            // error); the service keeps serving — a
                            // QueryPoisoned loses only its own batch.
                            eprintln!("[valuation-service] scan failed: {e}");
                        }
                    }
                }
            })
            .map_err(|e| anyhow!("spawn responder: {e}"))?;

        let (ready_tx, ready_rx) = bounded::<Result<()>>(1);
        let w_val = valuator.clone();
        let handle = std::thread::Builder::new()
            .name("valuation-service".into())
            .spawn(move || -> Result<()> {
                let valuator = w_val;
                // Pay the one-time setup (XLA compilation + lazy PJRT init)
                // BEFORE signalling readiness, so no request ever observes
                // it as tail latency (§Perf log). Scanning is native-kernel
                // only, so just the gradient program warms up.
                let setup = (|| -> Result<Runtime> {
                    let rt = Runtime::open(&cfg.artifact_dir)?;
                    rt.warmup(&["logra_log"])?;
                    // Compilation alone is not enough: the first EXECUTION
                    // pays lazy PJRT initialization. Run once with dummy
                    // inputs.
                    {
                        let man = &rt.manifest;
                        let p = f32_lit(&[man.n_params], &cfg.params)?;
                        let pr = f32_lit(&[man.proj_len], &cfg.proj_flat)?;
                        let zeros_tok = vec![0i32; man.log_batch * man.seq_len];
                        let tok = i32_lit(&[man.log_batch, man.seq_len], &zeros_tok)?;
                        rt.run_ref("logra_log", &[&p, &pr, &tok])?;
                    }
                    Ok(rt)
                })();
                let rt = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        let _ = ready_tx.send(Err(e));
                        return Err(anyhow!("service setup failed: {msg}"));
                    }
                };
                let man = &rt.manifest;
                // Gradient extraction runs at log_batch; batch at most
                // min(log_batch, test_batch) requests so latency stays in
                // the envelope the artifact was shaped for. (The native
                // backends themselves are shape-flexible.)
                let nt = man.test_batch.min(man.log_batch);
                let lb = man.log_batch;
                let t = man.seq_len;
                let k = man.k_total;
                let params_lit = f32_lit(&[man.n_params], &cfg.params)?;
                let proj_lit = f32_lit(&[man.proj_len], &cfg.proj_flat)?;
                while let Some(first) = rx.recv() {
                    // Dynamic batching: gather up to nt requests, parking
                    // on the channel's condvar until the deadline (no
                    // sleep-polling).
                    let mut reqs = vec![first];
                    let deadline = Instant::now() + cfg.max_wait;
                    while reqs.len() < nt {
                        match rx.recv_deadline(deadline) {
                            Some(r) => reqs.push(r),
                            None => break,
                        }
                    }
                    let real = reqs.len();
                    m2.requests.fetch_add(real as u64, std::sync::atomic::Ordering::Relaxed);
                    m2.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Per-batch error isolation: a failing batch drops its
                    // requesters' response channels (they see an error)
                    // but must never kill the worker.
                    let admitted = (|| -> Result<(PendingScores, bool)> {
                        // Assemble the fixed-shape token batch at the
                        // gradient artifact's log_batch (pad repeats the
                        // last real row).
                        let mut tokens = Vec::with_capacity(lb * t);
                        for row in 0..lb {
                            let r = &reqs[row.min(real - 1)];
                            anyhow::ensure!(
                                r.tokens.len() == t,
                                "query length {} != seq_len {t}",
                                r.tokens.len()
                            );
                            tokens.extend_from_slice(&r.tokens);
                        }
                        let t0 = Instant::now();
                        let tok_lit = i32_lit(&[lb, t], &tokens)?;
                        let out = rt
                            .run_ref("logra_log", &[&params_lit, &proj_lit, &tok_lit])?;
                        let mut g = to_f32_vec(&out[0])?;
                        Metrics::add_seconds(&m2.grad_nanos, t0.elapsed().as_secs_f64());
                        // Drop the padding rows: the native backends are
                        // shape-flexible, so an underfilled batch scans
                        // less and per-request metrics stay honest.
                        g.truncate(real * k);

                        let topk = reqs.iter().map(|r| r.topk).max().unwrap_or(1).max(1);
                        let t1 = Instant::now();
                        let pending = valuator
                            .query_async(QueryRequest::gradients(g, real, topk))?;
                        let ready = pending.is_ready();
                        if ready {
                            // Sequential backend: the scan ran at
                            // admission, on this thread.
                            Metrics::add_seconds(&m2.scan_nanos, t1.elapsed().as_secs_f64());
                        }
                        Ok((pending, ready))
                    })();
                    match admitted {
                        Ok((pending, ready)) => {
                            let inflight = InFlight {
                                reqs,
                                pending,
                                timed: !ready,
                                submitted: Instant::now(),
                                rows: (valuator.rows() * real) as u64,
                            };
                            if done_tx.send(inflight).is_err() {
                                return Err(anyhow!("responder thread died"));
                            }
                        }
                        Err(e) => {
                            eprintln!("[valuation-service] batch failed: {e:#}");
                            // Dropping `reqs` closes the response channels.
                        }
                    }
                }
                Ok(())
            })?;
        // Block until the worker is warm (or report its setup error).
        match ready_rx.recv() {
            Some(Ok(())) => {}
            Some(Err(e)) => return Err(e),
            None => return Err(anyhow!("service worker died during setup")),
        }
        Ok(ValuationService {
            tx: Some(tx),
            handle: Some(handle),
            responder: Some(responder),
            valuator: Some(valuator),
            metrics,
            seq_len,
        })
    }

    /// The persistent scan pool (None when the sequential backend serves an
    /// unsharded store) — snapshot it for queue depth, per-worker busy
    /// time, and in-flight query counts.
    pub fn scan_pool(&self) -> Option<&Arc<ScanPool>> {
        self.valuator.as_ref().and_then(|v| v.scan_pool())
    }

    /// Which scan backend [`ServiceConfig::backend`] resolved to.
    pub fn backend_kind(&self) -> Option<BackendKind> {
        self.valuator.as_ref().map(|v| v.kind())
    }

    /// Blocking query: value `tokens` (must be exactly seq_len long).
    pub fn query(&self, tokens: Vec<i32>, topk: usize) -> Result<QueryResult> {
        anyhow::ensure!(
            tokens.len() == self.seq_len,
            "query length {} != seq_len {}",
            tokens.len(),
            self.seq_len
        );
        let (rtx, rrx) = bounded(1);
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("service closed"))?
            .send(ServiceRequest { tokens, topk, resp: rtx })
            .map_err(|_| anyhow!("service worker died"))?;
        rrx.recv().ok_or_else(|| anyhow!("service dropped request"))
    }

    /// Graceful shutdown: stop admission, drain in-flight scans (the pool
    /// completes every admitted task), then propagate worker errors.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.tx.take());
        let res = match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow!("service worker panicked"))?,
            None => Ok(()),
        };
        if let Some(r) = self.responder.take() {
            let _ = r.join();
        }
        if let Some(v) = self.valuator.take() {
            if let Some(p) = v.scan_pool() {
                p.shutdown();
            }
        }
        res
    }
}

impl Drop for ValuationService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let Some(r) = self.responder.take() {
            let _ = r.join();
        }
        if let Some(v) = self.valuator.take() {
            if let Some(p) = v.scan_pool() {
                p.shutdown();
            }
        }
    }
}
