//! Valuation service: dynamic request batching over the query engine —
//! the serving face of Figure 1 (left top + right).
//!
//! PJRT handles are not `Send`, so the service keeps runtime warmup and
//! gradient extraction inside one worker thread; callers talk to it
//! through bounded channels. Requests are coalesced up to the artifact's
//! static `test_batch` shape or until `max_wait` expires — classic dynamic
//! batching: the HLO score program amortizes its fixed cost over every
//! query in the batch.
//!
//! The store fabric, preconditioner, and scan pool are shared-ownership
//! (`Arc`) and built at `spawn` time, BEFORE the worker starts: scans no
//! longer belong to the worker thread. Scanning dispatches on the store
//! layout: a plain v1 store keeps the sequential [`QueryEngine`] (HLO
//! score path — there is nothing to fan out over); a sharded store uses
//! the parallel scan-and-merge engine; with `quantized_scan` set (plus a
//! `quant_dir` produced by `logra store quantize`), queries run the
//! two-stage engine instead. Both parallel paths run on ONE persistent
//! [`ScanPool`]: the worker admits a scan (`query_async`) and immediately
//! returns to batching, so up to `max_in_flight` query batches interleave
//! their shard tasks on the pool's warm workers (no head-of-line blocking
//! on a large query), while a responder thread completes scans in
//! admission order and dispatches responses. Results stay bit-identical
//! to the sequential native scan for every interleaving (the pool's
//! shard-slot merge discipline; see `valuation::pool`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::metrics::Metrics;
use crate::hessian::BlockHessian;
use crate::runtime::literal::{f32_lit, i32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::store::{QuantShardedStore, ShardedStore};
use crate::util::pipeline::{bounded, Sender};
use crate::valuation::{
    Normalization, ParallelQueryEngine, PendingQuery, PendingTwoStage, QueryEngine,
    QueryResult, ScanPool, TwoStageEngine,
};

/// Service construction parameters (everything `Send`).
pub struct ServiceConfig {
    pub artifact_dir: PathBuf,
    pub store_dir: PathBuf,
    pub params: Vec<f32>,
    pub proj_flat: Vec<f32>,
    /// Pre-fitted Fisher blocks (from the logging phase).
    pub hessian: BlockHessian,
    pub damping: f32,
    pub norm: Normalization,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Scan-pool worker threads for SHARDED stores (0 = one per core,
    /// capped at 16; N = fixed count). The pool spawned at `spawn` time is
    /// the single authority — `Metrics::pool_workers` reports the resolved
    /// count. Unsharded v1 stores always use the sequential HLO engine —
    /// one shard has nothing to fan out over.
    pub scan_workers: usize,
    /// Serve queries through the two-stage engine: int8 coarse scan over
    /// the quantized copy at `quant_dir`, exact f32 rescore of a
    /// `rescore_factor × topk` candidate pool against `store_dir`.
    pub quantized_scan: bool,
    /// Stage-1 candidate pool multiplier (≥ 1; larger = higher recall,
    /// more exact-precision work). Ignored unless `quantized_scan`.
    pub rescore_factor: usize,
    /// Quantized copy of `store_dir` (from `logra store quantize`).
    /// Required when `quantized_scan` is set.
    pub quant_dir: Option<PathBuf>,
    /// Completion-queue depth for admitted query batches (≥ 1) — the
    /// batcher blocks once this many completed admissions are waiting on
    /// the responder. A throttle, not an exact bound: one further batch
    /// can sit in the responder and one in the batcher, so up to
    /// `max_in_flight + 2` batches may interleave shard tasks on the
    /// pool. Higher values overlap gradient extraction of batch N+1 with
    /// the scan of batch N.
    pub max_in_flight: usize,
}

/// One LM valuation request: value this token sequence against the store.
struct ServiceRequest {
    tokens: Vec<i32>,
    topk: usize,
    resp: Sender<QueryResult>,
}

/// Any scan engine behind one admission call. Only the sequential HLO
/// engine still borrows the runtime; the pool-backed engines own their
/// stores via `Arc`.
enum Scanner<'a> {
    Seq(QueryEngine<'a>),
    Par(ParallelQueryEngine),
    Two(TwoStageEngine),
}

/// A query batch admitted by the worker, completed by the responder.
struct InFlight {
    reqs: Vec<ServiceRequest>,
    outcome: Outcome,
    submitted: Instant,
    /// rows_scanned delta to record once the scan succeeds.
    rows: u64,
}

enum Outcome {
    /// Sequential path — already scanned on the worker thread.
    Ready(Vec<QueryResult>),
    Par(PendingQuery),
    Two(PendingTwoStage),
}

/// Client handle; cloneable across threads (wrap in `Arc`).
pub struct ValuationService {
    tx: Option<Sender<ServiceRequest>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    responder: Option<std::thread::JoinHandle<()>>,
    pool: Option<Arc<ScanPool>>,
    pub metrics: Arc<Metrics>,
    seq_len: usize,
}

impl ValuationService {
    /// Open the store fabric, spawn the scan pool and the worker. Store
    /// and pool errors surface here; artifact errors surface before the
    /// first query is accepted (the worker signals readiness only after
    /// warmup).
    pub fn spawn(cfg: ServiceConfig) -> Result<Self> {
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (tx, rx) = bounded::<ServiceRequest>(64);
        // Probe seq_len from the manifest before moving cfg.
        let man = crate::runtime::Manifest::load(&cfg.artifact_dir)?;
        let seq_len = man.seq_len;
        anyhow::ensure!(man.is_lm(), "valuation service currently serves LM queries");

        // Shared-ownership scan substrate, built before the worker exists:
        // stores, preconditioner, and ONE persistent pool for every scan.
        let store = Arc::new(ShardedStore::open(&cfg.store_dir)?);
        // Open (and sanity-check) the quantized companion up front so a
        // stale copy fails construction, not the first query.
        let quant: Option<Arc<QuantShardedStore>> = if cfg.quantized_scan {
            let qdir = cfg.quant_dir.as_ref().ok_or_else(|| {
                anyhow!("quantized_scan requires quant_dir (run `logra store quantize`)")
            })?;
            let q = QuantShardedStore::open(qdir)?;
            anyhow::ensure!(
                q.rows() == store.rows() && q.k() == store.k(),
                "quantized copy {} ({} rows, k={}) does not mirror store {} \
                 ({} rows, k={}) — re-run `logra store quantize`",
                qdir.display(),
                q.rows(),
                q.k(),
                cfg.store_dir.display(),
                store.rows(),
                store.k()
            );
            Some(Arc::new(q))
        } else {
            None
        };
        let precond = Arc::new(cfg.hessian.preconditioner(cfg.damping)?);
        // The sequential engine serves single-shard f32 stores; everything
        // else scans through the pool.
        let pool: Option<Arc<ScanPool>> = if quant.is_some() || store.as_single().is_none() {
            let p = Arc::new(ScanPool::spawn(cfg.scan_workers));
            metrics.pool_workers.store(p.workers() as u64, std::sync::atomic::Ordering::Relaxed);
            Some(p)
        } else {
            None
        };

        // Responder: completes admitted scans in admission order and
        // dispatches responses — the other half of pipelined admission.
        let (done_tx, done_rx) = bounded::<InFlight>(cfg.max_in_flight.max(1));
        let m3 = metrics.clone();
        let responder = std::thread::Builder::new()
            .name("valuation-responder".into())
            .spawn(move || {
                while let Some(inflight) = done_rx.recv() {
                    let InFlight { reqs, outcome, submitted, rows } = inflight;
                    let timed = !matches!(outcome, Outcome::Ready(_));
                    let res = match outcome {
                        Outcome::Ready(results) => Ok(results),
                        Outcome::Par(pending) => pending.wait(),
                        Outcome::Two(pending) => pending.wait(),
                    };
                    match res {
                        Ok(results) => {
                            if timed {
                                // Admission-to-completion wall time; with
                                // overlapping batches these sum past wall
                                // clock, like shard_scan_nanos.
                                Metrics::add_nanos(
                                    &m3.scan_nanos,
                                    submitted.elapsed().as_secs_f64(),
                                );
                            }
                            m3.rows_scanned.fetch_add(rows, std::sync::atomic::Ordering::Relaxed);
                            for (i, req) in reqs.into_iter().enumerate() {
                                let mut r = results[i].clone();
                                r.top.truncate(req.topk);
                                let _ = req.resp.send(r);
                            }
                        }
                        Err(e) => {
                            // Per-batch error isolation: dropping `reqs`
                            // closes the response channels (callers see an
                            // error); the service keeps serving.
                            eprintln!("[valuation-service] scan failed: {e:#}");
                        }
                    }
                }
            })
            .map_err(|e| anyhow!("spawn responder: {e}"))?;

        let (ready_tx, ready_rx) = bounded::<Result<()>>(1);
        let w_store = store.clone();
        let w_quant = quant.clone();
        let w_precond = precond.clone();
        let w_pool = pool.clone();
        let handle = std::thread::Builder::new()
            .name("valuation-service".into())
            .spawn(move || -> Result<()> {
                let store = w_store;
                let quant = w_quant;
                let precond = w_precond;
                // Pay the one-time setup (eigendecomposition happened at
                // spawn; XLA compilation + lazy PJRT init here) BEFORE
                // signalling readiness, so no request ever observes it as
                // tail latency (§Perf log).
                let setup = (|| -> Result<Runtime> {
                    let rt = Runtime::open(&cfg.artifact_dir)?;
                    rt.warmup(&["logra_log", "score"])?;
                    // Compilation alone is not enough: the first EXECUTION
                    // of each program pays lazy PJRT initialization. Run
                    // both once with dummy inputs.
                    {
                        let man = &rt.manifest;
                        let p = f32_lit(&[man.n_params], &cfg.params)?;
                        let pr = f32_lit(&[man.proj_len], &cfg.proj_flat)?;
                        let zeros_tok = vec![0i32; man.log_batch * man.seq_len];
                        let tok = i32_lit(&[man.log_batch, man.seq_len], &zeros_tok)?;
                        rt.run_ref("logra_log", &[&p, &pr, &tok])?;
                        let zeros_a = vec![0.0; man.test_batch * man.k_total];
                        let a = f32_lit(&[man.test_batch, man.k_total], &zeros_a)?;
                        let zeros_b = vec![0.0; man.train_chunk * man.k_total];
                        let b = f32_lit(&[man.train_chunk, man.k_total], &zeros_b)?;
                        rt.run_ref("score", &[&a, &b])?;
                    }
                    Ok(rt)
                })();
                let rt = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        let _ = ready_tx.send(Err(e));
                        return Err(anyhow!("service setup failed: {msg}"));
                    }
                };
                // Native engines derive their scan chunk from the query
                // shape (chunk + test block sized to fit L2;
                // `linalg::kernels::auto_chunk_len`) — the resolved value
                // lands in `Metrics::scan_chunk_len`. Only the HLO score
                // program is pinned to the manifest's static train_chunk.
                let engine = match &quant {
                    // Quantized serving: int8 coarse scan + exact rescore.
                    // (spawn already validated the copy, so `new` cannot
                    // fail here in practice.)
                    Some(q) => Scanner::Two(
                        TwoStageEngine::new(q.clone(), store.clone(), precond.clone())?
                            .with_workers(cfg.scan_workers)
                            .with_chunk_len(0)
                            .with_rescore_factor(cfg.rescore_factor)
                            .with_metrics(m2.clone())
                            .with_pool(w_pool.clone().expect("pool spawned for quantized scan")),
                    ),
                    None => match store.as_single() {
                        Some(single) => {
                            Scanner::Seq(QueryEngine::new(&rt, single, precond.as_ref()))
                        }
                        None => Scanner::Par(
                            ParallelQueryEngine::new(store.clone(), precond.clone())
                                .with_workers(cfg.scan_workers)
                                .with_chunk_len(0)
                                .with_metrics(m2.clone())
                                .with_pool(w_pool.clone().expect("pool spawned for sharded store")),
                        ),
                    },
                };
                let man = &rt.manifest;
                // Gradient extraction runs at log_batch; scoring at
                // test_batch. Batch at most min(log_batch, test_batch)
                // requests so one artifact call covers both shapes.
                let nt = man.test_batch.min(man.log_batch);
                let lb = man.log_batch;
                let t = man.seq_len;
                let k = man.k_total;
                let params_lit = f32_lit(&[man.n_params], &cfg.params)?;
                let proj_lit = f32_lit(&[man.proj_len], &cfg.proj_flat)?;
                while let Some(first) = rx.recv() {
                    // Dynamic batching: gather up to nt requests, parking
                    // on the channel's condvar until the deadline (no
                    // sleep-polling).
                    let mut reqs = vec![first];
                    let deadline = Instant::now() + cfg.max_wait;
                    while reqs.len() < nt {
                        match rx.recv_deadline(deadline) {
                            Some(r) => reqs.push(r),
                            None => break,
                        }
                    }
                    let real = reqs.len();
                    m2.requests.fetch_add(real as u64, std::sync::atomic::Ordering::Relaxed);
                    m2.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Per-batch error isolation: a failing batch drops its
                    // requesters' response channels (they see an error)
                    // but must never kill the worker.
                    let admitted = (|| -> Result<Outcome> {
                        // Assemble the fixed-shape token batch at the
                        // gradient artifact's log_batch (pad repeats the
                        // last real row).
                        let mut tokens = Vec::with_capacity(lb * t);
                        for row in 0..lb {
                            let r = &reqs[row.min(real - 1)];
                            anyhow::ensure!(
                                r.tokens.len() == t,
                                "query length {} != seq_len {t}",
                                r.tokens.len()
                            );
                            tokens.extend_from_slice(&r.tokens);
                        }
                        let t0 = Instant::now();
                        let tok_lit = i32_lit(&[lb, t], &tokens)?;
                        let out = rt
                            .run_ref("logra_log", &[&params_lit, &proj_lit, &tok_lit])?;
                        let g_full = to_f32_vec(&out[0])?;
                        Metrics::add_nanos(&m2.grad_nanos, t0.elapsed().as_secs_f64());
                        // Re-pad the real gradient rows to the scoring
                        // batch shape (test_batch) for the HLO score path.
                        let mut g = Vec::with_capacity(nt * k);
                        for row in 0..nt {
                            let src = row.min(real - 1);
                            g.extend_from_slice(&g_full[src * k..(src + 1) * k]);
                        }

                        let topk = reqs.iter().map(|r| r.topk).max().unwrap_or(1).max(1);
                        // Only the HLO scorer needs the static test_batch
                        // shape; the native engines are shape-flexible, so
                        // drop the padding rows on an underfilled batch —
                        // less scan work, and per-request metrics
                        // (rows_scanned, candidates_rescored) stay honest.
                        match &engine {
                            Scanner::Seq(e) => {
                                let t1 = Instant::now();
                                let results = e.query(&g, nt, topk, cfg.norm)?;
                                Metrics::add_nanos(&m2.scan_nanos, t1.elapsed().as_secs_f64());
                                Ok(Outcome::Ready(results))
                            }
                            Scanner::Par(e) => Ok(Outcome::Par(
                                e.query_async(&g[..real * k], real, topk, cfg.norm)?,
                            )),
                            Scanner::Two(e) => Ok(Outcome::Two(
                                e.query_async(&g[..real * k], real, topk, cfg.norm)?,
                            )),
                        }
                    })();
                    match admitted {
                        Ok(outcome) => {
                            let inflight = InFlight {
                                reqs,
                                outcome,
                                submitted: Instant::now(),
                                rows: (store.rows() * real) as u64,
                            };
                            if done_tx.send(inflight).is_err() {
                                return Err(anyhow!("responder thread died"));
                            }
                        }
                        Err(e) => {
                            eprintln!("[valuation-service] batch failed: {e:#}");
                            // Dropping `reqs` closes the response channels.
                        }
                    }
                }
                Ok(())
            })?;
        // Block until the worker is warm (or report its setup error).
        match ready_rx.recv() {
            Some(Ok(())) => {}
            Some(Err(e)) => return Err(e),
            None => return Err(anyhow!("service worker died during setup")),
        }
        Ok(ValuationService {
            tx: Some(tx),
            handle: Some(handle),
            responder: Some(responder),
            pool,
            metrics,
            seq_len,
        })
    }

    /// The persistent scan pool (None when the sequential engine serves an
    /// unsharded store) — snapshot it for queue depth, per-worker busy
    /// time, and in-flight query counts.
    pub fn scan_pool(&self) -> Option<&Arc<ScanPool>> {
        self.pool.as_ref()
    }

    /// Blocking query: value `tokens` (must be exactly seq_len long).
    pub fn query(&self, tokens: Vec<i32>, topk: usize) -> Result<QueryResult> {
        anyhow::ensure!(
            tokens.len() == self.seq_len,
            "query length {} != seq_len {}",
            tokens.len(),
            self.seq_len
        );
        let (rtx, rrx) = bounded(1);
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("service closed"))?
            .send(ServiceRequest { tokens, topk, resp: rtx })
            .map_err(|_| anyhow!("service worker died"))?;
        rrx.recv().ok_or_else(|| anyhow!("service dropped request"))
    }

    /// Graceful shutdown: stop admission, drain in-flight scans (the pool
    /// completes every admitted task), then propagate worker errors.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.tx.take());
        let res = match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow!("service worker panicked"))?,
            None => Ok(()),
        };
        if let Some(r) = self.responder.take() {
            let _ = r.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
        res
    }
}

impl Drop for ValuationService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        if let Some(r) = self.responder.take() {
            let _ = r.join();
        }
        if let Some(p) = self.pool.take() {
            p.shutdown();
        }
    }
}
