//! Valuation service: dynamic request batching over the query engine —
//! the serving face of Figure 1 (left top + right).
//!
//! PJRT handles are not `Send`, so the service owns runtime + store +
//! preconditioner inside one worker thread (constructed there from
//! `Send` ingredients); callers talk to it through bounded channels.
//! Requests are coalesced up to the artifact's static `test_batch` shape
//! or until `max_wait` expires — classic dynamic batching: the HLO score
//! program amortizes its fixed cost over every query in the batch.
//!
//! Scanning dispatches on the store layout: a plain v1 store keeps the
//! sequential [`QueryEngine`] (HLO score path — there is nothing to fan
//! out over); a sharded store uses the parallel scan-and-merge engine,
//! whose results are bit-identical to a sequential NATIVE scan of the
//! same rows (the HLO and native scorers may differ in f32 rounding, so
//! resharding a corpus swaps scorer as well as parallelism). With
//! `quantized_scan` set (plus a `quant_dir` produced by
//! `logra store quantize`), queries run the two-stage engine instead:
//! int8 coarse scan over the quantized copy, exact f32 rescore of a
//! `rescore_factor × topk` candidate pool.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::metrics::Metrics;
use crate::hessian::BlockHessian;
use crate::runtime::literal::{f32_lit, i32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::store::{QuantShardedStore, ShardedStore};
use crate::util::pipeline::{bounded, Sender};
use crate::valuation::{
    Normalization, ParallelQueryEngine, QueryEngine, QueryResult, TwoStageEngine,
};

/// Service construction parameters (everything `Send`).
pub struct ServiceConfig {
    pub artifact_dir: PathBuf,
    pub store_dir: PathBuf,
    pub params: Vec<f32>,
    pub proj_flat: Vec<f32>,
    /// Pre-fitted Fisher blocks (from the logging phase).
    pub hessian: BlockHessian,
    pub damping: f32,
    pub norm: Normalization,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Scan worker threads for SHARDED stores (0 = one per core, N =
    /// fixed count). Unsharded v1 stores always use the sequential HLO
    /// engine — one shard has nothing to fan out over.
    pub scan_workers: usize,
    /// Serve queries through the two-stage engine: int8 coarse scan over
    /// the quantized copy at `quant_dir`, exact f32 rescore of a
    /// `rescore_factor × topk` candidate pool against `store_dir`.
    pub quantized_scan: bool,
    /// Stage-1 candidate pool multiplier (≥ 1; larger = higher recall,
    /// more exact-precision work). Ignored unless `quantized_scan`.
    pub rescore_factor: usize,
    /// Quantized copy of `store_dir` (from `logra store quantize`).
    /// Required when `quantized_scan` is set.
    pub quant_dir: Option<PathBuf>,
}

/// One LM valuation request: value this token sequence against the store.
struct ServiceRequest {
    tokens: Vec<i32>,
    topk: usize,
    resp: Sender<QueryResult>,
}

/// Any scan engine behind one `query` call.
enum Scanner<'a> {
    Seq(QueryEngine<'a>),
    Par(ParallelQueryEngine<'a>),
    Two(TwoStageEngine<'a>),
}

impl Scanner<'_> {
    fn query(
        &self,
        g: &[f32],
        nt: usize,
        topk: usize,
        norm: Normalization,
    ) -> Result<Vec<QueryResult>> {
        match self {
            Scanner::Seq(e) => e.query(g, nt, topk, norm),
            Scanner::Par(e) => e.query(g, nt, topk, norm),
            Scanner::Two(e) => e.query(g, nt, topk, norm),
        }
    }
}

/// Client handle; cloneable across threads.
pub struct ValuationService {
    tx: Option<Sender<ServiceRequest>>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    pub metrics: Arc<Metrics>,
    seq_len: usize,
}

impl ValuationService {
    /// Spawn the worker. Fails later (on first query) if artifacts are
    /// missing — construction itself is cheap.
    pub fn spawn(cfg: ServiceConfig) -> Result<Self> {
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (tx, rx) = bounded::<ServiceRequest>(64);
        // Probe seq_len from the manifest before moving cfg.
        let man = crate::runtime::Manifest::load(&cfg.artifact_dir)?;
        let seq_len = man.seq_len;
        anyhow::ensure!(man.is_lm(), "valuation service currently serves LM queries");
        let (ready_tx, ready_rx) = bounded::<Result<()>>(1);
        let handle = std::thread::Builder::new()
            .name("valuation-service".into())
            .spawn(move || -> Result<()> {
                // Pay the one-time setup (store open, eigendecomposition,
                // XLA compilation) BEFORE signalling readiness, so no
                // request ever observes it as tail latency (§Perf log).
                type Setup =
                    (Runtime, ShardedStore, Option<QuantShardedStore>, crate::hessian::Preconditioner);
                let setup = (|| -> Result<Setup> {
                    let rt = Runtime::open(&cfg.artifact_dir)?;
                    let store = ShardedStore::open(&cfg.store_dir)?;
                    // Open (and sanity-check) the quantized companion up
                    // front so a stale copy fails construction, not the
                    // first query.
                    let quant = if cfg.quantized_scan {
                        let qdir = cfg.quant_dir.as_ref().ok_or_else(|| {
                            anyhow!("quantized_scan requires quant_dir (run `logra store quantize`)")
                        })?;
                        let q = QuantShardedStore::open(qdir)?;
                        anyhow::ensure!(
                            q.rows() == store.rows() && q.k() == store.k(),
                            "quantized copy {} ({} rows, k={}) does not mirror store {} \
                             ({} rows, k={}) — re-run `logra store quantize`",
                            qdir.display(),
                            q.rows(),
                            q.k(),
                            cfg.store_dir.display(),
                            store.rows(),
                            store.k()
                        );
                        Some(q)
                    } else {
                        None
                    };
                    let precond = cfg.hessian.preconditioner(cfg.damping)?;
                    rt.warmup(&["logra_log", "score"])?;
                    // Compilation alone is not enough: the first EXECUTION
                    // of each program pays lazy PJRT initialization. Run
                    // both once with dummy inputs.
                    {
                        let man = &rt.manifest;
                        let p = f32_lit(&[man.n_params], &cfg.params)?;
                        let pr = f32_lit(&[man.proj_len], &cfg.proj_flat)?;
                        let zeros_tok = vec![0i32; man.log_batch * man.seq_len];
                        let tok = i32_lit(&[man.log_batch, man.seq_len], &zeros_tok)?;
                        rt.run_ref("logra_log", &[&p, &pr, &tok])?;
                        let zeros_a = vec![0.0; man.test_batch * man.k_total];
                        let a = f32_lit(&[man.test_batch, man.k_total], &zeros_a)?;
                        let zeros_b = vec![0.0; man.train_chunk * man.k_total];
                        let b = f32_lit(&[man.train_chunk, man.k_total], &zeros_b)?;
                        rt.run_ref("score", &[&a, &b])?;
                    }
                    Ok((rt, store, quant, precond))
                })();
                let (rt, store, quant, precond) = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        let _ = ready_tx.send(Err(e));
                        return Err(anyhow!("service setup failed: {msg}"));
                    }
                };
                let chunk_len = rt.manifest.train_chunk.max(1);
                let engine = match &quant {
                    // Quantized serving: int8 coarse scan + exact rescore.
                    // (Setup already validated the copy, so `new` cannot
                    // fail here in practice.)
                    Some(q) => Scanner::Two(
                        TwoStageEngine::new(q, &store, &precond)?
                            .with_workers(cfg.scan_workers)
                            .with_chunk_len(chunk_len)
                            .with_rescore_factor(cfg.rescore_factor)
                            .with_metrics(m2.clone()),
                    ),
                    None => match store.as_single() {
                        Some(single) => Scanner::Seq(QueryEngine::new(&rt, single, &precond)),
                        None => Scanner::Par(
                            ParallelQueryEngine::new(&store, &precond)
                                .with_workers(cfg.scan_workers)
                                .with_chunk_len(chunk_len)
                                .with_metrics(m2.clone()),
                        ),
                    },
                };
                let man = &rt.manifest;
                // Gradient extraction runs at log_batch; scoring at
                // test_batch. Batch at most min(log_batch, test_batch)
                // requests so one artifact call covers both shapes.
                let nt = man.test_batch.min(man.log_batch);
                let lb = man.log_batch;
                let t = man.seq_len;
                let k = man.k_total;
                let params_lit = f32_lit(&[man.n_params], &cfg.params)?;
                let proj_lit = f32_lit(&[man.proj_len], &cfg.proj_flat)?;
                while let Some(first) = rx.recv() {
                    // Dynamic batching: gather up to nt requests, parking
                    // on the channel's condvar until the deadline (no
                    // sleep-polling).
                    let mut reqs = vec![first];
                    let deadline = Instant::now() + cfg.max_wait;
                    while reqs.len() < nt {
                        match rx.recv_deadline(deadline) {
                            Some(r) => reqs.push(r),
                            None => break,
                        }
                    }
                    let real = reqs.len();
                    m2.requests.fetch_add(real as u64, std::sync::atomic::Ordering::Relaxed);
                    m2.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Per-batch error isolation: a failing batch drops its
                    // requesters' response channels (they see an error)
                    // but must never kill the worker.
                    let batch_result = (|| -> Result<Vec<crate::valuation::QueryResult>> {
                        // Assemble the fixed-shape token batch at the
                        // gradient artifact's log_batch (pad repeats the
                        // last real row).
                        let mut tokens = Vec::with_capacity(lb * t);
                        for row in 0..lb {
                            let r = &reqs[row.min(real - 1)];
                            anyhow::ensure!(
                                r.tokens.len() == t,
                                "query length {} != seq_len {t}",
                                r.tokens.len()
                            );
                            tokens.extend_from_slice(&r.tokens);
                        }
                        let t0 = Instant::now();
                        let tok_lit = i32_lit(&[lb, t], &tokens)?;
                        let out = rt
                            .run_ref("logra_log", &[&params_lit, &proj_lit, &tok_lit])?;
                        let g_full = to_f32_vec(&out[0])?;
                        Metrics::add_nanos(&m2.grad_nanos, t0.elapsed().as_secs_f64());
                        // Re-pad the real gradient rows to the scoring
                        // batch shape (test_batch) for the HLO score path.
                        let mut g = Vec::with_capacity(nt * k);
                        for row in 0..nt {
                            let src = row.min(real - 1);
                            g.extend_from_slice(&g_full[src * k..(src + 1) * k]);
                        }

                        let topk = reqs.iter().map(|r| r.topk).max().unwrap_or(1);
                        let t1 = Instant::now();
                        // Only the HLO scorer needs the static test_batch
                        // shape; the native engines are shape-flexible, so
                        // drop the padding rows on an underfilled batch —
                        // less scan work, and per-request metrics
                        // (rows_scanned, candidates_rescored) stay honest.
                        let (q, qn) = match &engine {
                            Scanner::Seq(_) => (&g[..], nt),
                            Scanner::Par(_) | Scanner::Two(_) => (&g[..real * k], real),
                        };
                        let results = engine.query(q, qn, topk.max(1), cfg.norm)?;
                        Metrics::add_nanos(&m2.scan_nanos, t1.elapsed().as_secs_f64());
                        m2.rows_scanned.fetch_add(
                            (store.rows() * real) as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        Ok(results)
                    })();
                    match batch_result {
                        Ok(results) => {
                            for (i, req) in reqs.into_iter().enumerate() {
                                let mut r = results[i].clone();
                                r.top.truncate(req.topk);
                                let _ = req.resp.send(r);
                            }
                        }
                        Err(e) => {
                            eprintln!("[valuation-service] batch failed: {e:#}");
                            // Dropping `reqs` closes the response channels.
                        }
                    }
                }
                Ok(())
            })?;
        // Block until the worker is warm (or report its setup error).
        match ready_rx.recv() {
            Some(Ok(())) => {}
            Some(Err(e)) => return Err(e),
            None => return Err(anyhow!("service worker died during setup")),
        }
        Ok(ValuationService { tx: Some(tx), handle: Some(handle), metrics, seq_len })
    }

    /// Blocking query: value `tokens` (must be exactly seq_len long).
    pub fn query(&self, tokens: Vec<i32>, topk: usize) -> Result<QueryResult> {
        anyhow::ensure!(
            tokens.len() == self.seq_len,
            "query length {} != seq_len {}",
            tokens.len(),
            self.seq_len
        );
        let (rtx, rrx) = bounded(1);
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("service closed"))?
            .send(ServiceRequest { tokens, topk, resp: rtx })
            .map_err(|_| anyhow!("service worker died"))?;
        rrx.recv().ok_or_else(|| anyhow!("service dropped request"))
    }

    /// Graceful shutdown; propagates worker errors.
    pub fn shutdown(mut self) -> Result<()> {
        drop(self.tx.take());
        match self.handle.take() {
            Some(h) => h.join().map_err(|_| anyhow!("service worker panicked"))?,
            None => Ok(()),
        }
    }
}

impl Drop for ValuationService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
