//! L3 coordinator — the paper's system contribution (Figure 1): the
//! logging pipeline that populates the gradient store + Fisher blocks,
//! the KFAC pre-pass, query-side gradient extraction, the dynamic-batching
//! valuation service, and service metrics.

pub mod logging;
pub mod metrics;
pub mod service;

pub use logging::{fit_kfac, projected_grads, run_logging, LoggingOptions, LoggingReport};
pub use metrics::{Metrics, MetricsSnapshot};
pub use service::{ServiceConfig, ValuationService};
