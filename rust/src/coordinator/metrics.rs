//! Lightweight service metrics (atomic counters; no external deps).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::Obs;

/// Shared counters for the valuation service.
#[derive(Default)]
pub struct Metrics {
    /// Observability state (trace ring, latency histograms, query ids) —
    /// attaching a `Metrics` to a backend opts the whole layer in. See
    /// [`crate::obs`].
    pub obs: Obs,
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub rows_scanned: AtomicU64,
    pub scan_nanos: AtomicU64,
    pub grad_nanos: AtomicU64,
    pub queue_wait_nanos: AtomicU64,
    /// Shard scans completed by the parallel engine (one per shard per
    /// query batch).
    pub shards_scanned: AtomicU64,
    /// Summed per-shard scan time across workers. With W busy workers this
    /// accrues ~W× faster than `scan_nanos` wall time — the ratio is the
    /// scan's effective parallelism.
    pub shard_scan_nanos: AtomicU64,
    /// Two-stage engine: wall time of the stage-1 quantized coarse scan.
    pub stage1_nanos: AtomicU64,
    /// Two-stage engine: wall time of the stage-2 exact rescore.
    pub stage2_nanos: AtomicU64,
    /// Two-stage engine: candidate rows rescored at exact precision (the
    /// sublinear full-precision workload; compare against `rows_scanned`).
    pub candidates_rescored: AtomicU64,
    /// IVF engine: rows named by the stage-0 probe (the stage-1 coarse
    /// scan's workload — strictly below the corpus row count whenever the
    /// index is pruning).
    pub rows_probed: AtomicU64,
    /// Scan-pool workers ACTUALLY spawned (after `workers = 0` auto
    /// resolution) — the pool, not the config, is the authority. 0 when the
    /// service runs the sequential engine (no pool). Detailed pool health
    /// (queue depth, busy nanos, task counts) lives in
    /// `valuation::PoolSnapshot` via `ValuationService::scan_pool`.
    pub pool_workers: AtomicU64,
    /// Scan chunk length (rows per kernel call) the native engines
    /// RESOLVED for the latest query — the L2-fit auto derivation
    /// (`linalg::kernels::auto_chunk_len`) unless an explicit
    /// `BackendConfig::chunk_len` override pinned it. 0 until the first
    /// query.
    pub scan_chunk_len: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rows_scanned: self.rows_scanned.load(Ordering::Relaxed),
            scan_seconds: self.scan_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            grad_seconds: self.grad_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            queue_wait_seconds: self.queue_wait_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            shards_scanned: self.shards_scanned.load(Ordering::Relaxed),
            shard_scan_seconds: self.shard_scan_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            stage1_seconds: self.stage1_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            stage2_seconds: self.stage2_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            candidates_rescored: self.candidates_rescored.load(Ordering::Relaxed),
            rows_probed: self.rows_probed.load(Ordering::Relaxed),
            pool_workers: self.pool_workers.load(Ordering::Relaxed),
            scan_chunk_len: self.scan_chunk_len.load(Ordering::Relaxed),
        }
    }

    /// Add a duration measured in SECONDS to a nanosecond counter.
    pub fn add_seconds(counter: &AtomicU64, seconds: f64) {
        counter.fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
    }
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rows_scanned: u64,
    pub scan_seconds: f64,
    pub grad_seconds: f64,
    pub queue_wait_seconds: f64,
    pub shards_scanned: u64,
    pub shard_scan_seconds: f64,
    pub stage1_seconds: f64,
    pub stage2_seconds: f64,
    pub candidates_rescored: u64,
    pub rows_probed: u64,
    pub pool_workers: u64,
    pub scan_chunk_len: u64,
}

impl MetricsSnapshot {
    /// (train, test) pairs per second — the paper's Table-1 influence
    /// throughput metric.
    pub fn pairs_per_sec(&self, tests_per_batch: u64) -> f64 {
        let pairs = self.rows_scanned * tests_per_batch;
        let secs = self.scan_seconds.max(1e-12);
        pairs as f64 / secs
    }

    /// Mean batch occupancy (dynamic-batching effectiveness).
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Summed worker scan time over wall scan time: the parallel scan's
    /// effective concurrency (~1.0 sequential, ~W with W busy workers).
    pub fn scan_concurrency(&self) -> f64 {
        if self.scan_seconds <= 0.0 {
            0.0
        } else {
            self.shard_scan_seconds / self.scan_seconds
        }
    }

    /// Fraction of scanned rows that reached the exact rescore stage — the
    /// two-stage engine's sublinearity (≈ rescore_factor·topk / rows when
    /// quantized scanning is on; 0.0 on full-precision paths).
    pub fn rescore_fraction(&self) -> f64 {
        if self.rows_scanned == 0 {
            0.0
        } else {
            self.candidates_rescored as f64 / self.rows_scanned as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_math() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.batches.store(4, Ordering::Relaxed);
        m.rows_scanned.store(1000, Ordering::Relaxed);
        Metrics::add_seconds(&m.scan_nanos, 2.0);
        m.shards_scanned.store(8, Ordering::Relaxed);
        Metrics::add_seconds(&m.shard_scan_nanos, 6.0);
        Metrics::add_seconds(&m.stage1_nanos, 1.5);
        Metrics::add_seconds(&m.stage2_nanos, 0.5);
        m.candidates_rescored.store(40, Ordering::Relaxed);
        m.pool_workers.store(6, Ordering::Relaxed);
        m.scan_chunk_len.store(640, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.pool_workers, 6);
        assert_eq!(s.scan_chunk_len, 640);
        assert!((s.mean_batch_fill() - 2.5).abs() < 1e-12);
        assert!((s.pairs_per_sec(4) - 2000.0).abs() < 1.0);
        assert_eq!(s.shards_scanned, 8);
        assert!((s.scan_concurrency() - 3.0).abs() < 1e-9);
        assert!((s.stage1_seconds - 1.5).abs() < 1e-9);
        assert!((s.stage2_seconds - 0.5).abs() < 1e-9);
        assert!((s.rescore_fraction() - 0.04).abs() < 1e-12);
    }
}
