//! Host-side dense linear algebra: the K×K / n×n work around the AOT HLO
//! programs (Hessian blocks, eigendecompositions, SPD solves, PCA init),
//! plus the scan-kernel subsystem ([`kernels`]) that every influence
//! score's hot loop runs through.

pub mod eigh;
pub mod kernels;
pub mod matrix;
pub mod solve;

pub use eigh::{eigh, Eigh};
pub use kernels::{kernel_arm, KernelArm, ScanScratch};
pub use matrix::{cosine, dot, norm, Matrix};
pub use solve::{cholesky, solve_spd};
