//! Host-side dense linear algebra: the K×K / n×n work around the AOT HLO
//! programs (Hessian blocks, eigendecompositions, SPD solves, PCA init).

pub mod eigh;
pub mod matrix;
pub mod solve;

pub use eigh::{eigh, Eigh};
pub use matrix::{cosine, dot, norm, Matrix};
pub use solve::{cholesky, solve_spd};
