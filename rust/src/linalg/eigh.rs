//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! The Hessian service needs eigh three ways: (1) damped iHVP in the
//! projected space (Lemma 1's spectral form), (2) KFAC factor eigenbases
//! for the LoGra-PCA initialization (§3.2), (3) the EKFAC baseline's
//! rotation. Matrix sizes here are ≤ ~1k, where Jacobi is simple, robust
//! and accurate (it converges quadratically and keeps eigenvectors
//! orthogonal by construction). f64 accumulation internally; f32 I/O.

use crate::linalg::matrix::Matrix;

/// Eigendecomposition result: `a == q * diag(lambda) * q^T`, eigenvalues
/// ascending, eigenvectors as COLUMNS of `q`.
pub struct Eigh {
    pub eigenvalues: Vec<f32>,
    /// Column-eigenvector matrix, row-major [n, n]: `q[r*n + c]` is the
    /// r-th component of the c-th eigenvector.
    pub q: Matrix,
}

impl Eigh {
    /// The k eigenvectors with LARGEST eigenvalues, as rows [k, n]
    /// (exactly the LoGra-PCA `P` layout: projection = P @ x).
    pub fn top_k_rows(&self, k: usize) -> Matrix {
        let n = self.q.rows;
        assert!(k <= n);
        let mut p = Matrix::zeros(k, n);
        for i in 0..k {
            let col = n - 1 - i; // ascending order -> take from the back
            for r in 0..n {
                p.data[i * n + r] = self.q.at(r, col);
            }
        }
        p
    }
}

/// Cyclic Jacobi with threshold sweeps. `a` must be symmetric.
pub fn eigh(a: &Matrix) -> Eigh {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    // Work in f64: Jacobi's accuracy advantage is lost in f32 for n ~ 1k.
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    // Symmetrize defensively (accumulation order upstream may skew ulps).
    for r in 0..n {
        for c in (r + 1)..n {
            let avg = 0.5 * (m[r * n + c] + m[c * n + r]);
            m[r * n + c] = avg;
            m[c * n + r] = avg;
        }
    }
    let mut q = vec![0.0f64; n * n];
    for i in 0..n {
        q[i * n + i] = 1.0;
    }

    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for r in 0..n {
            for c in (r + 1)..n {
                off += m[r * n + c] * m[r * n + c];
            }
        }
        if off.sqrt() < 1e-11 * (1.0 + frob(&m, n)) {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apr = m[p * n + r];
                if apr.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let arr = m[r * n + r];
                // Rotation angle.
                let tau = (arr - app) / (2.0 * apr);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation G(p, r, theta) on both sides.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkr = m[k * n + r];
                    m[k * n + p] = c * mkp - s * mkr;
                    m[k * n + r] = s * mkp + c * mkr;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mrk = m[r * n + k];
                    m[p * n + k] = c * mpk - s * mrk;
                    m[r * n + k] = s * mpk + c * mrk;
                }
                for k in 0..n {
                    let qkp = q[k * n + p];
                    let qkr = q[k * n + r];
                    q[k * n + p] = c * qkp - s * qkr;
                    q[k * n + r] = s * qkp + c * qkr;
                }
            }
        }
    }

    // Extract and sort ascending.
    let mut pairs: Vec<(f64, usize)> =
        (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut eigenvalues = Vec::with_capacity(n);
    let mut qm = Matrix::zeros(n, n);
    for (dst, &(val, src)) in pairs.iter().enumerate() {
        eigenvalues.push(val as f32);
        for r in 0..n {
            qm.data[r * n + dst] = q[r * n + src] as f32;
        }
    }
    Eigh { eigenvalues, q: qm }
}

fn frob(m: &[f64], n: usize) -> f64 {
    m.iter().take(n * n).map(|x| x * x).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_symmetric(rng: &mut Pcg32, n: usize) -> Matrix {
        let a = Matrix::random_normal(rng, n, n, 1.0);
        let mut s = Matrix::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                s.data[r * n + c] = 0.5 * (a.at(r, c) + a.at(c, r));
            }
        }
        s
    }

    fn reconstruct(e: &Eigh) -> Matrix {
        let n = e.q.rows;
        let mut out = Matrix::zeros(n, n);
        for i in 0..n {
            let lam = e.eigenvalues[i];
            for r in 0..n {
                for c in 0..n {
                    out.data[r * n + c] += lam * e.q.at(r, i) * e.q.at(c, i);
                }
            }
        }
        out
    }

    #[test]
    fn reconstructs_random_symmetric() {
        let mut rng = Pcg32::seeded(1);
        for n in [1, 2, 3, 8, 33, 64] {
            let a = random_symmetric(&mut rng, n);
            let e = eigh(&a);
            let rec = reconstruct(&e);
            let scale = a.fro_norm().max(1.0);
            assert!(
                a.max_abs_diff(&rec) < 2e-5 * scale,
                "n={n}: {}",
                a.max_abs_diff(&rec)
            );
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Pcg32::seeded(2);
        let a = random_symmetric(&mut rng, 24);
        let e = eigh(&a);
        let qtq = e.q.transpose().matmul(&e.q);
        assert!(qtq.max_abs_diff(&Matrix::identity(24)) < 1e-4);
    }

    #[test]
    fn eigenvalues_ascending_and_known_case() {
        // [[2, 1], [1, 2]] -> eigenvalues 1, 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.eigenvalues[0] - 1.0).abs() < 1e-5);
        assert!((e.eigenvalues[1] - 3.0).abs() < 1e-5);
    }

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let mut d = Matrix::zeros(4, 4);
        for (i, v) in [4.0, -1.0, 2.5, 0.0].iter().enumerate() {
            d.data[i * 4 + i] = *v;
        }
        let e = eigh(&d);
        let mut want = vec![-1.0, 0.0, 2.5, 4.0];
        for (got, want) in e.eigenvalues.iter().zip(want.drain(..)) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn top_k_rows_extracts_largest() {
        let mut d = Matrix::zeros(3, 3);
        d.data[0] = 1.0;
        d.data[4] = 5.0;
        d.data[8] = 3.0;
        let e = eigh(&d);
        let p = e.top_k_rows(1);
        // Largest eigenvalue 5 has eigenvector e_1.
        assert!((p.at(0, 1).abs() - 1.0).abs() < 1e-5);
        assert!(p.at(0, 0).abs() < 1e-5 && p.at(0, 2).abs() < 1e-5);
    }

    #[test]
    fn psd_gram_matrix_nonnegative() {
        let mut rng = Pcg32::seeded(3);
        let b = Matrix::random_normal(&mut rng, 10, 6, 1.0);
        let g = b.transpose().matmul(&b); // PSD 6x6
        let e = eigh(&g);
        assert!(e.eigenvalues.iter().all(|&l| l > -1e-4));
    }
}
