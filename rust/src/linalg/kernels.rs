//! Scan-kernel subsystem: the SIMD register-tiled microkernels behind
//! every influence score in the system, plus the zero-allocation scratch
//! discipline the scan engines thread through their hot loops.
//!
//! The paper's throughput claim (§4.2: "write projected gradients once,
//! scan forever") lives or dies on the per-chunk score kernel. Before this
//! module, the f32 path was a single-accumulator triple loop
//! ([`crate::linalg::matrix::matmul_t_slices`], now the naive test/bench
//! reference) and the int8 path re-walked [`crate::store::quant::dot_q8`]
//! pair by pair — both serial-dependency-chained, both allocating a fresh
//! `[nt, len]` output per chunk. This module replaces them with:
//!
//! - **f32**: a register-tiled `A·Bᵀ` microkernel ([`matmul_t_into`]) with
//!   an AVX2+FMA arm (4×2 output tiles, one 8-lane accumulator per cell —
//!   eight independent FMA chains in flight, loaded vectors reused across
//!   the tile) and a portable scalar arm (8 independent accumulator lanes
//!   per dot, unrolled by 8 with a ragged tail — the shape LLVM
//!   auto-vectorizes).
//! - **int8**: a train-row-major quantized scan kernel ([`scan_q8_into`])
//!   holding the test rows hot so each train row's codes stream exactly
//!   once per chunk, with an AVX2 `maddubs` block-dot arm (32 int8
//!   products per instruction via the abs/sign trick) and an unrolled
//!   scalar arm; per-block scale products are formed once, outside the
//!   64-wide inner loop.
//! - **scratch reuse**: `_into` kernels write caller-owned buffers;
//!   [`ScanScratch`] is the per-worker lease of those buffers, so the
//!   steady-state scan performs **zero heap allocation per chunk**
//!   (observable via [`ScanScratch::grows`]).
//! - **cache blocking**: [`auto_chunk_len`] derives the default scan chunk
//!   so one train chunk + the test block + the score tile fit in L2.
//!
//! # Dispatch
//!
//! [`kernel_arm`] resolves ONCE per process: `LOGRA_FORCE_SCALAR=1` pins
//! the scalar arm (the CI lane that keeps both arms covered); otherwise
//! `is_x86_feature_detected!` picks AVX2+FMA when the CPU has it. A single
//! process never mixes arms, which is what makes the determinism contract
//! below hold.
//!
//! # Determinism contract
//!
//! Every f32 score is a **pure function of the two rows it scores** —
//! independent of chunk boundaries, tile position, output shape, worker
//! count, or which engine asked. Each output cell owns its accumulators
//! and consumes `k` in the same fixed order (8-wide groups, fixed pairwise
//! reduction tree, ragged tail appended last), whether it was computed in
//! the middle of a 4×2 tile, on a remainder edge, or by the standalone
//! [`dot_f32`] the two-stage rescore uses. SIMD changes the *summation
//! order vs the old naive kernel* (so absolute scores moved once, at this
//! PR), but because the sequential reference engine and both parallel
//! engines share this one kernel layer, cross-engine bit-identity — the
//! property `rust/tests/pool.rs` and `rust/tests/twostage.rs` pin — is
//! preserved for any sharding, chunking, or interleaving. The int8 kernel
//! is stronger still: block sums are exact integers and the per-block f32
//! combine order is fixed, so its scores are bit-identical **across
//! arms** and to the [`crate::store::quant::dot_q8`] reference
//! (property-tested in `rust/tests/kernels.rs`).

use std::sync::OnceLock;

/// Values per int8 quantization block (one f32 scale each). The store
/// codec's `QUANT_BLOCK` is defined as this constant.
pub const Q8_BLOCK: usize = 64;

/// Width of the f32 dot discipline: independent accumulator lanes per
/// output cell (8 f32 = one 256-bit register on the AVX2 arm).
pub const F32_LANES: usize = 8;

// -------------------------------------------------------------- dispatch

/// Which kernel implementation this process runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelArm {
    /// `std::arch` AVX2 + FMA intrinsics (x86_64, runtime-detected).
    Avx2Fma,
    /// Portable unrolled-scalar fallback (also the forced-scalar CI lane).
    Scalar,
}

impl KernelArm {
    /// Short name for logs / bench reports.
    pub fn name(self) -> &'static str {
        match self {
            KernelArm::Avx2Fma => "avx2+fma",
            KernelArm::Scalar => "scalar",
        }
    }
}

static ARM: OnceLock<KernelArm> = OnceLock::new();

/// The dispatch arm, resolved once per process: `LOGRA_FORCE_SCALAR`
/// (any value other than empty/`0`/`false`) pins the scalar arm, else
/// runtime CPU feature detection picks the widest available. Cached so a
/// process can never mix summation orders mid-flight.
pub fn kernel_arm() -> KernelArm {
    *ARM.get_or_init(|| {
        if force_scalar_env() {
            KernelArm::Scalar
        } else {
            detect_arm()
        }
    })
}

fn force_scalar_env() -> bool {
    match std::env::var("LOGRA_FORCE_SCALAR") {
        Ok(v) => {
            let v = v.trim();
            !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
        }
        Err(_) => false,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_arm() -> KernelArm {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        KernelArm::Avx2Fma
    } else {
        KernelArm::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_arm() -> KernelArm {
    KernelArm::Scalar
}

// --------------------------------------------------------------- scratch

/// Per-worker reusable scratch for the scan hot loop. The `_into` kernels
/// write caller-owned buffers; this type is where those buffers live
/// between chunks, so a steady-state shard scan allocates **nothing** per
/// chunk: each lease grows the backing `Vec` at most once (to the largest
/// size ever requested) and [`grows`](ScanScratch::grows) counts those
/// growth events — the zero-alloc claim's observable.
///
/// One instance per scan worker: [`crate::valuation::ScanPool`] workers
/// own one for their lifetime, the per-query scatter/gather path owns one
/// per scoped thread, and the sequential engine keeps one per engine.
#[derive(Default)]
pub struct ScanScratch {
    score: Vec<f32>,
    aux: Vec<f32>,
    grows: u64,
}

impl ScanScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lease the score buffer at `len` elements (contents unspecified —
    /// kernels overwrite every cell).
    pub fn score_buf(&mut self, len: usize) -> &mut [f32] {
        Self::lease(&mut self.score, &mut self.grows, len)
    }

    /// Lease the auxiliary f32 buffer (preconditioned-row staging for the
    /// batched self-influence path).
    pub fn aux_buf(&mut self, len: usize) -> &mut [f32] {
        Self::lease(&mut self.aux, &mut self.grows, len)
    }

    fn lease<'a>(buf: &'a mut Vec<f32>, grows: &mut u64, len: usize) -> &'a mut [f32] {
        if buf.capacity() < len {
            *grows += 1;
        }
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        &mut buf[..len]
    }

    /// Allocation growth events since construction. In steady state this
    /// saturates at one per distinct buffer in use (score, aux) and then
    /// stays flat — asserted by the zero-alloc tests.
    pub fn grows(&self) -> u64 {
        self.grows
    }
}

// -------------------------------------------------------- cache blocking

/// L2 working-set target for [`auto_chunk_len`]: conservative for any
/// core this decade (most have 512 KiB–2 MiB private L2).
const L2_TARGET_BYTES: usize = 512 * 1024;

/// Smallest / largest auto-derived chunk (rows). The floor keeps tiny-k
/// stores from degenerating into per-row calls; the cap bounds per-task
/// latency so pool interleaving stays responsive.
const MIN_CHUNK: usize = 64;
const MAX_CHUNK: usize = 8192;

/// Derive a scan `chunk_len` from the query shape: the largest multiple
/// of 64 such that one train chunk (`train_row_bytes` per row), the test
/// block (`nt × k` f32), and the score tile (`nt` f32 per train row) fit
/// the L2 target together, clamped to `[64, 8192]`. Engines use this when
/// their `chunk_len` knob is 0 (the default); an explicit knob value
/// overrides it unchanged.
pub fn auto_chunk_len(k: usize, nt: usize, train_row_bytes: usize) -> usize {
    let test_bytes = nt * k * 4;
    let per_row = train_row_bytes + nt * 4;
    let budget = L2_TARGET_BYTES.saturating_sub(test_bytes);
    let chunk = budget / per_row.max(1);
    (chunk / 64 * 64).clamp(MIN_CHUNK, MAX_CHUNK)
}

// -------------------------------------------------------------- f32 dots

/// Shared f32 dot: the one summation discipline every f32 influence score
/// goes through (chunk kernels, two-stage exact rescore, self-influence
/// denominators). Dispatches on [`kernel_arm`].
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    // Hard assert: the AVX2 arm does raw-pointer loads sized by `a`, so a
    // short `b` would be UB from a safe fn, not just a wrong answer.
    assert_eq!(a.len(), b.len(), "dot_f32: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if kernel_arm() == KernelArm::Avx2Fma {
        // SAFETY: arm implies avx2+fma are available on this CPU; the
        // length assert above bounds every pointer the intrinsics touch.
        return unsafe { avx2::dot(a, b) };
    }
    dot_f32_scalar(a, b)
}

/// Scalar arm of the dot discipline: 8 independent accumulator lanes over
/// the unrolled body (element `i` lands in lane `i % 8`), the ragged tail
/// continuing the lane assignment, then a fixed pairwise reduction tree
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`. Breaking the serial FP chain
/// into 8 lanes is both the ILP win and the shape LLVM vectorizes.
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; F32_LANES];
    let mut ca = a.chunks_exact(F32_LANES);
    let mut cb = b.chunks_exact(F32_LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for (lane, (x, y)) in acc.iter_mut().zip(xa.iter().zip(xb)) {
            *lane += x * y;
        }
    }
    for (lane, (x, y)) in acc.iter_mut().zip(ca.remainder().iter().zip(cb.remainder())) {
        *lane += x * y;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// `out = A·Bᵀ` over raw row-major slices: A is `[m, k]` (test rows,
/// preconditioned), B is `[n, k]` (train chunk), `out` is `[m, n]` and
/// fully overwritten. Every cell equals `dot_f32(a_row, b_row)` bitwise —
/// the determinism contract — while the AVX2 arm computes interior cells
/// in 4×2 register tiles for load reuse and ILP.
pub fn matmul_t_into(a: &[f32], m: usize, b: &[f32], n: usize, k: usize, out: &mut [f32]) {
    // Hard asserts (not debug_): the AVX2 arm does raw-pointer loads, so
    // undersized inputs would be UB from a safe fn in release builds. The
    // cost is nothing next to the O(m·n·k) kernel work.
    assert_eq!(a.len(), m * k, "matmul_t_into: a is not [m, k]");
    assert_eq!(b.len(), n * k, "matmul_t_into: b is not [n, k]");
    assert_eq!(out.len(), m * n, "matmul_t_into: out is not [m, n]");
    #[cfg(target_arch = "x86_64")]
    if kernel_arm() == KernelArm::Avx2Fma {
        // SAFETY: arm implies avx2+fma are available on this CPU; the
        // shape asserts above bound every pointer the intrinsics touch.
        unsafe { avx2::matmul_t(a, m, b, n, k, out) };
        return;
    }
    matmul_t_scalar_into(a, m, b, n, k, out);
}

/// Scalar arm of [`matmul_t_into`]: per-cell [`dot_f32_scalar`]. The
/// A-row stays L1-hot across the `n` inner iterations; cache blocking of
/// B is the caller's chunking ([`auto_chunk_len`]).
pub fn matmul_t_scalar_into(a: &[f32], m: usize, b: &[f32], n: usize, k: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot_f32_scalar(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Row-paired dots: `out.push(dot_f32(a_row_i, b_row_i))` for each of the
/// `n` rows — the batched self-influence kernel (`a` = preconditioned
/// rows, `b` = raw rows). Appends to `out` so shard-level callers
/// accumulate chunk results without a copy.
pub fn rowwise_dot_extend(a: &[f32], b: &[f32], n: usize, k: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), n * k);
    out.reserve(n);
    for r in 0..n {
        out.push(dot_f32(&a[r * k..(r + 1) * k], &b[r * k..(r + 1) * k]));
    }
}

// -------------------------------------------------------------- int8 scan

/// Quantized scan kernel: score `nt` quantized test rows against `len`
/// quantized train rows into row-major `out` (`[nt, len]`, fully
/// overwritten). Iterates train-row-major — each train row's codes and
/// scales are streamed exactly once per chunk while the (small) test
/// block stays cache-hot — with per-64-block i32 accumulation and the
/// block's scale product formed once, outside the inner loop.
///
/// Block sums are exact integers and the per-block f32 combine order is
/// fixed, so the result is bit-identical across dispatch arms and to the
/// [`crate::store::quant::dot_q8`] reference. Codes must lie in
/// `[-127, 127]` (the store codec's clamp) — the AVX2 arm's abs/sign
/// trick does not cover `-128`.
#[allow(clippy::too_many_arguments)]
pub fn scan_q8_into(
    t_codes: &[i8],
    t_scales: &[f32],
    nt: usize,
    codes: &[i8],
    scales: &[f32],
    len: usize,
    k: usize,
    out: &mut [f32],
) {
    let blocks = k.div_ceil(Q8_BLOCK);
    // Hard asserts (not debug_): the AVX2 arm does raw-pointer 64-byte
    // block loads, so undersized inputs would be UB from a safe fn in
    // release builds.
    assert_eq!(t_codes.len(), nt * k, "scan_q8_into: t_codes is not [nt, k]");
    assert_eq!(t_scales.len(), nt * blocks, "scan_q8_into: t_scales is not [nt, blocks]");
    assert_eq!(codes.len(), len * k, "scan_q8_into: codes is not [len, k]");
    assert_eq!(scales.len(), len * blocks, "scan_q8_into: scales is not [len, blocks]");
    assert_eq!(out.len(), nt * len, "scan_q8_into: out is not [nt, len]");
    #[cfg(target_arch = "x86_64")]
    if kernel_arm() == KernelArm::Avx2Fma {
        // SAFETY: arm implies avx2 is available; the shape asserts above
        // bound every pointer the intrinsics touch.
        unsafe { avx2::scan_q8(t_codes, t_scales, nt, codes, scales, len, k, out) };
        return;
    }
    scan_q8_scalar_into(t_codes, t_scales, nt, codes, scales, len, k, out);
}

/// Scalar arm of [`scan_q8_into`]: widened i16 products summed in i32
/// (both factors are in `[-127, 127]`, so an i16 product is exact and
/// pairs sum without overflow — the `pmaddwd` shape).
#[allow(clippy::too_many_arguments)]
pub fn scan_q8_scalar_into(
    t_codes: &[i8],
    t_scales: &[f32],
    nt: usize,
    codes: &[i8],
    scales: &[f32],
    len: usize,
    k: usize,
    out: &mut [f32],
) {
    let blocks = k.div_ceil(Q8_BLOCK);
    for j in 0..len {
        let jc = &codes[j * k..(j + 1) * k];
        let js = &scales[j * blocks..(j + 1) * blocks];
        for t in 0..nt {
            let tc = &t_codes[t * k..(t + 1) * k];
            let ts = &t_scales[t * blocks..(t + 1) * blocks];
            let mut acc = 0.0f32;
            for b in 0..blocks {
                let lo = b * Q8_BLOCK;
                let hi = (lo + Q8_BLOCK).min(k);
                let mut s = 0i32;
                for (&x, &y) in tc[lo..hi].iter().zip(&jc[lo..hi]) {
                    s += (x as i16 * y as i16) as i32;
                }
                acc += (ts[b] * js[b]) * s as f32;
            }
            out[t * len + j] = acc;
        }
    }
}

// ------------------------------------------------------------- AVX2 arms

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{F32_LANES, Q8_BLOCK};
    use std::arch::x86_64::*;

    /// Reduce one 8-lane f32 accumulator with the fixed tree
    /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` (two horizontal adds, then
    /// the 128-bit halves) — the same tree the scalar arm uses.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn reduce8(v: __m256) -> f32 {
        unsafe {
            let h1 = _mm256_hadd_ps(v, v);
            let h2 = _mm256_hadd_ps(h1, h1);
            let lo = _mm256_castps256_ps128(h2);
            let hi = _mm256_extractf128_ps::<1>(h2);
            _mm_cvtss_f32(_mm_add_ss(lo, hi))
        }
    }

    /// Append the ragged tail (k % 8 elements) to a reduced total with
    /// plain mul+add, in index order — shared by every cell so tails
    /// cannot perturb per-cell identity.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn tail_mul_add(total: f32, a: &[f32], b: &[f32]) -> f32 {
        let mut t = total;
        for (x, y) in a.iter().zip(b) {
            t += x * y;
        }
        t
    }

    /// AVX2 arm of the dot discipline: one 8-lane FMA accumulator over
    /// the unrolled body, tree reduction, scalar tail. Exactly the
    /// per-cell sequence of the tiled kernel, so `dot(a_row, b_row)` is
    /// bitwise what any tile cell would produce for the same rows.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let k = a.len();
        let k8 = k - k % F32_LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        unsafe {
            let mut acc = _mm256_setzero_ps();
            let mut p = 0usize;
            while p < k8 {
                let va = _mm256_loadu_ps(ap.add(p));
                let vb = _mm256_loadu_ps(bp.add(p));
                acc = _mm256_fmadd_ps(va, vb, acc);
                p += F32_LANES;
            }
            tail_mul_add(reduce8(acc), &a[k8..], &b[k8..])
        }
    }

    /// Register-tiled `A·Bᵀ`: interior cells in 4×2 tiles (8 independent
    /// FMA chains; each loaded A-vector feeds 2 FMAs, each B-vector 4),
    /// edges in 1×4 strips / single cells — every shape running the same
    /// per-cell op sequence as [`dot`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_t(a: &[f32], m: usize, b: &[f32], n: usize, k: usize, out: &mut [f32]) {
        const MR: usize = 4;
        const NR: usize = 2;
        let k8 = k - k % F32_LANES;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        unsafe {
            let mut i = 0usize;
            while i + MR <= m {
                let a0 = ap.add(i * k);
                let a1 = ap.add((i + 1) * k);
                let a2 = ap.add((i + 2) * k);
                let a3 = ap.add((i + 3) * k);
                let mut j = 0usize;
                while j + NR <= n {
                    let b0 = bp.add(j * k);
                    let b1 = bp.add((j + 1) * k);
                    let mut c00 = _mm256_setzero_ps();
                    let mut c01 = _mm256_setzero_ps();
                    let mut c10 = _mm256_setzero_ps();
                    let mut c11 = _mm256_setzero_ps();
                    let mut c20 = _mm256_setzero_ps();
                    let mut c21 = _mm256_setzero_ps();
                    let mut c30 = _mm256_setzero_ps();
                    let mut c31 = _mm256_setzero_ps();
                    let mut p = 0usize;
                    while p < k8 {
                        let vb0 = _mm256_loadu_ps(b0.add(p));
                        let vb1 = _mm256_loadu_ps(b1.add(p));
                        let va = _mm256_loadu_ps(a0.add(p));
                        c00 = _mm256_fmadd_ps(va, vb0, c00);
                        c01 = _mm256_fmadd_ps(va, vb1, c01);
                        let va = _mm256_loadu_ps(a1.add(p));
                        c10 = _mm256_fmadd_ps(va, vb0, c10);
                        c11 = _mm256_fmadd_ps(va, vb1, c11);
                        let va = _mm256_loadu_ps(a2.add(p));
                        c20 = _mm256_fmadd_ps(va, vb0, c20);
                        c21 = _mm256_fmadd_ps(va, vb1, c21);
                        let va = _mm256_loadu_ps(a3.add(p));
                        c30 = _mm256_fmadd_ps(va, vb0, c30);
                        c31 = _mm256_fmadd_ps(va, vb1, c31);
                        p += F32_LANES;
                    }
                    let tb0 = &b[j * k + k8..(j + 1) * k];
                    let tb1 = &b[(j + 1) * k + k8..(j + 2) * k];
                    let ta0 = &a[i * k + k8..(i + 1) * k];
                    let ta1 = &a[(i + 1) * k + k8..(i + 2) * k];
                    let ta2 = &a[(i + 2) * k + k8..(i + 3) * k];
                    let ta3 = &a[(i + 3) * k + k8..(i + 4) * k];
                    out[i * n + j] = tail_mul_add(reduce8(c00), ta0, tb0);
                    out[i * n + j + 1] = tail_mul_add(reduce8(c01), ta0, tb1);
                    out[(i + 1) * n + j] = tail_mul_add(reduce8(c10), ta1, tb0);
                    out[(i + 1) * n + j + 1] = tail_mul_add(reduce8(c11), ta1, tb1);
                    out[(i + 2) * n + j] = tail_mul_add(reduce8(c20), ta2, tb0);
                    out[(i + 2) * n + j + 1] = tail_mul_add(reduce8(c21), ta2, tb1);
                    out[(i + 3) * n + j] = tail_mul_add(reduce8(c30), ta3, tb0);
                    out[(i + 3) * n + j + 1] = tail_mul_add(reduce8(c31), ta3, tb1);
                    j += NR;
                }
                while j < n {
                    let brow = &b[j * k..(j + 1) * k];
                    for r in 0..MR {
                        out[(i + r) * n + j] = dot(&a[(i + r) * k..(i + r + 1) * k], brow);
                    }
                    j += 1;
                }
                i += MR;
            }
            // Remainder rows: 1×4 strips keep four independent chains per
            // loaded A-vector, then single cells.
            while i < m {
                let arow = &a[i * k..(i + 1) * k];
                let ai = ap.add(i * k);
                let mut j = 0usize;
                while j + 4 <= n {
                    let b0 = bp.add(j * k);
                    let b1 = bp.add((j + 1) * k);
                    let b2 = bp.add((j + 2) * k);
                    let b3 = bp.add((j + 3) * k);
                    let mut c0 = _mm256_setzero_ps();
                    let mut c1 = _mm256_setzero_ps();
                    let mut c2 = _mm256_setzero_ps();
                    let mut c3 = _mm256_setzero_ps();
                    let mut p = 0usize;
                    while p < k8 {
                        let va = _mm256_loadu_ps(ai.add(p));
                        c0 = _mm256_fmadd_ps(_mm256_loadu_ps(b0.add(p)), va, c0);
                        c1 = _mm256_fmadd_ps(_mm256_loadu_ps(b1.add(p)), va, c1);
                        c2 = _mm256_fmadd_ps(_mm256_loadu_ps(b2.add(p)), va, c2);
                        c3 = _mm256_fmadd_ps(_mm256_loadu_ps(b3.add(p)), va, c3);
                        p += F32_LANES;
                    }
                    let ta = &arow[k8..];
                    out[i * n + j] = tail_mul_add(reduce8(c0), ta, &b[j * k + k8..(j + 1) * k]);
                    out[i * n + j + 1] =
                        tail_mul_add(reduce8(c1), ta, &b[(j + 1) * k + k8..(j + 2) * k]);
                    out[i * n + j + 2] =
                        tail_mul_add(reduce8(c2), ta, &b[(j + 2) * k + k8..(j + 3) * k]);
                    out[i * n + j + 3] =
                        tail_mul_add(reduce8(c3), ta, &b[(j + 3) * k + k8..(j + 4) * k]);
                    j += 4;
                }
                while j < n {
                    out[i * n + j] = dot(arow, &b[j * k..(j + 1) * k]);
                    j += 1;
                }
                i += 1;
            }
        }
    }

    /// 64 int8 products accumulated into 8 i32 lanes (exact): the
    /// llama.cpp-style abs/sign trick makes `maddubs` (u8×i8 → i16 pairs)
    /// compute signed products — pair sums ≤ 2·127² < i16::MAX, so no
    /// saturation — then `madd` by 1 widens to i32.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn q8_block_sum(a: *const i8, b: *const i8) -> i32 {
        unsafe {
            let va0 = _mm256_loadu_si256(a as *const __m256i);
            let vb0 = _mm256_loadu_si256(b as *const __m256i);
            let va1 = _mm256_loadu_si256(a.add(32) as *const __m256i);
            let vb1 = _mm256_loadu_si256(b.add(32) as *const __m256i);
            let p0 = _mm256_maddubs_epi16(_mm256_abs_epi8(va0), _mm256_sign_epi8(vb0, va0));
            let p1 = _mm256_maddubs_epi16(_mm256_abs_epi8(va1), _mm256_sign_epi8(vb1, va1));
            let ones = _mm256_set1_epi16(1);
            let s = _mm256_add_epi32(_mm256_madd_epi16(p0, ones), _mm256_madd_epi16(p1, ones));
            let lo = _mm256_castsi256_si128(s);
            let hi = _mm256_extracti128_si256::<1>(s);
            let s4 = _mm_add_epi32(lo, hi);
            let s2 = _mm_add_epi32(s4, _mm_shuffle_epi32::<0b01_00_11_10>(s4));
            let s1 = _mm_add_epi32(s2, _mm_shuffle_epi32::<0b00_00_00_01>(s2));
            _mm_cvtsi128_si32(s1)
        }
    }

    /// AVX2 arm of the quantized scan. Train-row-major like the scalar
    /// arm; block sums are exact i32, so the output is bit-identical to
    /// the scalar arm and to the `dot_q8` reference.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn scan_q8(
        t_codes: &[i8],
        t_scales: &[f32],
        nt: usize,
        codes: &[i8],
        scales: &[f32],
        len: usize,
        k: usize,
        out: &mut [f32],
    ) {
        let blocks = k.div_ceil(Q8_BLOCK);
        let full = k / Q8_BLOCK;
        unsafe {
            for j in 0..len {
                let jc = codes.as_ptr().add(j * k);
                let js = &scales[j * blocks..(j + 1) * blocks];
                for t in 0..nt {
                    let tc = t_codes.as_ptr().add(t * k);
                    let ts = &t_scales[t * blocks..(t + 1) * blocks];
                    let mut acc = 0.0f32;
                    for b in 0..full {
                        let s = q8_block_sum(tc.add(b * Q8_BLOCK), jc.add(b * Q8_BLOCK));
                        acc += (ts[b] * js[b]) * s as f32;
                    }
                    if full < blocks {
                        let lo = full * Q8_BLOCK;
                        let mut s = 0i32;
                        for idx in lo..k {
                            s += (*tc.add(idx) as i16 * *jc.add(idx) as i16) as i32;
                        }
                        acc += (ts[full] * js[full]) * s as f32;
                    }
                    out[t * len + j] = acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::matmul_t_slices;
    use crate::util::rng::Pcg32;

    fn rand_rows(rng: &mut Pcg32, n: usize, k: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n * k];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn arm_resolves_and_is_stable() {
        let arm = kernel_arm();
        assert_eq!(arm, kernel_arm(), "dispatch must be cached");
        assert!(!arm.name().is_empty());
    }

    #[test]
    fn dispatched_matmul_matches_naive_reference() {
        let mut rng = Pcg32::seeded(11);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 33, 192), (5, 2, 65)] {
            let a = rand_rows(&mut rng, m, k);
            let b = rand_rows(&mut rng, n, k);
            let want = matmul_t_slices(&a, m, &b, n, k);
            let mut got = vec![0.0f32; m * n];
            matmul_t_into(&a, m, &b, n, k, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "({m},{n},{k}) cell {i}: {g} vs naive {w}"
                );
            }
        }
    }

    #[test]
    fn every_cell_equals_standalone_dot_bitwise() {
        // THE determinism contract: a cell's value must not depend on
        // where in the tile grid it was computed.
        let mut rng = Pcg32::seeded(12);
        for &(m, n, k) in &[(4usize, 2usize, 16usize), (9, 7, 21), (1, 11, 8), (6, 3, 200)] {
            let a = rand_rows(&mut rng, m, k);
            let b = rand_rows(&mut rng, n, k);
            let mut got = vec![0.0f32; m * n];
            matmul_t_into(&a, m, &b, n, k, &mut got);
            for i in 0..m {
                for j in 0..n {
                    let d = dot_f32(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                    assert_eq!(
                        got[i * n + j].to_bits(),
                        d.to_bits(),
                        "cell ({i},{j}) of ({m},{n},{k}) diverged from dot_f32"
                    );
                }
            }
        }
    }

    #[test]
    fn scalar_arm_cells_equal_scalar_dot_bitwise() {
        let mut rng = Pcg32::seeded(13);
        let (m, n, k) = (5usize, 9usize, 27usize);
        let a = rand_rows(&mut rng, m, k);
        let b = rand_rows(&mut rng, n, k);
        let mut got = vec![0.0f32; m * n];
        matmul_t_scalar_into(&a, m, &b, n, k, &mut got);
        for i in 0..m {
            for j in 0..n {
                let d = dot_f32_scalar(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                assert_eq!(got[i * n + j].to_bits(), d.to_bits(), "cell ({i},{j})");
            }
        }
    }

    #[test]
    fn scratch_grows_once_then_reuses() {
        let mut s = ScanScratch::new();
        let _ = s.score_buf(1024);
        assert_eq!(s.grows(), 1);
        for _ in 0..100 {
            let buf = s.score_buf(1024);
            assert_eq!(buf.len(), 1024);
            let small = s.score_buf(10);
            assert_eq!(small.len(), 10);
        }
        assert_eq!(s.grows(), 1, "steady-state leases must not allocate");
        let _ = s.aux_buf(64);
        assert_eq!(s.grows(), 2);
        let _ = s.score_buf(2048);
        assert_eq!(s.grows(), 3, "a larger lease is a growth event");
    }

    #[test]
    fn auto_chunk_len_is_bounded_and_l2_sized() {
        // Paper-shaped: k=192, nt=8, f32 rows.
        let c = auto_chunk_len(192, 8, 192 * 4);
        assert!(c % 64 == 0 && (64..=8192).contains(&c), "chunk {c}");
        assert!(c * (192 * 4 + 32) + 8 * 192 * 4 <= L2_TARGET_BYTES, "chunk {c} busts L2");
        // Quantized rows are ~4x smaller -> ~4x longer chunks.
        let cq = auto_chunk_len(192, 8, 192 + 3 * 4);
        assert!(cq > c, "q8 chunk {cq} should exceed f32 chunk {c}");
        // Degenerate shapes stay clamped.
        assert_eq!(auto_chunk_len(1_000_000, 8, 4_000_000), 64);
        assert_eq!(auto_chunk_len(1, 1, 4), 8192);
    }

    #[test]
    fn rowwise_dot_matches_per_row_dot() {
        let mut rng = Pcg32::seeded(14);
        let (n, k) = (17usize, 37usize);
        let a = rand_rows(&mut rng, n, k);
        let b = rand_rows(&mut rng, n, k);
        let mut out = Vec::new();
        rowwise_dot_extend(&a, &b, n, k, &mut out);
        assert_eq!(out.len(), n);
        for r in 0..n {
            let d = dot_f32(&a[r * k..(r + 1) * k], &b[r * k..(r + 1) * k]);
            assert_eq!(out[r].to_bits(), d.to_bits(), "row {r}");
        }
    }
}
