//! Dense row-major f32 matrix with the handful of BLAS-level ops the
//! coordinator needs host-side (Hessian blocks, projections, baselines).
//!
//! Heavy lifting (per-sample projection, scoring) happens inside the AOT
//! HLO programs; this type covers the small K×K / n×n work around them
//! (accumulation, eigendecomposition inputs, PCA initialization).

use crate::util::rng::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    pub fn random_normal(rng: &mut Pcg32, rows: usize, cols: usize, sigma: f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.at(r, c);
            }
        }
        t
    }

    /// C = self * other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, vectorizes the inner axis.
        for i in 0..self.rows {
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// C = self * other^T (the scoring shape: [m,k] x [n,k] -> [m,n]).
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut out = Matrix::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.rows {
                let brow = other.row(j);
                let mut acc = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                out.data[i * other.rows + j] = acc;
            }
        }
        out
    }

    /// y = self * x  for a vector x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0f32; self.rows];
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[r] = acc;
        }
        y
    }

    /// self += alpha * x x^T (rank-1 symmetric update).
    pub fn syr(&mut self, alpha: f32, x: &[f32]) {
        assert_eq!(self.rows, self.cols);
        assert_eq!(x.len(), self.rows);
        for r in 0..self.rows {
            let xr = alpha * x[r];
            if xr == 0.0 {
                continue;
            }
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (o, &xc) in row.iter_mut().zip(x) {
                *o += xr * xc;
            }
        }
    }

    /// self += alpha * other (same shape).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Max |a - b| across entries.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Rows orthonormalized in place via modified Gram–Schmidt
    /// (random-projection init for LoGra-random / TRAK).
    pub fn orthonormalize_rows(&mut self) {
        for i in 0..self.rows {
            for j in 0..i {
                let dot: f32 = {
                    let (a, b) = (self.row_slice(i), self.row_slice(j));
                    a.iter().zip(b).map(|(x, y)| x * y).sum()
                };
                let cols = self.cols;
                for c in 0..cols {
                    let v = self.data[j * cols + c];
                    self.data[i * cols + c] -= dot * v;
                }
            }
            let norm: f32 =
                self.row(i).iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-20);
            for c in 0..self.cols {
                self.data[i * self.cols + c] /= norm;
            }
        }
    }

    fn row_slice(&self, r: usize) -> Vec<f32> {
        self.row(r).to_vec()
    }
}

/// `out = A B^T` over raw row-major slices — the NAIVE single-accumulator
/// reference kernel. The serving hot path runs
/// [`crate::linalg::kernels::matmul_t_into`] instead (register-tiled,
/// SIMD-dispatched, allocation-free); this version is kept as the
/// plain-ordering oracle for kernel property tests and the bench's
/// before/after comparison. A is [m, k], B is [n, k].
pub fn matmul_t_slices(a: &[f32], m: usize, b: &[f32], n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (x, y) in arow.iter().zip(brow) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    out
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity (0 when either vector is ~zero).
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-20 || nb < 1e-20 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_t_matches_matmul() {
        let mut rng = Pcg32::seeded(1);
        let a = Matrix::random_normal(&mut rng, 5, 7, 1.0);
        let b = Matrix::random_normal(&mut rng, 4, 7, 1.0);
        let got = a.matmul_t(&b);
        let want = a.matmul(&b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg32::seeded(2);
        let a = Matrix::random_normal(&mut rng, 4, 4, 1.0);
        assert!(a.matmul(&Matrix::identity(4)).max_abs_diff(&a) < 1e-7);
        assert!(Matrix::identity(4).matmul(&a).max_abs_diff(&a) < 1e-7);
    }

    #[test]
    fn syr_accumulates_outer_product() {
        let mut m = Matrix::zeros(3, 3);
        m.syr(2.0, &[1.0, 0.0, -1.0]);
        assert_eq!(m.at(0, 0), 2.0);
        assert_eq!(m.at(0, 2), -2.0);
        assert_eq!(m.at(2, 2), 2.0);
        assert_eq!(m.at(1, 1), 0.0);
    }

    #[test]
    fn orthonormalize_rows_gives_orthonormal() {
        let mut rng = Pcg32::seeded(3);
        let mut m = Matrix::random_normal(&mut rng, 4, 16, 1.0);
        m.orthonormalize_rows();
        for i in 0..4 {
            for j in 0..4 {
                let d = dot(m.row(i), m.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "({i},{j}) -> {d}");
            }
        }
    }

    #[test]
    fn cosine_bounds() {
        let a = [1.0f32, 2.0, 3.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
        let b = [-1.0f32, -2.0, -3.0];
        assert!((cosine(&a, &b) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&a, &[0.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg32::seeded(4);
        let a = Matrix::random_normal(&mut rng, 6, 3, 1.0);
        let x = vec![1.0f32, -2.0, 0.5];
        let y = a.matvec(&x);
        let xm = Matrix::from_vec(3, 1, x);
        let want = a.matmul(&xm);
        for i in 0..6 {
            assert!((y[i] - want.data[i]).abs() < 1e-6);
        }
    }
}
