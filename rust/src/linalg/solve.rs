//! Cholesky factorization and SPD solves.
//!
//! Used for damped iHVP solves where the eigen-route is unnecessary, and
//! as an independent cross-check of the eigh-based inverse in tests.

use crate::linalg::matrix::Matrix;

/// Lower-triangular Cholesky factor of an SPD matrix. Returns None if the
/// matrix is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + j] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(Matrix::from_vec(n, n, l.iter().map(|&x| x as f32).collect()))
}

/// Solve `a x = b` for SPD `a` via Cholesky. None if not SPD.
pub fn solve_spd(a: &Matrix, b: &[f32]) -> Option<Vec<f32>> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Forward substitution: L y = b.
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= l.at(i, k) as f64 * y[k];
        }
        y[i] = sum / l.at(i, i) as f64;
    }
    // Back substitution: L^T x = y.
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l.at(k, i) as f64 * x[k];
        }
        x[i] = sum / l.at(i, i) as f64;
    }
    Some(x.iter().map(|&v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::dot;
    use crate::util::rng::Pcg32;

    fn random_spd(rng: &mut Pcg32, n: usize) -> Matrix {
        let b = Matrix::random_normal(rng, n + 3, n, 1.0);
        let mut g = b.transpose().matmul(&b);
        for i in 0..n {
            *g.at_mut(i, i) += 0.1; // damping
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg32::seeded(1);
        for n in [1, 2, 5, 16, 40] {
            let a = random_spd(&mut rng, n);
            let l = cholesky(&a).expect("SPD");
            let rec = l.matmul(&l.transpose());
            assert!(a.max_abs_diff(&rec) < 1e-3 * a.fro_norm().max(1.0), "n={n}");
        }
    }

    #[test]
    fn solve_spd_residual_small() {
        let mut rng = Pcg32::seeded(2);
        let n = 24;
        let a = random_spd(&mut rng, n);
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let x = solve_spd(&a, &b).expect("SPD");
        let ax = a.matvec(&x);
        let resid: f32 = ax.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum::<f32>().sqrt();
        let bnorm = dot(&b, &b).sqrt();
        assert!(resid < 1e-3 * bnorm.max(1.0), "resid={resid}");
    }

    #[test]
    fn non_spd_rejected() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_matches_eigh_inverse() {
        use crate::linalg::eigh::eigh;
        let mut rng = Pcg32::seeded(3);
        let n = 12;
        let a = random_spd(&mut rng, n);
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let x_chol = solve_spd(&a, &b).unwrap();
        // Eigen route: x = Q diag(1/l) Q^T b.
        let e = eigh(&a);
        let qtb = e.q.transpose().matvec(&b);
        let scaled: Vec<f32> = qtb.iter().zip(&e.eigenvalues).map(|(v, l)| v / l).collect();
        let x_eig = e.q.matvec(&scaled);
        for (p, q) in x_chol.iter().zip(&x_eig) {
            assert!((p - q).abs() < 2e-3, "{p} vs {q}");
        }
    }
}
