//! Raw projected Fisher/Hessian, per-module blocks, and the damped iHVP
//! preconditioner.
//!
//! LoGra computes the *exact* Fisher restricted to the projected subspace
//! (the accuracy edge over EKFAC the paper cites in §4.1): for each
//! instrumented module l, `H_l = E[g_l g_l^T]` over stored projected
//! gradient blocks. The preconditioner applies
//! `(H_l + λ_l I)^{-1}` per block via eigendecomposition, with the paper's
//! damping rule `λ_l = 0.1 · mean(eigenvalues)` (Appendix C) — Lemma 1's
//! spectral sparsification made executable.

use anyhow::{anyhow, Result};

use crate::linalg::{eigh, Matrix};
use crate::runtime::Manifest;

/// Per-module accumulated second-moment blocks.
pub struct BlockHessian {
    /// (offset, block matrix) per module, offsets into a gradient row.
    pub blocks: Vec<(usize, Matrix)>,
    pub k_total: usize,
    pub count: u64,
}

impl BlockHessian {
    /// Blocks sized from the manifest's module table (projected layout).
    pub fn new(man: &Manifest) -> Self {
        let blocks = man
            .modules
            .iter()
            .map(|m| (m.g_off, Matrix::zeros(m.g_len, m.g_len)))
            .collect();
        BlockHessian { blocks, k_total: man.k_total, count: 0 }
    }

    /// A single-block Hessian over a k-dim space (TRAK baseline).
    pub fn single_block(k: usize) -> Self {
        BlockHessian { blocks: vec![(0, Matrix::zeros(k, k))], k_total: k, count: 0 }
    }

    /// Accumulate `real` rows of a row-major [rows, k_total] gradient
    /// buffer (pad rows beyond `real` are ignored).
    pub fn accumulate(&mut self, rows: &[f32], real: usize) {
        let k = self.k_total;
        assert!(rows.len() >= real * k, "gradient buffer too small");
        for r in 0..real {
            let row = &rows[r * k..(r + 1) * k];
            for (off, block) in self.blocks.iter_mut() {
                let seg = &row[*off..*off + block.rows];
                block.syr(1.0, seg);
            }
        }
        self.count += real as u64;
    }

    /// Mean (Fisher) blocks.
    pub fn mean_blocks(&self) -> Vec<(usize, Matrix)> {
        let scale = 1.0 / self.count.max(1) as f32;
        self.blocks
            .iter()
            .map(|(off, b)| {
                let mut m = b.clone();
                m.scale(scale);
                (*off, m)
            })
            .collect()
    }

    /// Build the damped iHVP preconditioner. `damping_factor` follows the
    /// paper (0.1 × mean eigenvalue per block).
    pub fn preconditioner(&self, damping_factor: f32) -> Result<Preconditioner> {
        if self.count == 0 {
            return Err(anyhow!("preconditioner before any accumulation"));
        }
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (off, mean) in self.mean_blocks() {
            let e = eigh(&mean);
            let mean_eig: f32 =
                e.eigenvalues.iter().sum::<f32>() / e.eigenvalues.len() as f32;
            let damp = (damping_factor * mean_eig).max(1e-10);
            blocks.push(PrecondBlock {
                off,
                q: e.q,
                eigenvalues: e.eigenvalues,
                damp,
            });
        }
        Ok(Preconditioner { blocks, k_total: self.k_total })
    }
}

/// One eigendecomposed damped block.
pub struct PrecondBlock {
    pub off: usize,
    /// Column-eigenvector matrix [k, k].
    pub q: Matrix,
    pub eigenvalues: Vec<f32>,
    pub damp: f32,
}

/// Applies `(H + λI)^{-1}` blockwise to gradient rows.
pub struct Preconditioner {
    pub blocks: Vec<PrecondBlock>,
    pub k_total: usize,
}

impl Preconditioner {
    /// Largest block width (the per-apply rotation scratch size).
    fn max_block(&self) -> usize {
        self.blocks.iter().map(|b| b.q.rows).max().unwrap_or(0)
    }

    /// out = (H + λI)^{-1} g (new vector).
    pub fn apply(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; g.len()];
        let mut v = vec![0.0f32; self.max_block()];
        self.apply_into(g, &mut out, &mut v);
        out
    }

    /// `out = (H + λI)^{-1} g` into caller-owned storage; `v` is rotation
    /// scratch of at least [`max_block`](Self::max_block) elements. Same
    /// math and op order as [`apply`](Self::apply) — the allocation-free
    /// body both entry points share.
    fn apply_into(&self, g: &[f32], out: &mut [f32], v: &mut [f32]) {
        assert_eq!(g.len(), self.k_total);
        assert_eq!(out.len(), self.k_total);
        // Blocks assign (not accumulate) their segments; zero first so any
        // unclaimed gap reads 0 like the allocating path.
        out.fill(0.0);
        for b in &self.blocks {
            let k = b.q.rows;
            let seg = &g[b.off..b.off + k];
            // v = Q^T seg ; v_i /= (λ_i + damp) ; out_seg = Q v
            let vb = &mut v[..k];
            for i in 0..k {
                let mut acc = 0.0f32;
                for r in 0..k {
                    acc += b.q.at(r, i) * seg[r];
                }
                vb[i] = acc / (b.eigenvalues[i].max(0.0) + b.damp);
            }
            let oseg = &mut out[b.off..b.off + k];
            for r in 0..k {
                let mut acc = 0.0f32;
                for i in 0..k {
                    acc += b.q.at(r, i) * vb[i];
                }
                oseg[r] = acc;
            }
        }
    }

    /// Batch apply over row-major [n, k_total].
    pub fn apply_rows(&self, rows: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * self.k_total];
        self.apply_rows_into(rows, n, &mut out);
        out
    }

    /// Batch apply into caller-owned storage (`out.len() == n * k_total`);
    /// one rotation-scratch allocation per call, none per row.
    pub fn apply_rows_into(&self, rows: &[f32], n: usize, out: &mut [f32]) {
        let k = self.k_total;
        assert_eq!(rows.len(), n * k);
        assert_eq!(out.len(), n * k);
        let mut v = vec![0.0f32; self.max_block()];
        for r in 0..n {
            self.apply_into(&rows[r * k..(r + 1) * k], &mut out[r * k..(r + 1) * k], &mut v);
        }
    }

    /// Self-influence g^T (H+λI)^{-1} g (RelatIF denominator). Routed
    /// through the shared kernel dot so single-row and batched
    /// self-influences are bitwise interchangeable.
    pub fn self_influence(&self, g: &[f32]) -> f32 {
        crate::linalg::kernels::dot_f32(&self.apply(g), g)
    }

    /// Batched self-influences of `n` row-major rows, appended to `out`.
    /// `applied` is caller scratch of at least `n * k_total` elements
    /// (lease it from a [`crate::linalg::ScanScratch`]); each row's value
    /// is bitwise identical to [`self_influence`](Self::self_influence) —
    /// the invariant that keeps RelatIF denominators engine-independent.
    pub fn self_influences_into(
        &self,
        rows: &[f32],
        n: usize,
        applied: &mut [f32],
        out: &mut Vec<f32>,
    ) {
        let k = self.k_total;
        let applied = &mut applied[..n * k];
        self.apply_rows_into(rows, n, applied);
        crate::linalg::kernels::rowwise_dot_extend(applied, rows, n, k, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::util::rng::Pcg32;

    fn toy_hessian(k_blocks: &[usize], rows: usize, seed: u64) -> (BlockHessian, Vec<f32>) {
        let k_total: usize = k_blocks.iter().sum();
        let mut offs = Vec::new();
        let mut off = 0;
        for &k in k_blocks {
            offs.push((off, Matrix::zeros(k, k)));
            off += k;
        }
        let mut h = BlockHessian { blocks: offs, k_total, count: 0 };
        let mut rng = Pcg32::seeded(seed);
        let mut data = vec![0.0f32; rows * k_total];
        rng.fill_normal(&mut data, 1.0);
        h.accumulate(&data, rows);
        (h, data)
    }

    #[test]
    fn accumulate_matches_direct_outer_products() {
        let (h, data) = toy_hessian(&[3, 2], 10, 1);
        let mean = h.mean_blocks();
        // Direct: block 0 = mean over rows of g[0..3] outer.
        let mut want = Matrix::zeros(3, 3);
        for r in 0..10 {
            want.syr(0.1, &data[r * 5..r * 5 + 3]);
        }
        assert!(mean[0].1.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn precondition_inverts_damped_hessian() {
        let (h, _) = toy_hessian(&[4, 3], 200, 2);
        let p = h.preconditioner(0.1).unwrap();
        let mut rng = Pcg32::seeded(3);
        let mut g = vec![0.0f32; 7];
        rng.fill_normal(&mut g, 1.0);
        let x = p.apply(&g);
        // Verify (H + λI) x == g blockwise.
        for (bi, (off, mean)) in h.mean_blocks().into_iter().enumerate() {
            let k = mean.rows;
            let damp = p.blocks[bi].damp;
            let xseg = &x[off..off + k];
            let mut hx = mean.matvec(xseg);
            for (i, hx_i) in hx.iter_mut().enumerate() {
                *hx_i += damp * xseg[i];
            }
            for (a, b) in hx.iter().zip(&g[off..off + k]) {
                assert!((a - b).abs() < 2e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn self_influence_positive() {
        let (h, data) = toy_hessian(&[5], 50, 4);
        let p = h.preconditioner(0.1).unwrap();
        for r in 0..10 {
            let si = p.self_influence(&data[r * 5..(r + 1) * 5]);
            assert!(si > 0.0);
        }
    }

    #[test]
    fn batched_paths_match_single_row_bitwise() {
        // apply_rows_into / self_influences_into must be bitwise
        // interchangeable with the per-row entry points — multi-block
        // preconditioner, scratch pre-filled with garbage to catch any
        // missing zeroing.
        let (h, data) = toy_hessian(&[4, 3], 120, 7);
        let p = h.preconditioner(0.1).unwrap();
        let n = 9;
        let rows = &data[..n * 7];
        let mut applied = vec![f32::NAN; n * 7];
        p.apply_rows_into(rows, n, &mut applied);
        let mut selfs = Vec::new();
        let mut scratch = vec![f32::NAN; n * 7];
        p.self_influences_into(rows, n, &mut scratch, &mut selfs);
        assert_eq!(selfs.len(), n);
        for r in 0..n {
            let row = &rows[r * 7..(r + 1) * 7];
            let single = p.apply(row);
            for (c, (a, b)) in applied[r * 7..(r + 1) * 7].iter().zip(&single).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "row {r} col {c}");
            }
            let want = p.self_influence(row);
            assert_eq!(selfs[r].to_bits(), want.to_bits(), "self-influence row {r}");
        }
    }

    #[test]
    fn lemma1_spectral_identity() {
        // Paper Lemma 1: g_te^T (H+λI)^{-1} g_tr
        //   == Σ_i <e_i,g_te> <e_i,g_tr> / (λ_i + λ).
        let (h, data) = toy_hessian(&[6], 100, 5);
        let p = h.preconditioner(0.1).unwrap();
        let gte = &data[0..6];
        let gtr = &data[6..12];
        let lhs = dot(&p.apply(gte), gtr);
        let b = &p.blocks[0];
        let mut rhs = 0.0f32;
        for i in 0..6 {
            let mut cte = 0.0f32;
            let mut ctr = 0.0f32;
            for r in 0..6 {
                cte += b.q.at(r, i) * gte[r];
                ctr += b.q.at(r, i) * gtr[r];
            }
            rhs += cte * ctr / (b.eigenvalues[i] + b.damp);
        }
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn lemma1_coefficient_normalization() {
        // E[c_i^2] ≈ 1 when c_i = <e_i, g> / sqrt(λ_i) over the fitting
        // distribution itself (Assumption 1's self-consistency).
        let (h, data) = toy_hessian(&[8], 4000, 6);
        let p = h.preconditioner(0.1).unwrap();
        let b = &p.blocks[0];
        let mut csq = vec![0.0f64; 8];
        let rows = 4000;
        for r in 0..rows {
            let g = &data[r * 8..(r + 1) * 8];
            for i in 0..8 {
                let mut proj = 0.0f32;
                for j in 0..8 {
                    proj += b.q.at(j, i) * g[j];
                }
                let lam = b.eigenvalues[i].max(1e-12);
                let c = proj / lam.sqrt();
                csq[i] += (c * c) as f64;
            }
        }
        for (i, s) in csq.iter().enumerate() {
            let mean = s / rows as f64;
            assert!((mean - 1.0).abs() < 0.15, "component {i}: E[c^2]={mean}");
        }
    }

    #[test]
    fn empty_hessian_rejected() {
        let h = BlockHessian::single_block(4);
        assert!(h.preconditioner(0.1).is_err());
    }
}
