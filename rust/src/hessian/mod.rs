//! Hessian service: raw projected Fisher blocks + damped iHVP (LoGra),
//! KFAC factor fitting + PCA initialization (§3.2), EKFAC baseline state.

pub mod block;
pub mod kfac;

pub use block::{BlockHessian, PrecondBlock, Preconditioner};
pub use kfac::{pack_projections, pca_projections, random_projections, Ekfac, KfacFactors};
