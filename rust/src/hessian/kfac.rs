//! KFAC factor fitting, LoGra-PCA initialization, and the EKFAC state.
//!
//! KFAC (§3.2): per module, `H ≈ C_F ⊗ C_B` with `C_F = E[x x^T]`,
//! `C_B = E[dx dx^T]`. From the eigendecompositions `C_F = Q_F Λ_F Q_F^T`,
//! `C_B = Q_B Λ_B Q_B^T`:
//!   * LoGra-PCA init: `P_i = top-k_in rows of Q_F^T`, `P_o = top-k_out
//!     rows of Q_B^T` — projecting onto the largest KFAC eigen-directions
//!     (the spectral-sparsification argument of Lemma 1).
//!   * EKFAC baseline: rotate gradients into the FULL eigenbasis and
//!     replace `Λ_F ⊗ Λ_B` with corrected per-entry eigenvalues
//!     `Λ*_oi = E[(Q_B^T DW Q_F)_oi²]` fitted from data (Grosse et al.).

use anyhow::{anyhow, Result};

use crate::linalg::{eigh, Matrix};
use crate::runtime::Manifest;
use crate::util::rng::Pcg32;

/// Accumulated per-module activation covariances.
pub struct KfacFactors {
    /// (C_F [n_in,n_in], C_B [n_out,n_out]) per module.
    pub factors: Vec<(Matrix, Matrix)>,
    pub rows: u64,
}

impl KfacFactors {
    pub fn new(man: &Manifest) -> Self {
        let factors = man
            .modules
            .iter()
            .map(|m| (Matrix::zeros(m.n_in, m.n_in), Matrix::zeros(m.n_out, m.n_out)))
            .collect();
        KfacFactors { factors, rows: 0 }
    }

    /// Add one `cov_stats` artifact output (flat, per-module C_F then C_B,
    /// summed over the batch's rows). Only feed FULL batches: the artifact
    /// cannot distinguish pad rows. `batch_rows` is the row count the
    /// artifact summed over.
    pub fn accumulate(&mut self, man: &Manifest, cov_flat: &[f32], batch_rows: u64) -> Result<()> {
        if cov_flat.len() != man.cov_len {
            return Err(anyhow!(
                "cov vector len {} != manifest cov_len {}",
                cov_flat.len(),
                man.cov_len
            ));
        }
        for (mi, m) in man.modules.iter().enumerate() {
            let f_len = m.n_in * m.n_in;
            let b_len = m.n_out * m.n_out;
            let off = m.cov_off;
            let (cf, cb) = &mut self.factors[mi];
            for (dst, src) in cf.data.iter_mut().zip(&cov_flat[off..off + f_len]) {
                *dst += src;
            }
            for (dst, src) in
                cb.data.iter_mut().zip(&cov_flat[off + f_len..off + f_len + b_len])
            {
                *dst += src;
            }
        }
        self.rows += batch_rows;
        Ok(())
    }

    /// Eigendecompose the mean factors: per module (eig_F, eig_B).
    pub fn eigenbases(&self) -> Vec<(crate::linalg::Eigh, crate::linalg::Eigh)> {
        let scale = 1.0 / self.rows.max(1) as f32;
        self.factors
            .iter()
            .map(|(cf, cb)| {
                let mut f = cf.clone();
                f.scale(scale);
                let mut b = cb.clone();
                b.scale(scale);
                (eigh(&f), eigh(&b))
            })
            .collect()
    }
}

/// Pack per-module (P_i, P_o) into the flat projection vector layout the
/// `logra_log` artifact expects (manifest `p_off` order).
pub fn pack_projections(man: &Manifest, projs: &[(Matrix, Matrix)]) -> Vec<f32> {
    let mut flat = vec![0.0f32; man.proj_len];
    for (m, (pi, po)) in man.modules.iter().zip(projs) {
        assert_eq!(pi.cols, m.n_in);
        assert_eq!(po.cols, m.n_out);
        let off = m.p_off;
        flat[off..off + pi.data.len()].copy_from_slice(&pi.data);
        flat[off + pi.data.len()..off + pi.data.len() + po.data.len()]
            .copy_from_slice(&po.data);
    }
    flat
}

/// LoGra-random initialization: orthonormalized Gaussian rows per module.
pub fn random_projections(man: &Manifest, rng: &mut Pcg32) -> Vec<f32> {
    let projs: Vec<(Matrix, Matrix)> = man
        .modules
        .iter()
        .map(|m| {
            let mut pi = Matrix::random_normal(rng, man.k_in, m.n_in, 1.0);
            pi.orthonormalize_rows();
            let mut po = Matrix::random_normal(rng, man.k_out, m.n_out, 1.0);
            po.orthonormalize_rows();
            (pi, po)
        })
        .collect();
    pack_projections(man, &projs)
}

/// LoGra-PCA initialization from fitted KFAC factors (§3.2).
pub fn pca_projections(man: &Manifest, kfac: &KfacFactors) -> Vec<f32> {
    let bases = kfac.eigenbases();
    let projs: Vec<(Matrix, Matrix)> = bases
        .iter()
        .map(|(ef, eb)| (ef.top_k_rows(man.k_in), eb.top_k_rows(man.k_out)))
        .collect();
    pack_projections(man, &projs)
}

// ------------------------------------------------------------------ EKFAC

/// EKFAC baseline state: full-rank eigenbasis rotations + corrected
/// eigenvalues + per-module damping.
pub struct Ekfac {
    /// Flat full-rank projection vector (`pfull` layout) holding Q_F^T /
    /// Q_B^T rows per module — fed to the `ekfac_log` artifact.
    pub rotations_flat: Vec<f32>,
    /// Corrected eigenvalues, one per entry of a full-rank gradient row.
    pub lambda: Vec<f32>,
    /// Per-module damping, `0.1 · mean(λ*_module)`.
    pub damp: Vec<f32>,
    fitted_rows: u64,
}

impl Ekfac {
    /// Build rotations from fitted KFAC factors. `lambda` starts at the
    /// KFAC Kronecker eigenvalues and is replaced by `fit_corrected`.
    pub fn from_kfac(man: &Manifest, kfac: &KfacFactors) -> Self {
        let bases = kfac.eigenbases();
        let mut flat = vec![0.0f32; man.proj_len_full];
        let mut lambda = vec![0.0f32; man.k_full];
        for (m, (ef, eb)) in man.modules.iter().zip(&bases) {
            // Full-rank "projections": all eigenvectors as rows.
            let pi = ef.top_k_rows(m.n_in);
            let po = eb.top_k_rows(m.n_out);
            let off = m.pfull_off;
            flat[off..off + pi.data.len()].copy_from_slice(&pi.data);
            flat[off + pi.data.len()..off + pi.data.len() + po.data.len()]
                .copy_from_slice(&po.data);
            // KFAC eigenvalues: λ_B[o] * λ_F[i], row-major (o, i) to match
            // the gradient-block layout vec(P_o DW P_i^T).
            // top_k_rows returns descending eigenvalues.
            let lam_f: Vec<f32> =
                (0..m.n_in).map(|i| ef.eigenvalues[m.n_in - 1 - i].max(0.0)).collect();
            let lam_b: Vec<f32> =
                (0..m.n_out).map(|o| eb.eigenvalues[m.n_out - 1 - o].max(0.0)).collect();
            for o in 0..m.n_out {
                for i in 0..m.n_in {
                    lambda[m.gfull_off + o * m.n_in + i] = lam_b[o] * lam_f[i];
                }
            }
        }
        let mut ek = Ekfac { rotations_flat: flat, lambda, damp: vec![0.0; man.modules.len()], fitted_rows: 0 };
        ek.refresh_damping(man);
        ek
    }

    /// Accumulate corrected eigenvalues from rotated per-sample gradients
    /// (`ekfac_log` output rows). Call `finish_corrected` afterwards.
    pub fn accumulate_corrected(&mut self, rows: &[f32], real: usize, k_full: usize) {
        if self.fitted_rows == 0 {
            self.lambda.iter_mut().for_each(|l| *l = 0.0);
        }
        for r in 0..real {
            let row = &rows[r * k_full..(r + 1) * k_full];
            for (l, &g) in self.lambda.iter_mut().zip(row) {
                *l += g * g;
            }
        }
        self.fitted_rows += real as u64;
    }

    pub fn finish_corrected(&mut self, man: &Manifest) {
        if self.fitted_rows > 0 {
            let inv = 1.0 / self.fitted_rows as f32;
            for l in self.lambda.iter_mut() {
                *l *= inv;
            }
        }
        self.refresh_damping(man);
    }

    fn refresh_damping(&mut self, man: &Manifest) {
        for (mi, m) in man.modules.iter().enumerate() {
            let seg = &self.lambda[m.gfull_off..m.gfull_off + m.gfull_len];
            let mean: f32 = seg.iter().sum::<f32>() / seg.len() as f32;
            self.damp[mi] = (0.1 * mean).max(1e-12);
        }
    }

    /// iHVP in the eigenbasis: out_j = g_j / (λ*_j + damp(module of j)).
    pub fn precondition(&self, man: &Manifest, g_rot: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; g_rot.len()];
        for (mi, m) in man.modules.iter().enumerate() {
            let d = self.damp[mi];
            for j in m.gfull_off..m.gfull_off + m.gfull_len {
                out[j] = g_rot[j] / (self.lambda[j] + d);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, ModuleInfo, ParamInfo};

    /// Hand-built 2-module manifest for unit tests.
    pub fn toy_manifest() -> Manifest {
        let modules = vec![
            ModuleInfo {
                name: "fc0".into(),
                n_in: 3,
                n_out: 4,
                g_off: 0,
                g_len: 4,
                gfull_off: 0,
                gfull_len: 12,
                p_off: 0,
                pfull_off: 0,
                cov_off: 0,
            },
            ModuleInfo {
                name: "fc1".into(),
                n_in: 4,
                n_out: 2,
                g_off: 4,
                g_len: 4,
                gfull_off: 12,
                gfull_len: 8,
                p_off: 2 * 3 + 2 * 4,
                pfull_off: 9 + 16,
                cov_off: 9 + 16,
            },
        ];
        Manifest {
            name: "toy".into(),
            kind: "mlp".into(),
            n_params: 20,
            k_in: 2,
            k_out: 2,
            k_total: 8,
            k_full: 20,
            proj_len: (2 * 3 + 2 * 4) + (2 * 4 + 2 * 2),
            proj_len_full: (9 + 16) + (16 + 4),
            cov_len: (9 + 16) + (16 + 4),
            train_batch: 4,
            log_batch: 4,
            test_batch: 2,
            train_chunk: 8,
            vocab: 0,
            seq_len: 0,
            input_dim: 3,
            classes: 2,
            repr_dim: 4,
            modules,
            params: vec![
                ParamInfo { name: "fc0.w".into(), off: 0, shape: vec![4, 3] },
                ParamInfo { name: "fc1.w".into(), off: 12, shape: vec![2, 4] },
            ],
            entries: vec![],
        }
    }

    #[test]
    fn pack_projections_layout() {
        let man = toy_manifest();
        let pi0 = Matrix::from_vec(2, 3, (0..6).map(|x| x as f32).collect());
        let po0 = Matrix::from_vec(2, 4, (10..18).map(|x| x as f32).collect());
        let pi1 = Matrix::from_vec(2, 4, (20..28).map(|x| x as f32).collect());
        let po1 = Matrix::from_vec(2, 2, (30..34).map(|x| x as f32).collect());
        let flat = pack_projections(&man, &[(pi0, po0), (pi1, po1)]);
        assert_eq!(flat.len(), man.proj_len);
        assert_eq!(flat[0], 0.0);
        assert_eq!(flat[6], 10.0); // po0 starts after pi0
        assert_eq!(flat[14], 20.0); // module 1 at p_off
        assert_eq!(flat[14 + 8], 30.0);
    }

    #[test]
    fn random_projections_orthonormal_rows() {
        let man = toy_manifest();
        let mut rng = Pcg32::seeded(1);
        let flat = random_projections(&man, &mut rng);
        // First module's P_i rows (2x3) orthonormal.
        let pi = Matrix::from_vec(2, 3, flat[0..6].to_vec());
        let g = pi.matmul_t(&pi);
        assert!(g.max_abs_diff(&Matrix::identity(2)) < 1e-4);
    }

    #[test]
    fn kfac_accumulate_and_pca() {
        let man = toy_manifest();
        let mut kf = KfacFactors::new(&man);
        // Covariance with a dominant direction e0 for module 0's C_F.
        let mut cov = vec![0.0f32; man.cov_len];
        // C_F module0 = diag(9, 1, 0.1)
        cov[0] = 9.0;
        cov[4] = 1.0;
        cov[8] = 0.1;
        // C_B module0 = diag(4, 2, 1, 0.5)
        for (i, v) in [4.0, 2.0, 1.0, 0.5].iter().enumerate() {
            cov[9 + i * 4 + i] = *v;
        }
        // Module 1 factors = identity-ish.
        let off1 = man.modules[1].cov_off;
        for i in 0..4 {
            cov[off1 + i * 4 + i] = 1.0;
        }
        for i in 0..2 {
            cov[off1 + 16 + i * 2 + i] = 1.0;
        }
        kf.accumulate(&man, &cov, 1).unwrap();
        let flat = pca_projections(&man, &kf);
        // Module-0 P_i top eigenvector = e0 (eigenvalue 9).
        let pi = Matrix::from_vec(2, 3, flat[0..6].to_vec());
        assert!((pi.at(0, 0).abs() - 1.0).abs() < 1e-4, "{:?}", pi.data);
        assert!(pi.at(0, 1).abs() < 1e-4);
        // Second row = e1 (eigenvalue 1).
        assert!((pi.at(1, 1).abs() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn ekfac_kron_eigenvalues_and_precondition() {
        let man = toy_manifest();
        let mut kf = KfacFactors::new(&man);
        let mut cov = vec![0.0f32; man.cov_len];
        // Diagonal factors so eigenbases are axis-aligned.
        for (i, v) in [3.0, 2.0, 1.0].iter().enumerate() {
            cov[i * 4] = *v; // C_F diag at (i,i): index i*3+i = i*4
        }
        for i in 0..4 {
            cov[9 + i * 5] = (4 - i) as f32; // C_B diag 4,3,2,1
        }
        let off1 = man.modules[1].cov_off;
        for i in 0..4 {
            cov[off1 + i * 5] = 1.0;
        }
        for i in 0..2 {
            cov[off1 + 16 + i * 3] = 1.0;
        }
        kf.accumulate(&man, &cov, 1).unwrap();
        let ek = Ekfac::from_kfac(&man, &kf);
        // λ(o=0, i=0) = λ_B max * λ_F max = 4 * 3 = 12.
        assert!((ek.lambda[0] - 12.0).abs() < 1e-3, "{}", ek.lambda[0]);
        // Preconditioning divides by λ + damp.
        let g = vec![1.0f32; man.k_full];
        let pg = ek.precondition(&man, &g);
        assert!(pg[0] < pg[11], "larger eigenvalue entries shrink more");
    }

    #[test]
    fn ekfac_corrected_fit_replaces_lambda() {
        let man = toy_manifest();
        let mut kf = KfacFactors::new(&man);
        let mut cov = vec![0.0f32; man.cov_len];
        for i in 0..3 {
            cov[i * 4] = 1.0;
        }
        for i in 0..4 {
            cov[9 + i * 5] = 1.0;
        }
        let off1 = man.modules[1].cov_off;
        for i in 0..4 {
            cov[off1 + i * 5] = 1.0;
        }
        for i in 0..2 {
            cov[off1 + 16 + i * 3] = 1.0;
        }
        kf.accumulate(&man, &cov, 1).unwrap();
        let mut ek = Ekfac::from_kfac(&man, &kf);
        // Rotated grads with known second moments: g_j = sqrt(j).
        let row: Vec<f32> = (0..man.k_full).map(|j| (j as f32).sqrt()).collect();
        ek.accumulate_corrected(&row, 1, man.k_full);
        ek.finish_corrected(&man);
        for j in 0..man.k_full {
            assert!((ek.lambda[j] - j as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn cov_len_mismatch_rejected() {
        let man = toy_manifest();
        let mut kf = KfacFactors::new(&man);
        assert!(kf.accumulate(&man, &[0.0; 3], 1).is_err());
    }
}
