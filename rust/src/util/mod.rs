//! Infrastructure substrates: everything the offline crate set forced us
//! to hand-roll (see DESIGN.md §1) — PRNG, statistics, top-k selection,
//! bounded pipelines, property testing, micro-benchmarking, memory probes.

pub mod bench;
pub mod json;
pub mod memory;
pub mod pipeline;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod topk;

/// Wall-clock timer with a labelled report.
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: std::time::Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}
