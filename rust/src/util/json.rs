//! Hand-rolled parser for the JSON subset this crate emits and reads —
//! objects, arrays, escape-free strings, unsigned integers. No serde in
//! the offline crate set, so both the shard manifest (`shards.json`,
//! [`crate::store::ShardManifest`]) and the test-side validation of
//! generated JSON (Chrome trace events, bench reports) go through here.
//!
//! Deliberately NOT a general JSON parser: no floats, no negatives, no
//! booleans/null, no string escapes. Everything the crate writes for its
//! own consumption sticks to this subset (e.g.
//! [`crate::obs::chrome_trace_json`] emits integer microsecond
//! timestamps), which keeps the parser ~150 lines and obviously correct.

use anyhow::{anyhow, ensure, Result};

/// A parsed JSON value (the supported subset).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Num(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (`None` on non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse one JSON value; the whole input must be consumed (trailing
/// whitespace allowed).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    ensure!(p.i == p.b.len(), "trailing bytes after JSON value");
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, ch: u8) -> Result<()> {
        let got = self.peek()?;
        ensure!(got == ch, "expected {:?}, got {:?}", ch as char, got as char);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b'0'..=b'9' => self.number(),
            other => Err(anyhow!("unexpected JSON byte {:?}", other as char)),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            pairs.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => {
                    return Err(anyhow!("expected ',' or '}}', got {:?}", other as char))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(anyhow!("expected ',' or ']', got {:?}", other as char))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let start = self.i;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    let s = std::str::from_utf8(&self.b[start..self.i])?.to_string();
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => return Err(anyhow!("escapes unsupported in this JSON subset")),
                _ => self.i += 1,
            }
        }
        Err(anyhow!("unterminated JSON string"))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        ensure!(!s.is_empty(), "empty JSON number");
        Ok(Json::Num(s.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_subset() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "n": 7}"#).unwrap();
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(7));
        let arr = v.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn rejects_out_of_subset_input() {
        assert!(parse("{\"a\": -1}").is_err(), "negatives unsupported");
        assert!(parse("{\"a\": 1.5}").is_err(), "floats unsupported");
        assert!(parse("{\"a\": \"x\\n\"}").is_err(), "escapes unsupported");
        assert!(parse("{} trailing").is_err());
        assert!(parse("").is_err());
    }
}
