//! Hand-rolled parser + writer for the JSON subset this crate emits and
//! reads — objects, arrays, strings (with the standard escapes), unsigned
//! integers, and floats. No serde in the offline crate set, so the shard
//! manifest (`shards.json`, [`crate::store::ShardManifest`]), the bench
//! report read-modify-write in `logra loadgen`, the `logra serve` request
//! bodies, and the test-side validation of generated JSON (Chrome trace
//! events) all go through here.
//!
//! Deliberately NOT a general JSON parser: no booleans, no null, no
//! duplicate-key detection. Digit-only literals stay exact `u64`s (row
//! ids must not round-trip through f64); anything signed, fractional, or
//! exponent-bearing becomes [`Json::Float`]. The writer side is
//! [`escape_into`]/[`escaped`] — the single escape-correct string
//! serializer shared by [`crate::obs::chrome_trace_json`] and the
//! `logra serve` response writers — plus [`Json::render`] for
//! re-serializing parsed values.

use anyhow::{anyhow, ensure, Result};
use std::fmt::Write as _;

/// A parsed JSON value (the supported subset).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// Digit-only literal (kept exact: row ids are u64).
    Num(u64),
    /// Signed, fractional, or exponent-bearing literal.
    Float(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (`None` on non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as f64 — accepts both [`Json::Num`] and
    /// [`Json::Float`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n as f64),
            Json::Float(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize back to JSON text. Floats use Rust's shortest-roundtrip
    /// `{:?}` formatting (integral floats keep a trailing `.0`, so the
    /// value re-parses as a `Float`); non-finite floats are not
    /// representable in JSON and render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Num(n) => {
                let _ = write!(out, "{}", n);
            }
            Json::Float(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{:?}", x);
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                escape_into(out, s);
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    escape_into(out, k);
                    out.push_str("\":");
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append `s` to `out` with JSON string escaping (the content only — the
/// caller writes the surrounding quotes). Escapes `"`, `\`, and all
/// control bytes below 0x20 (named short forms where JSON has them,
/// `\u00XX` otherwise). This is the one escape path every writer in the
/// crate shares; emitting a string any other way is a bug.
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// Convenience form of [`escape_into`] returning a fresh `String`
/// (content only, no surrounding quotes).
pub fn escaped(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    escape_into(&mut out, s);
    out
}

/// Parse one JSON value; the whole input must be consumed (trailing
/// whitespace allowed).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    ensure!(p.i == p.b.len(), "trailing bytes after JSON value");
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, ch: u8) -> Result<()> {
        let got = self.peek()?;
        ensure!(got == ch, "expected {:?}, got {:?}", ch as char, got as char);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b'0'..=b'9' | b'-' => self.number(),
            other => Err(anyhow!("unexpected JSON byte {:?}", other as char)),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            pairs.push((key, self.value()?));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => {
                    return Err(anyhow!("expected ',' or '}}', got {:?}", other as char))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(anyhow!("expected ',' or ']', got {:?}", other as char))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let esc = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| anyhow!("unterminated escape in JSON string"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => {
                            return Err(anyhow!(
                                "unsupported escape \\{:?} in JSON string",
                                other as char
                            ))
                        }
                    }
                }
                _ => {
                    // Copy a full UTF-8 scalar, not byte-by-byte, so
                    // multi-byte content survives intact.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
        Err(anyhow!("unterminated JSON string"))
    }

    /// Parse the 4 hex digits after `\u` (the `\u` itself is consumed).
    /// UTF-16 surrogate pairs (`\uD83D\uDE00`) are combined.
    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            ensure!(
                self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u'),
                "unpaired UTF-16 high surrogate in JSON string"
            );
            self.i += 2;
            let lo = self.hex4()?;
            ensure!(
                (0xDC00..0xE000).contains(&lo),
                "invalid UTF-16 low surrogate in JSON string"
            );
            let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return char::from_u32(code).ok_or_else(|| anyhow!("invalid surrogate pair"));
        }
        ensure!(!(0xDC00..0xE000).contains(&hi), "unpaired UTF-16 low surrogate");
        char::from_u32(hi).ok_or_else(|| anyhow!("invalid \\u escape"))
    }

    fn hex4(&mut self) -> Result<u32> {
        ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        u32::from_str_radix(s, 16).map_err(|_| anyhow!("non-hex \\u escape {:?}", s))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        let mut exact = true; // digits only => keep as u64
        if self.b.get(self.i) == Some(&b'-') {
            exact = false;
            self.i += 1;
        }
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        if self.b.get(self.i) == Some(&b'.') {
            exact = false;
            self.i += 1;
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        if matches!(self.b.get(self.i), Some(&b'e') | Some(&b'E')) {
            exact = false;
            self.i += 1;
            if matches!(self.b.get(self.i), Some(&b'+') | Some(&b'-')) {
                self.i += 1;
            }
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        ensure!(!s.is_empty() && s != "-", "empty JSON number");
        if exact {
            if let Ok(n) = s.parse::<u64>() {
                return Ok(Json::Num(n));
            }
        }
        Ok(Json::Float(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_subset() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "n": 7}"#).unwrap();
        assert_eq!(v.get("n").and_then(Json::as_u64), Some(7));
        let arr = v.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parses_floats_and_negatives() {
        let v = parse(r#"{"a": -1, "b": 1.5, "c": 2e3, "d": -0.25, "e": 7}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_f64), Some(-1.0));
        assert_eq!(v.get("b").and_then(Json::as_f64), Some(1.5));
        assert_eq!(v.get("c").and_then(Json::as_f64), Some(2000.0));
        assert_eq!(v.get("d").and_then(Json::as_f64), Some(-0.25));
        // Digit-only literals stay exact u64s, but as_f64 still reads them.
        assert_eq!(v.get("e").and_then(Json::as_u64), Some(7));
        assert_eq!(v.get("e").and_then(Json::as_f64), Some(7.0));
        assert_eq!(v.get("a").and_then(Json::as_u64), None);
    }

    #[test]
    fn float_roundtrips_bit_exact() {
        // {:?} on f64 is shortest-roundtrip, so render -> parse recovers
        // the exact bits (the serve responses rely on the same property).
        for x in [1.5e-300f64, -0.1, 3.141592653589793, 1e17 + 1.0] {
            let v = parse(&Json::Float(x).render()).unwrap();
            match v {
                Json::Float(y) => assert_eq!(x.to_bits(), y.to_bits()),
                other => panic!("expected Float, got {:?}", other),
            }
        }
    }

    #[test]
    fn parses_string_escapes() {
        let v = parse(r#"{"s": "a\"b\\c\nd\te\u0041", "t": "\ud83d\ude00"}"#).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("a\"b\\c\nd\teA"));
        assert_eq!(v.get("t").and_then(Json::as_str), Some("\u{1F600}"));
    }

    #[test]
    fn escape_writer_roundtrips_through_parser() {
        let nasty = "quote\" slash\\ nl\n tab\t ctrl\u{0001} uni\u{1F600}";
        let mut doc = String::from("{\"k\":\"");
        escape_into(&mut doc, nasty);
        doc.push_str("\"}");
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("k").and_then(Json::as_str), Some(nasty));
        assert_eq!(escaped("a\"b"), "a\\\"b");
    }

    #[test]
    fn render_roundtrips() {
        let src = r#"{"a":[1,2,{"b":"x\ny"}],"n":7,"f":-1.5}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.render()).unwrap(), v);
        assert_eq!(v.render(), src);
    }

    #[test]
    fn rejects_out_of_subset_input() {
        assert!(parse("{\"a\": true}").is_err(), "booleans unsupported");
        assert!(parse("{\"a\": null}").is_err(), "null unsupported");
        assert!(parse("{\"a\": \"x\\q\"}").is_err(), "unknown escape");
        assert!(parse("{\"a\": \"\\u12\"}").is_err(), "truncated \\u escape");
        assert!(parse("{\"a\": \"\\ud800\"}").is_err(), "unpaired surrogate");
        assert!(parse("{} trailing").is_err());
        assert!(parse("-").is_err());
        assert!(parse("").is_err());
    }
}
