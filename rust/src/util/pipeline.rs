//! Bounded MPSC channel + pipeline stages (std-only; no tokio offline).
//!
//! The coordinator's logging and query paths are staged pipelines
//! (batcher -> executor -> writer; prefetcher -> scorer). A bounded
//! channel gives backpressure: a slow disk naturally throttles the
//! executor instead of letting gradients pile up in memory — the paper's
//! §E.2 "overlap IO with compute" design, minus the unbounded queues.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

struct Inner<T> {
    queue: VecDeque<T>,
    cap: usize,
    closed: bool,
    senders: usize,
}

/// Bounded blocking channel. `send` blocks when full; `recv` blocks when
/// empty; both unblock on close/disconnect.
pub struct Sender<T> {
    inner: Arc<(Mutex<Inner<T>>, Condvar, Condvar)>,
}

pub struct Receiver<T> {
    inner: Arc<(Mutex<Inner<T>>, Condvar, Condvar)>,
}

/// Create a bounded channel with capacity `cap` (>= 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1);
    let inner = Arc::new((
        Mutex::new(Inner { queue: VecDeque::new(), cap, closed: false, senders: 1 }),
        Condvar::new(), // not-full
        Condvar::new(), // not-empty
    ));
    (Sender { inner: inner.clone() }, Receiver { inner })
}

#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

impl<T> Sender<T> {
    /// Blocking send. Err(value) if the receiver is gone.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let (lock, not_full, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        loop {
            if g.closed {
                return Err(SendError(value));
            }
            if g.queue.len() < g.cap {
                g.queue.push_back(value);
                not_empty.notify_one();
                return Ok(());
            }
            g = not_full.wait(g).unwrap();
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        let (lock, ..) = &*self.inner;
        lock.lock().unwrap().senders += 1;
        Sender { inner: self.inner.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let (lock, _, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        g.senders -= 1;
        if g.senders == 0 {
            not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive. None when all senders dropped and queue drained.
    pub fn recv(&self) -> Option<T> {
        let (lock, not_full, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        loop {
            if let Some(v) = g.queue.pop_front() {
                not_full.notify_one();
                return Some(v);
            }
            if g.senders == 0 || g.closed {
                return None;
            }
            g = not_empty.wait(g).unwrap();
        }
    }

    /// Blocking receive with a deadline: waits on the condvar (no
    /// spinning) until a value arrives, all senders drop, or `deadline`
    /// passes. `None` means closed OR timed out — deadline loops should
    /// simply stop batching either way.
    pub fn recv_deadline(&self, deadline: Instant) -> Option<T> {
        let (lock, not_full, not_empty) = &*self.inner;
        let mut g = lock.lock().unwrap();
        loop {
            if let Some(v) = g.queue.pop_front() {
                not_full.notify_one();
                return Some(v);
            }
            if g.senders == 0 || g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _timeout) = not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// True once every sender has dropped AND the queue is drained —
    /// i.e. `recv` would return `None` because the channel is finished,
    /// not because a deadline passed. Disambiguates the two `None` cases
    /// of [`Receiver::recv_deadline`] for callers that poll with short
    /// deadlines (the serve path's cancellable waits).
    pub fn is_disconnected(&self) -> bool {
        let g = self.inner.0.lock().unwrap();
        g.senders == 0 && g.queue.is_empty()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let (lock, not_full, _) = &*self.inner;
        let mut g = lock.lock().unwrap();
        let v = g.queue.pop_front();
        if v.is_some() {
            not_full.notify_one();
        }
        v
    }

    /// Current queue depth (diagnostics / backpressure metrics).
    pub fn depth(&self) -> usize {
        self.inner.0.lock().unwrap().queue.len()
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let (lock, not_full, _) = &*self.inner;
        let mut g = lock.lock().unwrap();
        g.closed = true;
        not_full.notify_all();
    }
}

/// Spawn a named worker thread.
pub fn spawn_worker<F>(name: &str, f: F) -> std::thread::JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .expect("spawn worker")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn fifo_order_preserved() {
        let (tx, rx) = bounded(4);
        let h = spawn_worker("t", move || {
            for i in 0..100 {
                tx.send(i).unwrap();
            }
        });
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        h.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_applies_backpressure() {
        let (tx, rx) = bounded(2);
        let sent = Arc::new(AtomicUsize::new(0));
        let sent2 = sent.clone();
        let h = spawn_worker("producer", move || {
            for i in 0..10 {
                tx.send(i).unwrap();
                sent2.fetch_add(1, Ordering::SeqCst);
            }
        });
        std::thread::sleep(Duration::from_millis(50));
        // Producer must be stuck well before 10: cap 2 (+1 in flight).
        assert!(sent.load(Ordering::SeqCst) <= 3);
        let mut n = 0;
        while rx.recv().is_some() {
            n += 1;
        }
        assert_eq!(n, 10);
        h.join().unwrap();
    }

    #[test]
    fn recv_none_after_senders_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(tx);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_deadline_returns_queued_value_immediately() {
        let (tx, rx) = bounded(2);
        tx.send(7).unwrap();
        let t0 = std::time::Instant::now();
        let got = rx.recv_deadline(t0 + Duration::from_secs(5));
        assert_eq!(got, Some(7));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn recv_deadline_times_out_without_spinning() {
        let (tx, rx) = bounded::<i32>(1);
        let t0 = std::time::Instant::now();
        let got = rx.recv_deadline(t0 + Duration::from_millis(30));
        assert_eq!(got, None);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        drop(tx);
    }

    #[test]
    fn recv_deadline_wakes_on_send() {
        let (tx, rx) = bounded(1);
        let h = spawn_worker("late-sender", move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(42).unwrap();
        });
        let got = rx.recv_deadline(std::time::Instant::now() + Duration::from_secs(5));
        assert_eq!(got, Some(42));
        h.join().unwrap();
    }

    #[test]
    fn recv_deadline_none_when_closed() {
        let (tx, rx) = bounded::<i32>(1);
        drop(tx);
        let got = rx.recv_deadline(std::time::Instant::now() + Duration::from_secs(5));
        assert_eq!(got, None);
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn multi_producer_no_loss() {
        let (tx, rx) = bounded(3);
        let mut handles = vec![];
        for t in 0..4 {
            let txc = tx.clone();
            handles.push(spawn_worker("p", move || {
                for i in 0..50 {
                    txc.send(t * 1000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        let mut want: Vec<i32> =
            (0..4).flat_map(|t| (0..50).map(move |i| t * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
