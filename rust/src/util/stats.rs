//! Descriptive statistics and correlation measures.
//!
//! Spearman rank correlation is the paper's LDS metric (§4.1); Pearson,
//! mean/std and percentiles back the benchmark harness.

/// Arithmetic mean. Empty input -> NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator). len < 2 -> 0.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return f64::NAN;
    }
    let (mx, my) = (mean(x), mean(y));
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

/// Fractional ranks with ties averaged (the convention Spearman needs).
pub fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // 1-based average rank
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation (ties averaged) — the LDS statistic.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

/// Summary of a sample (used by the bench harness).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        p50: percentile(xs, 50.0),
        p95: percentile(xs, 95.0),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_is_rank_based() {
        // Monotone nonlinear map preserves Spearman exactly.
        let x = [1.0f64, 2.0, 3.0, 4.0, 5.0];
        let y: Vec<f64> = x.iter().map(|v| v.exp()).collect();
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_ties_averaged() {
        let x = [1.0, 1.0, 2.0];
        let r = ranks(&x);
        assert_eq!(r, vec![1.5, 1.5, 3.0]);
    }

    #[test]
    fn spearman_anticorrelation() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [10.0, 8.0, 5.0, 1.0];
        assert!((spearman(&x, &y) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn spearman_independent_near_zero() {
        use crate::util::rng::Pcg32;
        let mut r = Pcg32::seeded(3);
        let x: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        let y: Vec<f64> = (0..2000).map(|_| r.normal()).collect();
        assert!(spearman(&x, &y).abs() < 0.08);
    }
}
