//! PCG32 pseudo-random number generator.
//!
//! Deterministic, seedable, splittable-by-stream — the single randomness
//! source for the whole coordinator (data synthesis, random projections,
//! subset sampling for LDS, …). Hand-rolled because the offline crate set
//! has no `rand`; PCG32 (Melissa O'Neill, PCG-XSH-RR 64/32) is small,
//! fast and statistically solid for simulation workloads.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams
    /// are independent sequences even under the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (e.g. per worker thread).
    pub fn split(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        debug_assert!(n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Standard normal via Box–Muller (caches the second deviate? no —
    /// simplicity over speed; this is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma) f32 deviates.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (floyd's algorithm for
    /// small k, shuffle for large).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.below_usize(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Sample from a discrete distribution given cumulative weights
    /// (last element = total mass).
    pub fn categorical_cdf(&mut self, cdf: &[f64]) -> usize {
        let total = *cdf.last().expect("empty cdf");
        let u = self.uniform() * total;
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn streams_are_independent() {
        let a: Vec<u32> = (0..32).map({
            let mut r = Pcg32::new(1, 0);
            move |_| r.next_u32()
        }).collect();
        let b: Vec<u32> = (0..32).map({
            let mut r = Pcg32::new(1, 1);
            move |_| r.next_u32()
        }).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Pcg32::seeded(5);
        for (n, k) in [(10, 3), (100, 50), (64, 64), (1000, 5)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg32::seeded(17);
        let cdf = [1.0, 1.0, 4.0]; // weights 1, 0, 3
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical_cdf(&cdf)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!((counts[0] as f64 / 40_000.0 - 0.25).abs() < 0.02);
    }
}
