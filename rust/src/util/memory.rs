//! Process-memory probes (Table-1 "Memory" column).
//!
//! Reads `/proc/self/status` (Linux) for resident-set figures and keeps an
//! explicit byte-ledger for the big planned allocations (gradient buffers,
//! Hessian blocks, mmap windows) so phase reports can split "model/runtime"
//! from "valuation state" the way the paper's Table 1 does.

use std::sync::atomic::{AtomicI64, Ordering};

static LEDGER: AtomicI64 = AtomicI64::new(0);
static LEDGER_PEAK: AtomicI64 = AtomicI64::new(0);

/// Record an allocation of `bytes` in the explicit ledger.
pub fn ledger_alloc(bytes: usize) {
    let now = LEDGER.fetch_add(bytes as i64, Ordering::Relaxed) + bytes as i64;
    LEDGER_PEAK.fetch_max(now, Ordering::Relaxed);
}

/// Record a release of `bytes`.
pub fn ledger_free(bytes: usize) {
    LEDGER.fetch_sub(bytes as i64, Ordering::Relaxed);
}

/// Current / peak ledger bytes.
pub fn ledger_now() -> i64 {
    LEDGER.load(Ordering::Relaxed)
}

pub fn ledger_peak() -> i64 {
    LEDGER_PEAK.load(Ordering::Relaxed)
}

/// Reset peak tracking (between benchmark phases).
pub fn ledger_reset_peak() {
    LEDGER_PEAK.store(LEDGER.load(Ordering::Relaxed), Ordering::Relaxed);
}

fn read_status_kb(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            let rest = rest.trim_start_matches(':').trim();
            let num = rest.split_whitespace().next()?;
            return num.parse().ok();
        }
    }
    None
}

/// Current resident set size in bytes (0 if unavailable).
pub fn rss_bytes() -> u64 {
    read_status_kb("VmRSS").map(|kb| kb * 1024).unwrap_or(0)
}

/// Peak resident set size in bytes (0 if unavailable).
pub fn peak_rss_bytes() -> u64 {
    read_status_kb("VmHWM").map(|kb| kb * 1024).unwrap_or(0)
}

/// Human-readable byte count.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(rss_bytes() > 0);
        assert!(peak_rss_bytes() >= rss_bytes() / 2);
    }

    #[test]
    fn ledger_tracks_peak() {
        ledger_reset_peak();
        let base = ledger_now();
        ledger_alloc(1000);
        ledger_alloc(500);
        ledger_free(800);
        assert_eq!(ledger_now(), base + 700);
        assert!(ledger_peak() >= base + 1500);
        ledger_free(700);
    }

    #[test]
    fn human_bytes_formats() {
        assert_eq!(human_bytes(512), "512.0 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }
}
