//! Streaming top-k selection (min-heap over (score, id)).
//!
//! The query engine scans millions of stored train gradients per query and
//! keeps only the k most valuable — this heap is that reduction. NaN scores
//! are rejected at insert so ordering stays total.

/// Fixed-capacity top-k accumulator over (score, id) pairs.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    // Min-heap by score: heap[0] is the current k-th best.
    heap: Vec<(f64, u64)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "top-k with k=0");
        TopK { k, heap: Vec::with_capacity(k) }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current admission threshold (score of the weakest kept element).
    pub fn threshold(&self) -> f64 {
        if self.heap.len() < self.k {
            f64::NEG_INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Offer one candidate. O(log k) when admitted, O(1) when rejected.
    pub fn push(&mut self, score: f64, id: u64) {
        if score.is_nan() {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, id));
            self.sift_up(self.heap.len() - 1);
        } else if score > self.heap[0].0 {
            self.heap[0] = (score, id);
            self.sift_down(0);
        }
    }

    /// Drain into (score, id) pairs sorted by descending score.
    pub fn into_sorted(mut self) -> Vec<(f64, u64)> {
        self.heap
            .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 < self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && self.heap[l].0 < self.heap[smallest].0 {
                smallest = l;
            }
            if r < n && self.heap[r].0 < self.heap[smallest].0 {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn brute_topk(scores: &[f64], k: usize) -> Vec<(f64, u64)> {
        let mut pairs: Vec<(f64, u64)> =
            scores.iter().enumerate().map(|(i, &s)| (s, i as u64)).collect();
        pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        pairs.truncate(k);
        pairs
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Pcg32::seeded(1);
        for trial in 0..50 {
            let n = 1 + rng.below_usize(200);
            let k = 1 + rng.below_usize(20);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut tk = TopK::new(k);
            for (i, &s) in scores.iter().enumerate() {
                tk.push(s, i as u64);
            }
            let got = tk.into_sorted();
            let want = brute_topk(&scores, k);
            assert_eq!(got.len(), want.len(), "trial {trial}");
            // Scores must match exactly; ids may differ only among ties.
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.0, w.0, "trial {trial}");
            }
        }
    }

    #[test]
    fn rejects_nan() {
        let mut tk = TopK::new(2);
        tk.push(f64::NAN, 0);
        tk.push(1.0, 1);
        assert_eq!(tk.len(), 1);
    }

    #[test]
    fn threshold_tracks_kth() {
        let mut tk = TopK::new(3);
        assert_eq!(tk.threshold(), f64::NEG_INFINITY);
        for (i, s) in [5.0, 1.0, 3.0, 4.0].iter().enumerate() {
            tk.push(*s, i as u64);
        }
        assert_eq!(tk.threshold(), 3.0);
    }

    #[test]
    fn ties_are_deterministic() {
        let mut tk = TopK::new(2);
        for i in 0..5 {
            tk.push(1.0, i);
        }
        let out = tk.into_sorted();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|&(s, _)| s == 1.0));
    }
}
