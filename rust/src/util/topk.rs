//! Streaming top-k selection (min-heap over (score, id)).
//!
//! The query engine scans millions of stored train gradients per query and
//! keeps only the k most valuable — this heap is that reduction. NaN scores
//! are rejected at insert so ordering stays total, and ties are broken by
//! data id (smaller id wins), making the kept SET a pure function of the
//! candidate multiset — independent of push order. That order-independence
//! is what lets the parallel scan engine keep one heap per shard and merge
//! them into results bit-identical to a single sequential scan.

/// Total order used for admission and eviction: by score, ties broken by
/// preferring the smaller id (matches [`TopK::into_sorted`]'s ordering).
#[inline]
fn less(a: (f64, u64), b: (f64, u64)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 > b.1)
}

/// Fixed-capacity top-k accumulator over (score, id) pairs.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    // Min-heap by `less`: heap[0] is the current k-th best.
    heap: Vec<(f64, u64)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "top-k with k=0");
        TopK { k, heap: Vec::with_capacity(k) }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current admission threshold (score of the weakest kept element).
    pub fn threshold(&self) -> f64 {
        if self.heap.len() < self.k {
            f64::NEG_INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Offer one candidate. O(log k) when admitted, O(1) when rejected.
    pub fn push(&mut self, score: f64, id: u64) {
        if score.is_nan() {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, id));
            self.sift_up(self.heap.len() - 1);
        } else if less(self.heap[0], (score, id)) {
            self.heap[0] = (score, id);
            self.sift_down(0);
        }
    }

    /// Merge another heap's survivors into this one.
    pub fn merge(&mut self, other: TopK) {
        for (s, id) in other.heap {
            self.push(s, id);
        }
    }

    /// Drain into (score, id) pairs sorted by descending score.
    pub fn into_sorted(mut self) -> Vec<(f64, u64)> {
        self.heap
            .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        self.heap
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if less(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && less(self.heap[l], self.heap[smallest]) {
                smallest = l;
            }
            if r < n && less(self.heap[r], self.heap[smallest]) {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn brute_topk(scores: &[f64], k: usize) -> Vec<(f64, u64)> {
        let mut pairs: Vec<(f64, u64)> =
            scores.iter().enumerate().map(|(i, &s)| (s, i as u64)).collect();
        pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        pairs.truncate(k);
        pairs
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Pcg32::seeded(1);
        for trial in 0..50 {
            let n = 1 + rng.below_usize(200);
            let k = 1 + rng.below_usize(20);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut tk = TopK::new(k);
            for (i, &s) in scores.iter().enumerate() {
                tk.push(s, i as u64);
            }
            let got = tk.into_sorted();
            let want = brute_topk(&scores, k);
            // With total-order tie-breaking, ids match exactly too.
            assert_eq!(got, want, "trial {trial}");
        }
    }

    #[test]
    fn rejects_nan() {
        let mut tk = TopK::new(2);
        tk.push(f64::NAN, 0);
        tk.push(1.0, 1);
        assert_eq!(tk.len(), 1);
    }

    #[test]
    fn threshold_tracks_kth() {
        let mut tk = TopK::new(3);
        assert_eq!(tk.threshold(), f64::NEG_INFINITY);
        for (i, s) in [5.0, 1.0, 3.0, 4.0].iter().enumerate() {
            tk.push(*s, i as u64);
        }
        assert_eq!(tk.threshold(), 3.0);
    }

    #[test]
    fn ties_keep_smallest_ids() {
        let mut tk = TopK::new(2);
        for i in [4u64, 2, 0, 3, 1] {
            tk.push(1.0, i);
        }
        assert_eq!(tk.into_sorted(), vec![(1.0, 0), (1.0, 1)]);
    }

    #[test]
    fn kept_set_is_push_order_independent() {
        // The property the parallel scan-and-merge relies on.
        let mut rng = Pcg32::seeded(7);
        for trial in 0..30 {
            let n = 5 + rng.below_usize(100);
            let k = 1 + rng.below_usize(10);
            // Coarse scores force plenty of ties.
            let pairs: Vec<(f64, u64)> =
                (0..n).map(|i| ((rng.below(5) as f64) / 2.0, i as u64)).collect();
            let mut fwd = TopK::new(k);
            let mut rev = TopK::new(k);
            for &(s, id) in &pairs {
                fwd.push(s, id);
            }
            for &(s, id) in pairs.iter().rev() {
                rev.push(s, id);
            }
            assert_eq!(fwd.into_sorted(), rev.into_sorted(), "trial {trial}");
        }
    }

    #[test]
    fn merge_of_partial_heaps_matches_global() {
        let mut rng = Pcg32::seeded(9);
        for trial in 0..30 {
            let n = 10 + rng.below_usize(200);
            let k = 1 + rng.below_usize(8);
            let parts = 2 + rng.below_usize(4);
            let scores: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut global = TopK::new(k);
            let mut shards: Vec<TopK> = (0..parts).map(|_| TopK::new(k)).collect();
            for (i, &s) in scores.iter().enumerate() {
                global.push(s, i as u64);
                shards[i % parts].push(s, i as u64);
            }
            let mut merged = TopK::new(k);
            for sh in shards {
                merged.merge(sh);
            }
            assert_eq!(merged.into_sorted(), global.into_sorted(), "trial {trial}");
        }
    }
}
