//! Minimal property-based testing framework (offline stand-in for
//! `proptest`, which is unavailable in the vendored crate set — see
//! DESIGN.md §1).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for N
//! seeded cases and, on failure, retries the same seed with shrink hints
//! so size-dependent generators (`Gen::size_hint`) produce smaller
//! counterexamples. Failures report the reproducing seed.

use crate::util::rng::Pcg32;

/// Randomness + size budget handed to each property case.
pub struct Gen {
    pub rng: Pcg32,
    /// 0.0..=1.0 scale for "how big" generated values should be; the
    /// shrink loop lowers this after a failure.
    pub size: f64,
}

impl Gen {
    /// Integer in [lo, hi] scaled by the current size budget.
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below_usize(span + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo) * self.size.max(0.05)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_f64(&mut self, max_len: usize) -> Vec<f64> {
        let n = self.int_in(0, max_len);
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn vec_f32(&mut self, max_len: usize) -> Vec<f32> {
        let n = self.int_in(0, max_len);
        (0..n).map(|_| self.rng.normal_f32()).collect()
    }
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` for `cases` seeded cases. On failure, tries shrunken sizes
/// for the failing seed and panics with the smallest failure found.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> CaseResult) {
    let base_seed = 0xC0FFEE ^ fxhash(name);
    for case in 0..cases {
        let seed =
            base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Pcg32::seeded(seed), size: 1.0 };
        if let Err(first) = prop(&mut g) {
            // Shrink: replay same seed at smaller sizes.
            let mut smallest = first;
            for &size in &[0.5, 0.25, 0.1, 0.02] {
                let mut g = Gen { rng: Pcg32::seeded(seed), size };
                if let Err(msg) = prop(&mut g) {
                    smallest = msg;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  {smallest}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-twice", 50, |g| {
            let v = g.vec_f64(64);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "reverse twice changed vec");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn sizes_shrink_generated_values() {
        let mut g = Gen { rng: Pcg32::seeded(1), size: 0.02 };
        for _ in 0..50 {
            assert!(g.int_in(0, 100) <= 3);
        }
    }
}
