//! Query engine over a finalized gradient store.

use std::cell::RefCell;

use anyhow::Result;

use crate::hessian::Preconditioner;
use crate::linalg::{dot, Matrix};
use crate::runtime::literal::{f32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::store::GradStore;
use crate::util::topk::TopK;

/// Score normalization mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Normalization {
    /// Raw influence g_te^T (H+λI)^{-1} g_tr.
    None,
    /// ℓ-RelatIF (Barshan et al.; paper §4.2): influence divided by
    /// sqrt(self-influence of the train example) — suppresses the
    /// high-gradient-norm outliers that otherwise dominate LM valuation.
    RelatIf,
}

/// Top-k result for one query row.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// (score, data_id) descending.
    pub top: Vec<(f64, u64)>,
}

/// Influence scorer bound to (runtime, store, preconditioner).
pub struct QueryEngine<'a> {
    pub rt: &'a Runtime,
    pub store: &'a GradStore,
    pub precond: &'a Preconditioner,
    /// Score chunks through the AOT Pallas `score` program (true) or the
    /// native matmul fallback (false). HLO requires the manifest's
    /// (test_batch, train_chunk) shapes; other shapes fall back natively.
    pub use_hlo: bool,
    /// Lazily computed self-influence of every stored train row
    /// (RelatIF denominators), cached across queries.
    self_inf: RefCell<Option<Vec<f32>>>,
}

impl<'a> QueryEngine<'a> {
    pub fn new(rt: &'a Runtime, store: &'a GradStore, precond: &'a Preconditioner) -> Self {
        QueryEngine { rt, store, precond, use_hlo: true, self_inf: RefCell::new(None) }
    }

    /// Self-influence of each stored row (computed once, then cached).
    pub fn train_self_influences(&self) -> Vec<f32> {
        if let Some(v) = self.self_inf.borrow().as_ref() {
            return v.clone();
        }
        let k = self.store.k();
        let mut out = Vec::with_capacity(self.store.rows());
        for i in 0..self.store.rows() {
            let row = self.store.chunk(i, 1);
            out.push(self.precond.self_influence(&row[..k]));
        }
        *self.self_inf.borrow_mut() = Some(out.clone());
        out
    }

    /// Score one chunk of stored rows against preconditioned test rows.
    /// `pre_rows` is row-major [nt, k]. Returns row-major [nt, len].
    fn score_chunk(&self, pre_rows: &[f32], nt: usize, start: usize, len: usize) -> Result<Vec<f32>> {
        let k = self.store.k();
        let man = &self.rt.manifest;
        let chunk = self.store.chunk(start, len);
        let use_hlo = self.use_hlo
            && nt == man.test_batch
            && len == man.train_chunk
            && k == man.k_total;
        if use_hlo {
            let out = self.rt.run(
                "score",
                &[f32_lit(&[nt, k], pre_rows)?, f32_lit(&[len, k], chunk)?],
            )?;
            return Ok(to_f32_vec(&out[0])?);
        }
        // Native fallback (also used by tests as an oracle) — operates on
        // the mmap chunk in place, no copies.
        Ok(crate::linalg::matrix::matmul_t_slices(pre_rows, nt, chunk, len, k))
    }

    /// Full scan: top-k most valuable train examples per test row.
    ///
    /// `test_grads` is row-major [nt, k] of RAW projected test gradients
    /// (preconditioning happens here).
    pub fn query(
        &self,
        test_grads: &[f32],
        nt: usize,
        topk: usize,
        norm: Normalization,
    ) -> Result<Vec<QueryResult>> {
        let k = self.store.k();
        assert_eq!(test_grads.len(), nt * k);
        let pre = self.precond.apply_rows(test_grads, nt);
        let selfs = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(topk)).collect();
        let rows = self.store.rows();
        let chunk_len = self.rt.manifest.train_chunk.max(1);
        let mut at = 0usize;
        while at < rows {
            let len = chunk_len.min(rows - at);
            // Overlap: hint the NEXT chunk while we score this one.
            if at + len < rows {
                self.store.prefetch(at + len, chunk_len.min(rows - at - len));
            }
            let scores = self.score_chunk(&pre, nt, at, len)?;
            for t in 0..nt {
                let heap = &mut heaps[t];
                let srow = &scores[t * len..(t + 1) * len];
                for (j, &s) in srow.iter().enumerate() {
                    let s = match &selfs {
                        Some(si) => s as f64 / (si[at + j].max(0.0) as f64).sqrt().max(1e-12),
                        None => s as f64,
                    };
                    heap.push(s, self.store.id(at + j));
                }
            }
            at += len;
        }
        Ok(heaps.into_iter().map(|h| QueryResult { top: h.into_sorted() }).collect())
    }

    /// Dense value matrix [nt, n_train] (counterfactual evals need every
    /// score, not just the top-k).
    pub fn values_matrix(
        &self,
        test_grads: &[f32],
        nt: usize,
        norm: Normalization,
    ) -> Result<Matrix> {
        let k = self.store.k();
        assert_eq!(test_grads.len(), nt * k);
        let pre = self.precond.apply_rows(test_grads, nt);
        let selfs = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let rows = self.store.rows();
        let mut out = Matrix::zeros(nt, rows);
        let chunk_len = self.rt.manifest.train_chunk.max(1);
        let mut at = 0usize;
        while at < rows {
            let len = chunk_len.min(rows - at);
            let scores = self.score_chunk(&pre, nt, at, len)?;
            for t in 0..nt {
                for j in 0..len {
                    let mut s = scores[t * len + j];
                    if let Some(si) = &selfs {
                        s /= (si[at + j].max(0.0)).sqrt().max(1e-12);
                    }
                    out.data[t * rows + at + j] = s;
                }
            }
            at += len;
        }
        Ok(out)
    }

    /// Influence of a single (test, train) pair straight from rows.
    pub fn pair_influence(&self, test_row: &[f32], train_idx: usize) -> f32 {
        let pre = self.precond.apply(test_row);
        dot(&pre, self.store.chunk(train_idx, 1))
    }
}
