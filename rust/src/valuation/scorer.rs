//! Query engine over a finalized gradient store.

use std::borrow::Cow;
use std::cell::{Ref, RefCell};

use anyhow::Result;

use crate::hessian::Preconditioner;
use crate::linalg::kernels::{self, matmul_t_into};
use crate::linalg::{Matrix, ScanScratch};
use crate::runtime::literal::{f32_lit, to_f32_vec};
use crate::runtime::Runtime;
use crate::store::GradStore;
use crate::util::topk::TopK;

/// Score normalization mode.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Normalization {
    /// Raw influence g_te^T (H+λI)^{-1} g_tr (the default).
    #[default]
    None,
    /// ℓ-RelatIF (Barshan et al.; paper §4.2): influence divided by
    /// sqrt(self-influence of the train example) — suppresses the
    /// high-gradient-norm outliers that otherwise dominate LM valuation.
    RelatIf,
}

impl Normalization {
    /// Parse a CLI flag value: `none` | `relatif`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Normalization::None),
            "relatif" => Ok(Normalization::RelatIf),
            other => Err(anyhow::anyhow!(
                "unknown normalization {other:?}; try none|relatif"
            )),
        }
    }
}

/// Top-k result for one query row.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// (score, data_id) descending.
    pub top: Vec<(f64, u64)>,
}

/// Influence scorer bound to (store, preconditioner), optionally backed by
/// a PJRT runtime for the AOT `score` program.
pub struct QueryEngine<'a> {
    rt: Option<&'a Runtime>,
    pub store: &'a GradStore,
    pub precond: &'a Preconditioner,
    /// Score chunks through the AOT Pallas `score` program (true) or the
    /// native matmul fallback (false). HLO requires a runtime and the
    /// manifest's (test_batch, train_chunk) shapes; other shapes fall back
    /// natively.
    pub use_hlo: bool,
    /// Scan chunk length (the manifest's `train_chunk` when a runtime is
    /// attached; 0 = derive per query so chunk + test block fit L2).
    chunk_len: usize,
    /// Lazily computed self-influence of every stored train row
    /// (RelatIF denominators), cached across queries.
    self_inf: RefCell<Option<Vec<f32>>>,
    /// Reusable kernel scratch: the engine is single-threaded per query,
    /// so one scratch serves every chunk of every query — zero per-chunk
    /// allocation, same contract as the pool workers'.
    scratch: RefCell<ScanScratch>,
}

impl<'a> QueryEngine<'a> {
    pub fn new(rt: &'a Runtime, store: &'a GradStore, precond: &'a Preconditioner) -> Self {
        QueryEngine {
            rt: Some(rt),
            store,
            precond,
            use_hlo: true,
            chunk_len: rt.manifest.train_chunk.max(1),
            self_inf: RefCell::new(None),
            scratch: RefCell::new(ScanScratch::new()),
        }
    }

    /// Runtime-free engine: native scoring only. The oracle the parallel
    /// scan engine is verified against, and the path tests use without
    /// artifacts. `chunk_len` 0 derives the chunk per query
    /// ([`kernels::auto_chunk_len`]).
    pub fn new_native(
        store: &'a GradStore,
        precond: &'a Preconditioner,
        chunk_len: usize,
    ) -> Self {
        QueryEngine {
            rt: None,
            store,
            precond,
            use_hlo: false,
            chunk_len,
            self_inf: RefCell::new(None),
            scratch: RefCell::new(ScanScratch::new()),
        }
    }

    /// Scan chunk for an nt-row query: the explicit knob, or the L2-fit
    /// derivation when the knob is 0.
    fn resolved_chunk_len(&self, nt: usize) -> usize {
        if self.chunk_len != 0 {
            self.chunk_len
        } else {
            kernels::auto_chunk_len(self.store.k(), nt.max(1), self.store.k() * 4)
        }
    }

    /// Self-influence of each stored row (computed chunk-wise once through
    /// the batched kernel path, then served from the cache — no per-query
    /// clone).
    pub fn train_self_influences(&self) -> Ref<'_, [f32]> {
        if self.self_inf.borrow().is_none() {
            let k = self.store.k();
            let rows = self.store.rows();
            let chunk_len = super::parallel::resolve_chunk_len_self_inf(self.chunk_len, k);
            let mut scratch = self.scratch.borrow_mut();
            let mut out = Vec::with_capacity(rows);
            let mut at = 0usize;
            while at < rows {
                let len = chunk_len.min(rows - at);
                let chunk = self.store.chunk(at, len);
                let applied = scratch.aux_buf(len * k);
                self.precond.self_influences_into(chunk, len, applied, &mut out);
                at += len;
            }
            *self.self_inf.borrow_mut() = Some(out);
        }
        Ref::map(self.self_inf.borrow(), |o| o.as_deref().unwrap())
    }

    /// Score one chunk of stored rows against preconditioned test rows:
    /// row-major [nt, len]. The native path writes the engine scratch in
    /// place (no per-chunk allocation) and borrows it; the HLO path hands
    /// back the runtime's decoded buffer as-is (its allocation is
    /// unavoidable — copying it into scratch would only add work).
    fn score_chunk_into<'s>(
        &self,
        pre_rows: &[f32],
        nt: usize,
        start: usize,
        len: usize,
        scratch: &'s mut ScanScratch,
    ) -> Result<Cow<'s, [f32]>> {
        let k = self.store.k();
        let chunk = self.store.chunk(start, len);
        if self.use_hlo {
            if let Some(rt) = self.rt {
                let man = &rt.manifest;
                if nt == man.test_batch && len == man.train_chunk && k == man.k_total {
                    let out = rt.run(
                        "score",
                        &[f32_lit(&[nt, k], pre_rows)?, f32_lit(&[len, k], chunk)?],
                    )?;
                    return Ok(Cow::Owned(to_f32_vec(&out[0])?));
                }
            }
        }
        // Native fallback (also the oracle the parallel engines are
        // verified against) — the shared scan kernel, writing the leased
        // buffer in place: no copies, no per-chunk allocation.
        let buf = scratch.score_buf(nt * len);
        matmul_t_into(pre_rows, nt, chunk, len, k, buf);
        Ok(Cow::Borrowed(buf))
    }

    /// Full scan: top-k most valuable train examples per test row.
    ///
    /// `test_grads` is row-major [nt, k] of RAW projected test gradients
    /// (preconditioning happens here).
    pub fn query(
        &self,
        test_grads: &[f32],
        nt: usize,
        topk: usize,
        norm: Normalization,
    ) -> Result<Vec<QueryResult>> {
        let k = self.store.k();
        assert_eq!(test_grads.len(), nt * k);
        let pre = self.precond.apply_rows(test_grads, nt);
        let selfs_guard = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let selfs: Option<&[f32]> = selfs_guard.as_deref();
        let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(topk)).collect();
        let rows = self.store.rows();
        let chunk_len = self.resolved_chunk_len(nt);
        let mut scratch = self.scratch.borrow_mut();
        let mut at = 0usize;
        while at < rows {
            let len = chunk_len.min(rows - at);
            // Overlap: hint the NEXT chunk while we score this one.
            if at + len < rows {
                self.store.prefetch(at + len, chunk_len.min(rows - at - len));
            }
            let scores = self.score_chunk_into(&pre, nt, at, len, &mut scratch)?;
            for t in 0..nt {
                let heap = &mut heaps[t];
                let srow = &scores[t * len..(t + 1) * len];
                for (j, &s) in srow.iter().enumerate() {
                    let s = match selfs {
                        Some(si) => s as f64 / (si[at + j].max(0.0) as f64).sqrt().max(1e-12),
                        None => s as f64,
                    };
                    heap.push(s, self.store.id(at + j));
                }
            }
            at += len;
        }
        Ok(heaps.into_iter().map(|h| QueryResult { top: h.into_sorted() }).collect())
    }

    /// Dense value matrix [nt, n_train] (counterfactual evals need every
    /// score, not just the top-k).
    pub fn values_matrix(
        &self,
        test_grads: &[f32],
        nt: usize,
        norm: Normalization,
    ) -> Result<Matrix> {
        let k = self.store.k();
        assert_eq!(test_grads.len(), nt * k);
        let pre = self.precond.apply_rows(test_grads, nt);
        let selfs_guard = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let selfs: Option<&[f32]> = selfs_guard.as_deref();
        let rows = self.store.rows();
        let mut out = Matrix::zeros(nt, rows);
        let chunk_len = self.resolved_chunk_len(nt);
        let mut scratch = self.scratch.borrow_mut();
        let mut at = 0usize;
        while at < rows {
            let len = chunk_len.min(rows - at);
            // Overlap: hint the NEXT chunk while we score this one (same
            // pipelining as `query` — dense evals scan the whole store too).
            if at + len < rows {
                self.store.prefetch(at + len, chunk_len.min(rows - at - len));
            }
            let scores = self.score_chunk_into(&pre, nt, at, len, &mut scratch)?;
            for t in 0..nt {
                for j in 0..len {
                    // RelatIF division in f64, exactly as `query` does —
                    // the two paths must agree on every (test, train) pair
                    // up to the matrix's f32 storage precision.
                    let mut s = scores[t * len + j] as f64;
                    if let Some(si) = selfs {
                        s /= (si[at + j].max(0.0) as f64).sqrt().max(1e-12);
                    }
                    out.data[t * rows + at + j] = s as f32;
                }
            }
            at += len;
        }
        Ok(out)
    }

    /// Influence of a single (test, train) pair straight from rows —
    /// kernel dot, so it agrees bitwise with the scan's cell for the same
    /// pair.
    pub fn pair_influence(&self, test_row: &[f32], train_idx: usize) -> f32 {
        let pre = self.precond.apply(test_row);
        kernels::dot_f32(&pre, self.store.chunk(train_idx, 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hessian::BlockHessian;
    use crate::store::GradStoreWriter;
    use crate::util::rng::Pcg32;

    #[test]
    fn query_and_values_matrix_agree_on_relatif_scores() {
        // `query` normalizes in f64; `values_matrix` must use the same
        // math (then round once to its f32 storage). Before unification,
        // dividing in f32 could round to a DIFFERENT f32 than the
        // f64-divide-then-cast, so exact equality here is load-bearing.
        let dir = std::env::temp_dir().join("logra-scorer-tests").join("agree");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let k = 6;
        let n = 48;
        let nt = 3;
        let mut rng = Pcg32::seeded(13);
        let mut rows = vec![0.0f32; n * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (0..n as u64).collect(); // id == row index
        let mut w = GradStoreWriter::create(&dir, k).unwrap();
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();
        let store = GradStore::open(&dir).unwrap();
        let mut hess = BlockHessian::single_block(k);
        hess.accumulate(&rows, n);
        let precond = hess.preconditioner(0.1).unwrap();
        let engine = QueryEngine::new_native(&store, &precond, 7);
        let mut test = vec![0.0f32; nt * k];
        rng.fill_normal(&mut test, 1.0);

        for norm in [Normalization::None, Normalization::RelatIf] {
            let q = engine.query(&test, nt, n, norm).unwrap();
            let m = engine.values_matrix(&test, nt, norm).unwrap();
            for (t, res) in q.iter().enumerate() {
                assert_eq!(res.top.len(), n);
                for &(score, id) in &res.top {
                    let got = m.at(t, id as usize);
                    assert_eq!(
                        got, score as f32,
                        "paths disagree (norm {norm:?}, test {t}, train {id})"
                    );
                }
            }
        }
    }
}
