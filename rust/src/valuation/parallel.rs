//! Parallel scan engine over a sharded gradient store.
//!
//! The paper's cost trade (§4.2) answers every influence query by scanning
//! stored projected gradients; this module makes that scan scale past one
//! thread: workers run per-shard scans chunk-wise through the native
//! scoring path (PJRT handles are not `Send`, and chunked dot products are
//! bitwise independent of the chunk split), keep one [`TopK`] heap per
//! (shard, test row), and a deterministic merge stage folds the per-shard
//! heaps into final results.
//!
//! Determinism: scores are per-(test,train)-pair dot products through the
//! shared kernel layer ([`crate::linalg::kernels`]), whose per-cell
//! summation order is independent of chunk boundaries and tile position —
//! so sharding and chunking cannot move a bit; [`TopK`]'s total order on
//! (score, id) makes the kept set a pure function of the candidate
//! multiset. Together these make the parallel result **bit-identical** to
//! the sequential [`QueryEngine`](super::QueryEngine) native scan,
//! whatever the shard decomposition, worker count, or interleaving with
//! concurrent queries (verified by `rust/tests/shards.rs` and
//! `rust/tests/pool.rs`). (The HLO scorer may round differently — the
//! claim is scoped to the native path both engines share.)
//!
//! Execution substrate: the engine shares ownership of the store fabric
//! (`Arc`), so scans can run EITHER on per-query scoped threads
//! (`scatter_gather` — the one-shot CLI shape) or on a long-lived
//! [`ScanPool`](super::ScanPool) attached via
//! [`BackendConfig::pool`](super::BackendConfig) — the serving shape,
//! where concurrent queries interleave their shard tasks on warm workers.
//! Admission goes through the [`ScanBackend`](super::ScanBackend) trait:
//! `submit` returns a [`PendingScores`](super::PendingScores) handle whose
//! `wait` performs the deterministic merge.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::hessian::Preconditioner;
use crate::linalg::kernels::{auto_chunk_len, matmul_t_into};
use crate::linalg::ScanScratch;
use crate::obs::{QueryReport, ScanObs};
use crate::store::ShardedStore;
use crate::util::pipeline::bounded;
use crate::util::topk::TopK;

use super::backend::{
    BackendConfig, BackendKind, GradQuery, PendingScores, QueryRequest, ReportCtx,
    ScanBackend, ValuationError,
};
use super::pool::{auto_workers, ScanHandle, NEVER_POLL};
use super::scorer::{Normalization, QueryResult};

/// Resolve a `chunk_len` knob for an f32 scan: explicit values pass
/// through, 0 derives from the query shape ([`auto_chunk_len`] with
/// `k * 4`-byte train rows).
pub(crate) fn resolve_chunk_len_f32(requested: usize, k: usize, nt: usize) -> usize {
    if requested != 0 {
        requested
    } else {
        auto_chunk_len(k, nt.max(1), k * 4)
    }
}

/// Chunk resolution for the self-influence cache build: rows are read
/// once and staged once through the preconditioner (`~8k` bytes of L2
/// footprint per row), with a single-row "test block".
pub(crate) fn resolve_chunk_len_self_inf(requested: usize, k: usize) -> usize {
    if requested != 0 {
        requested
    } else {
        auto_chunk_len(k, 1, k * 8)
    }
}

/// Parallel influence scorer over a shared-ownership sharded store.
/// Runtime-free: scoring runs on the native matmul path so workers stay
/// `Send`. The engine itself is `Send + Sync` — share it across client
/// threads behind an `Arc` and submit concurrent queries.
pub struct ParallelQueryEngine {
    store: Arc<ShardedStore>,
    precond: Arc<Preconditioner>,
    cfg: BackendConfig,
    /// Self-influence per GLOBAL row (RelatIF denominators), filled in
    /// parallel on first use and cached across queries (and threads).
    self_inf: Mutex<Option<Arc<Vec<f32>>>>,
}

impl ParallelQueryEngine {
    /// Construction takes the whole [`BackendConfig`] — the old
    /// per-engine `with_*` builder stack lives on the
    /// [`Valuator`](super::Valuator) builder now.
    pub fn new(
        store: Arc<ShardedStore>,
        precond: Arc<Preconditioner>,
        cfg: BackendConfig,
    ) -> Self {
        ParallelQueryEngine { store, precond, cfg, self_inf: Mutex::new(None) }
    }

    /// Self-influence of each stored row in global order (computed once in
    /// parallel on scoped threads, then cached; concurrent callers block on
    /// the first computation and share the result).
    pub fn train_self_influences(&self) -> Arc<Vec<f32>> {
        cached_self_influences(
            &self.self_inf,
            &self.store,
            &self.precond,
            resolve_workers(self.cfg.workers, self.store.n_shards()),
            resolve_chunk_len_self_inf(self.cfg.chunk_len, self.store.k()),
        )
    }

    /// Admission body behind [`ScanBackend::submit`]: fan the shard
    /// scan out (pool or per-query spawn) and package the deterministic
    /// merge into the shared completion handle.
    fn submit_grads(&self, q: GradQuery) -> Result<PendingScores, ValuationError> {
        let GradQuery { rows: test_grads, nt, topk, norm } = q;
        let k = self.store.k();
        let scan_obs = self.cfg.metrics.as_ref().map(|m| Arc::new(ScanObs::new(&m.obs)));
        let pre = Arc::new(self.precond.apply_rows(&test_grads, nt));
        let selfs: Option<Arc<Vec<f32>>> = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let chunk_len = resolve_chunk_len_f32(self.cfg.chunk_len, k, nt);
        if let Some(m) = &self.cfg.metrics {
            m.scan_chunk_len.store(chunk_len as u64, std::sync::atomic::Ordering::Relaxed);
        }
        let ctx = match (&self.cfg.metrics, &scan_obs) {
            (Some(m), Some(so)) => Some(ReportCtx::new(
                m.clone(),
                so.clone(),
                BackendKind::Parallel.name(),
                self.store.n_shards() as u32,
                self.store.rows() as u64,
            )),
            _ => None,
        };
        let scan = match &self.cfg.pool {
            Some(pool) => {
                let store = self.store.clone();
                let metrics = self.cfg.metrics.clone();
                let pre = pre.clone();
                let selfs = selfs.clone();
                let scan_obs = scan_obs.clone();
                ScanHandle::Pool(pool.submit_with_scratch(
                    self.store.n_shards(),
                    move |si, scratch| {
                        scan_shard(
                            &store,
                            si,
                            &pre,
                            nt,
                            topk,
                            selfs.as_ref().map(|s| s.as_slice()),
                            chunk_len,
                            metrics.as_deref(),
                            scan_obs.as_deref(),
                            scratch,
                        )
                    },
                )?)
            }
            None => {
                let store = &self.store;
                let metrics = self.cfg.metrics.as_deref();
                let pre_rows: &[f32] = &pre;
                let selfs_ref: Option<&[f32]> = selfs.as_ref().map(|s| s.as_slice());
                let scan_obs_ref = scan_obs.as_deref();
                ScanHandle::Ready(scatter_gather(
                    self.workers(),
                    store.n_shards(),
                    &|si, scratch| {
                        scan_shard(
                            store,
                            si,
                            pre_rows,
                            nt,
                            topk,
                            selfs_ref,
                            chunk_len,
                            metrics,
                            scan_obs_ref,
                            scratch,
                        )
                    },
                ))
            }
        };
        Ok(PendingScores::merge(PendingMerge { scan, nt, topk, ctx }))
    }
}

impl ScanBackend for ParallelQueryEngine {
    fn submit(&self, req: QueryRequest) -> Result<PendingScores, ValuationError> {
        self.submit_grads(req.resolve(self.cfg.norm, self.store.k())?)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Parallel
    }

    fn rows(&self) -> usize {
        self.store.rows()
    }

    fn k(&self) -> usize {
        self.store.k()
    }

    /// Resolved worker count: the pool's actual count when attached, else
    /// the per-query spawn resolution (never more than there are shards).
    fn workers(&self) -> usize {
        match &self.cfg.pool {
            Some(pool) => pool.workers(),
            None => resolve_workers(self.cfg.workers, self.store.n_shards()),
        }
    }

    fn exact(&self) -> bool {
        true
    }

    fn gradient_row(&self, i: usize) -> Option<Vec<f32>> {
        (i < self.store.rows()).then(|| self.store.row(i).to_vec())
    }
}

/// An admitted parallel query: per-shard heaps in flight (or ready), plus
/// the merge parameters. `finish` performs the shard-major deterministic
/// merge — identical to the synchronous path. Callers hold this inside the
/// shared [`PendingScores`] handle.
pub(crate) struct PendingMerge {
    scan: ScanHandle,
    nt: usize,
    topk: usize,
    /// Report finalizer when the backend carries metrics.
    ctx: Option<ReportCtx>,
}

impl PendingMerge {
    /// True when the scan already ran at admission (per-query spawn path):
    /// only the local merge remains, so `finish` cannot block.
    pub(crate) fn is_eager(&self) -> bool {
        matches!(self.scan, ScanHandle::Ready(_))
    }

    pub(crate) fn finish(
        self,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        self.finish_until(&mut || false, NEVER_POLL)
    }

    /// [`finish`](Self::finish) with a cancellation seam: while a pool
    /// scan is in flight, `should_cancel` is re-checked every `poll`
    /// interval; true cancels the query ([`ValuationError::Cancelled`],
    /// unstarted shard tasks skipped). Eager scans merge immediately.
    pub(crate) fn finish_until(
        self,
        should_cancel: &mut dyn FnMut() -> bool,
        poll: std::time::Duration,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        let shard_heaps = self.scan.wait_until(should_cancel, poll)?;
        let scan_done = self.ctx.as_ref().map(|c| c.scan.elapsed_nanos()).unwrap_or(0);
        // Deterministic merge, shard-major: with TopK's total order the
        // merged set equals the sequential scan's set; into_sorted then
        // fixes the output order.
        let mut finals: Vec<TopK> = (0..self.nt).map(|_| TopK::new(self.topk)).collect();
        for heaps in shard_heaps {
            for (t, h) in heaps.into_iter().enumerate() {
                finals[t].merge(h);
            }
        }
        let report = self.ctx.map(|c| {
            let merge_done = c.scan.elapsed_nanos();
            c.complete(scan_done, merge_done, 0)
        });
        Ok((
            finals.into_iter().map(|h| QueryResult { top: h.into_sorted() }).collect(),
            report,
        ))
    }
}

/// Resolve a requested worker count for the PER-QUERY spawn path:
/// [`auto_workers`] (the central `0 = cores, cap 16` rule), additionally
/// clamped by the number of shards there are to scan.
pub(crate) fn resolve_workers(requested: usize, n_shards: usize) -> usize {
    auto_workers(requested).clamp(1, n_shards.max(1))
}

/// Run `job(shard_idx, scratch)` for every shard across `workers` scoped
/// threads and return results in shard order. Each thread owns one
/// [`ScanScratch`] reused across every shard (and chunk) it scans — the
/// per-query-spawn twin of the pool's per-worker scratch. Work
/// distribution goes through a bounded pipeline channel so an uneven
/// shard mix load-balances. This is the one-shot path; long-lived serving
/// goes through [`super::ScanPool`]. Shared with the two-stage quantized engine
/// ([`super::twostage`]), whose stage-1 scan is the same fan-out over
/// quantized shards.
pub(crate) fn scatter_gather<T, F>(workers: usize, n_shards: usize, job: &F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut ScanScratch) -> T + Sync,
{
    let workers = workers.clamp(1, n_shards.max(1));
    let (work_tx, work_rx) = bounded::<usize>(n_shards.max(1));
    let (res_tx, res_rx) = bounded::<(usize, T)>(n_shards.max(1));
    let mut out: Vec<Option<T>> = (0..n_shards).map(|_| None).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let rx = &work_rx;
            let tx = res_tx.clone();
            s.spawn(move || {
                let mut scratch = ScanScratch::new();
                while let Some(si) = rx.recv() {
                    if tx.send((si, job(si, &mut scratch))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(res_tx);
        for si in 0..n_shards {
            // Capacity covers every shard; never blocks.
            work_tx.send(si).expect("scan workers died");
        }
        drop(work_tx);
        while let Some((si, v)) = res_rx.recv() {
            out[si] = Some(v);
        }
    });
    out.into_iter().map(|v| v.expect("shard result missing")).collect()
}

/// Scan one shard: per-test-row TopK heaps over the shard's rows.
/// `pre` is already preconditioned ([nt, k]); `scratch` holds the score
/// buffer between chunks, so the steady-state loop allocates nothing per
/// chunk (kernel writes in place, heap pushes go to pre-sized heaps).
/// With `metrics` attached the task also feeds the shard-scan histogram
/// and records a per-(query, shard) `"scan"` trace span; `scan_obs` lets
/// the first task of a query stamp its queue wait and every task register
/// its worker lane.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_shard(
    store: &ShardedStore,
    si: usize,
    pre: &[f32],
    nt: usize,
    topk: usize,
    selfs: Option<&[f32]>,
    chunk_len: usize,
    metrics: Option<&Metrics>,
    scan_obs: Option<&ScanObs>,
    scratch: &mut ScanScratch,
) -> Vec<TopK> {
    let t0 = Instant::now();
    let obs_start = metrics.map(|m| m.obs.now_nanos());
    if let (Some(m), Some(so)) = (metrics, scan_obs) {
        so.task_started(&m.obs);
    }
    let k = store.k();
    let shard = store.shard(si);
    let base = store.shard_start(si);
    let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(topk)).collect();
    let rows = shard.rows();
    let mut at = 0usize;
    while at < rows {
        let len = chunk_len.min(rows - at);
        if at + len < rows {
            shard.prefetch(at + len, chunk_len.min(rows - at - len));
        }
        let chunk = shard.chunk(at, len);
        let scores = scratch.score_buf(nt * len);
        matmul_t_into(pre, nt, chunk, len, k, scores);
        for (t, heap) in heaps.iter_mut().enumerate() {
            let srow = &scores[t * len..(t + 1) * len];
            for (j, &s) in srow.iter().enumerate() {
                let s = match selfs {
                    Some(si_all) => {
                        s as f64 / (si_all[base + at + j].max(0.0) as f64).sqrt().max(1e-12)
                    }
                    None => s as f64,
                };
                heap.push(s, shard.id(at + j));
            }
        }
        at += len;
    }
    if let Some(m) = metrics {
        m.shards_scanned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dur = t0.elapsed();
        Metrics::add_seconds(&m.shard_scan_nanos, dur.as_secs_f64());
        let dur_nanos = dur.as_nanos() as u64;
        m.obs.shard_scan.record(dur_nanos);
        m.obs.span(
            "scan",
            scan_obs.map(|s| s.query()).unwrap_or(0),
            Some(si as u32),
            obs_start.unwrap_or(0),
            dur_nanos,
        );
    }
    heaps
}

/// Compute-once self-influence cache shared by [`ParallelQueryEngine`]
/// and the two-stage engine: fan the per-shard computation out over
/// scoped threads, flatten in shard order, publish the `Arc`. The lock is
/// held through the computation on purpose — concurrent callers block and
/// then share the one result instead of racing duplicate scans.
pub(crate) fn cached_self_influences(
    cache: &Mutex<Option<Arc<Vec<f32>>>>,
    store: &ShardedStore,
    precond: &Preconditioner,
    workers: usize,
    chunk_len: usize,
) -> Arc<Vec<f32>> {
    let mut guard = cache.lock().unwrap();
    if let Some(cached) = &*guard {
        return cached.clone();
    }
    let per_shard = scatter_gather(workers, store.n_shards(), &|si, scratch| {
        shard_self_influences(store, precond, si, chunk_len, scratch)
    });
    let mut flat = Vec::with_capacity(store.rows());
    for v in per_shard {
        flat.extend(v);
    }
    let arc = Arc::new(flat);
    *guard = Some(arc.clone());
    arc
}

/// Self-influences of one shard's rows, chunk-wise and batched through
/// the kernel layer: each chunk is preconditioned in one
/// `apply_rows_into` pass into scratch and row-dotted by the shared
/// kernel — same fast path (and bitwise the same values) as the
/// per-row [`Preconditioner::self_influence`], without its two
/// allocations per row.
pub(crate) fn shard_self_influences(
    store: &ShardedStore,
    precond: &Preconditioner,
    si: usize,
    chunk_len: usize,
    scratch: &mut ScanScratch,
) -> Vec<f32> {
    let k = store.k();
    let shard = store.shard(si);
    let rows = shard.rows();
    let mut out = Vec::with_capacity(rows);
    let mut at = 0usize;
    while at < rows {
        let len = chunk_len.min(rows - at);
        let chunk = shard.chunk(at, len);
        let applied = scratch.aux_buf(len * k);
        precond.self_influences_into(chunk, len, applied, &mut out);
        at += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hessian::BlockHessian;
    use crate::store::GradStoreWriter;
    use crate::util::rng::Pcg32;
    use std::path::PathBuf;

    fn tmp_store(name: &str, n: usize, k: usize) -> (PathBuf, Vec<f32>) {
        let dir = std::env::temp_dir().join("logra-parallel-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Pcg32::seeded(0xA11C);
        let mut rows = vec![0.0f32; n * k];
        rng.fill_normal(&mut rows, 1.0);
        let ids: Vec<u64> = (0..n as u64).collect();
        let mut w = GradStoreWriter::create(&dir, k).unwrap();
        w.append(&ids, &rows).unwrap();
        w.finalize().unwrap();
        (dir, rows)
    }

    #[test]
    fn steady_state_scan_reuses_scratch() {
        // The zero-alloc contract: after the first chunk warms the score
        // buffer, further chunks — and further whole scans — must not
        // grow it again.
        let k = 16;
        let n = 200;
        let (dir, rows) = tmp_store("zero-alloc", n, k);
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let mut hess = BlockHessian::single_block(k);
        hess.accumulate(&rows, n);
        let precond = hess.preconditioner(0.1).unwrap();
        let nt = 3;
        let mut rng = Pcg32::seeded(7);
        let mut test = vec![0.0f32; nt * k];
        rng.fill_normal(&mut test, 1.0);
        let pre = precond.apply_rows(&test, nt);

        let mut scratch = ScanScratch::new();
        // Multi-chunk scan (chunk_len 32 over 200 rows = 7 chunks).
        let heaps = scan_shard(&store, 0, &pre, nt, 5, None, 32, None, None, &mut scratch);
        assert_eq!(heaps.len(), nt);
        assert_eq!(scratch.grows(), 1, "one warmup growth for the score buffer");
        for _ in 0..3 {
            let again = scan_shard(&store, 0, &pre, nt, 5, None, 32, None, None, &mut scratch);
            assert_eq!(again.len(), nt);
        }
        assert_eq!(scratch.grows(), 1, "steady-state scans must not allocate");
    }

    #[test]
    fn batched_self_influences_match_per_row() {
        let k = 10;
        let n = 77;
        let (dir, rows) = tmp_store("selfinf-batch", n, k);
        let store = Arc::new(ShardedStore::open(&dir).unwrap());
        let mut hess = BlockHessian::single_block(k);
        hess.accumulate(&rows, n);
        let precond = hess.preconditioner(0.1).unwrap();
        let mut scratch = ScanScratch::new();
        // Ragged chunking (13 does not divide 77).
        let got = shard_self_influences(&store, &precond, 0, 13, &mut scratch);
        assert_eq!(got.len(), n);
        for (r, &g) in got.iter().enumerate() {
            let want = precond.self_influence(&rows[r * k..(r + 1) * k]);
            assert_eq!(g.to_bits(), want.to_bits(), "row {r}");
        }
        // And the batch path, like the scan, reuses its scratch.
        let grows = scratch.grows();
        let _ = shard_self_influences(&store, &precond, 0, 13, &mut scratch);
        assert_eq!(scratch.grows(), grows, "second cache build must not allocate");
    }
}
