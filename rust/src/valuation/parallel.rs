//! Parallel scan engine over a sharded gradient store.
//!
//! The paper's cost trade (§4.2) answers every influence query by scanning
//! stored projected gradients; this module makes that scan scale past one
//! thread: N workers pull shard indices off a bounded
//! [`crate::util::pipeline`] channel, scan their shards chunk-wise through
//! the native scoring path (PJRT handles are not `Send`, and chunked dot
//! products are bitwise independent of the chunk split), keep one [`TopK`]
//! heap per (shard, test row), and a deterministic merge stage folds the
//! per-shard heaps into final results.
//!
//! Determinism: scores are per-(test,train)-pair dot products, unaffected
//! by sharding or chunking; [`TopK`]'s total order on (score, id) makes the
//! kept set a pure function of the candidate multiset. Together these make
//! the parallel result **bit-identical** to the sequential
//! [`QueryEngine`](super::QueryEngine) native scan, whatever the shard
//! decomposition or worker count (verified by `rust/tests/shards.rs`).
//! (The HLO scorer may round differently — the claim is scoped to the
//! native path both engines share.)
//!
//! Workers are scoped threads spawned per query: the engine borrows the
//! store, so threads cannot outlive it without `Arc`-ifying the fabric.
//! Per-query spawn costs ~10s of µs per worker — noise once shards hold
//! real row counts; a persistent pool is a follow-up once profiling says
//! it matters.

use std::cell::{Ref, RefCell};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::metrics::Metrics;
use crate::hessian::Preconditioner;
use crate::linalg::matrix::matmul_t_slices;
use crate::store::ShardedStore;
use crate::util::pipeline::bounded;
use crate::util::topk::TopK;

use super::scorer::{Normalization, QueryResult};

/// Knobs for the parallel scan.
#[derive(Clone, Copy, Debug)]
pub struct ParallelScanConfig {
    /// Worker threads; 0 = one per available core (capped at 16).
    pub workers: usize,
    /// Rows scored per chunk within a shard.
    pub chunk_len: usize,
}

impl Default for ParallelScanConfig {
    fn default() -> Self {
        ParallelScanConfig { workers: 0, chunk_len: 1024 }
    }
}

/// Parallel influence scorer over a sharded store. Runtime-free: scoring
/// runs on the native matmul path so workers stay `Send`.
pub struct ParallelQueryEngine<'a> {
    store: &'a ShardedStore,
    precond: &'a Preconditioner,
    cfg: ParallelScanConfig,
    metrics: Option<Arc<Metrics>>,
    /// Self-influence per GLOBAL row (RelatIF denominators), filled in
    /// parallel on first use and cached across queries.
    self_inf: RefCell<Option<Vec<f32>>>,
}

impl<'a> ParallelQueryEngine<'a> {
    pub fn new(store: &'a ShardedStore, precond: &'a Preconditioner) -> Self {
        ParallelQueryEngine {
            store,
            precond,
            cfg: ParallelScanConfig::default(),
            metrics: None,
            self_inf: RefCell::new(None),
        }
    }

    /// Set worker count (0 = auto).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.cfg.workers = workers;
        self
    }

    pub fn with_chunk_len(mut self, chunk_len: usize) -> Self {
        self.cfg.chunk_len = chunk_len.max(1);
        self
    }

    /// Record per-shard scan counters into shared service metrics.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Resolved worker count: explicit, else one per core, never more than
    /// there are shards to scan.
    pub fn workers(&self) -> usize {
        resolve_workers(self.cfg.workers, self.store.n_shards())
    }

    /// Full scan: top-k most valuable train examples per test row, merged
    /// across shards. Same contract as the sequential
    /// [`QueryEngine::query`](super::QueryEngine::query) (`test_grads`
    /// row-major [nt, k], raw — preconditioning happens here), same
    /// results.
    pub fn query(
        &self,
        test_grads: &[f32],
        nt: usize,
        topk: usize,
        norm: Normalization,
    ) -> Result<Vec<QueryResult>> {
        let k = self.store.k();
        ensure!(
            test_grads.len() == nt * k,
            "query: {nt} rows x k={k} needs {} floats, got {}",
            nt * k,
            test_grads.len()
        );
        let pre = self.precond.apply_rows(test_grads, nt);
        let selfs_guard = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let selfs: Option<&[f32]> = selfs_guard.as_deref();

        // Workers capture only Sync borrows (store, precond, slices) — the
        // engine itself holds a RefCell cache and must stay on this thread.
        let store = self.store;
        let chunk_len = self.cfg.chunk_len.max(1);
        let metrics = self.metrics.as_deref();
        let pre_rows: &[f32] = &pre;
        let shard_heaps = scatter_gather(self.workers(), store.n_shards(), &|si| {
            scan_shard(store, si, pre_rows, nt, topk, selfs, chunk_len, metrics)
        });

        // Deterministic merge, shard-major: with TopK's total order the
        // merged set equals the sequential scan's set; into_sorted then
        // fixes the output order.
        let mut finals: Vec<TopK> = (0..nt).map(|_| TopK::new(topk)).collect();
        for heaps in shard_heaps {
            for (t, h) in heaps.into_iter().enumerate() {
                finals[t].merge(h);
            }
        }
        Ok(finals.into_iter().map(|h| QueryResult { top: h.into_sorted() }).collect())
    }

    /// Self-influence of each stored row in global order (computed once in
    /// parallel, then cached).
    pub fn train_self_influences(&self) -> Ref<'_, [f32]> {
        if self.self_inf.borrow().is_none() {
            let store = self.store;
            let precond = self.precond;
            let chunk_len = self.cfg.chunk_len.max(1);
            let per_shard = scatter_gather(self.workers(), store.n_shards(), &|si| {
                shard_self_influences(store, precond, si, chunk_len)
            });
            let mut flat = Vec::with_capacity(store.rows());
            for v in per_shard {
                flat.extend(v);
            }
            *self.self_inf.borrow_mut() = Some(flat);
        }
        Ref::map(self.self_inf.borrow(), |o| o.as_deref().unwrap())
    }
}

/// Resolve a requested worker count (0 = one per core, capped at 16)
/// against the number of shards there are to scan.
pub(crate) fn resolve_workers(requested: usize, n_shards: usize) -> usize {
    let raw = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
    } else {
        requested
    };
    raw.clamp(1, n_shards.max(1))
}

/// Run `job(shard_idx)` for every shard across `workers` threads and
/// return results in shard order. Work distribution goes through a bounded
/// pipeline channel so an uneven shard mix load-balances. Shared with the
/// two-stage quantized engine ([`super::twostage`]), whose stage-1 scan is
/// the same fan-out over quantized shards.
pub(crate) fn scatter_gather<T, F>(workers: usize, n_shards: usize, job: &F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n_shards.max(1));
    let (work_tx, work_rx) = bounded::<usize>(n_shards.max(1));
    let (res_tx, res_rx) = bounded::<(usize, T)>(n_shards.max(1));
    let mut out: Vec<Option<T>> = (0..n_shards).map(|_| None).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let rx = &work_rx;
            let tx = res_tx.clone();
            s.spawn(move || {
                while let Some(si) = rx.recv() {
                    if tx.send((si, job(si))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(res_tx);
        for si in 0..n_shards {
            // Capacity covers every shard; never blocks.
            work_tx.send(si).expect("scan workers died");
        }
        drop(work_tx);
        while let Some((si, v)) = res_rx.recv() {
            out[si] = Some(v);
        }
    });
    out.into_iter().map(|v| v.expect("shard result missing")).collect()
}

/// Scan one shard: per-test-row TopK heaps over the shard's rows.
/// `pre` is already preconditioned ([nt, k]).
#[allow(clippy::too_many_arguments)]
fn scan_shard(
    store: &ShardedStore,
    si: usize,
    pre: &[f32],
    nt: usize,
    topk: usize,
    selfs: Option<&[f32]>,
    chunk_len: usize,
    metrics: Option<&Metrics>,
) -> Vec<TopK> {
    let t0 = Instant::now();
    let k = store.k();
    let shard = store.shard(si);
    let base = store.shard_start(si);
    let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(topk)).collect();
    let rows = shard.rows();
    let mut at = 0usize;
    while at < rows {
        let len = chunk_len.min(rows - at);
        if at + len < rows {
            shard.prefetch(at + len, chunk_len.min(rows - at - len));
        }
        let chunk = shard.chunk(at, len);
        let scores = matmul_t_slices(pre, nt, chunk, len, k);
        for (t, heap) in heaps.iter_mut().enumerate() {
            let srow = &scores[t * len..(t + 1) * len];
            for (j, &s) in srow.iter().enumerate() {
                let s = match selfs {
                    Some(si_all) => {
                        s as f64 / (si_all[base + at + j].max(0.0) as f64).sqrt().max(1e-12)
                    }
                    None => s as f64,
                };
                heap.push(s, shard.id(at + j));
            }
        }
        at += len;
    }
    if let Some(m) = metrics {
        m.shards_scanned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Metrics::add_nanos(&m.shard_scan_nanos, t0.elapsed().as_secs_f64());
    }
    heaps
}

/// Self-influences of one shard's rows, chunk-wise.
pub(crate) fn shard_self_influences(
    store: &ShardedStore,
    precond: &Preconditioner,
    si: usize,
    chunk_len: usize,
) -> Vec<f32> {
    let k = store.k();
    let shard = store.shard(si);
    let rows = shard.rows();
    let mut out = Vec::with_capacity(rows);
    let mut at = 0usize;
    while at < rows {
        let len = chunk_len.min(rows - at);
        let chunk = shard.chunk(at, len);
        for r in 0..len {
            out.push(precond.self_influence(&chunk[r * k..(r + 1) * k]));
        }
        at += len;
    }
    out
}
