//! IVF-probed sublinear query engine over an indexed quantized store.
//!
//! Stage 0 probes each shard's IVF index ([`IvfIndex`], built by
//! `logra store index`): the preconditioned test rows rank the shard's
//! k-means centroids by inner product and the union of each row's top
//! `nprobe` clusters names the candidate rows. Stage 1 then runs the SAME
//! int8 block-dot coarse scan as [`TwoStageEngine`](super::TwoStageEngine)
//! — but only over the probed rows, coalesced into contiguous runs —
//! and stage 2 is the two-stage engine's exact f32 rescore, shared
//! verbatim through its pending-rescore handle. The linear int8 pass
//! becomes sublinear in corpus size; the rescore was already sublinear.
//!
//! Determinism and the bit-identity anchor: the probed row set is a pure
//! function of (index bytes, test rows, `nprobe`), the scan kernel scores
//! each row independently of its chunk neighbors, and [`TopK`]'s total
//! order is push-order independent — so with `nprobe >=` every shard's
//! cluster count the probe names every row and the output is
//! **bit-identical** to the two-stage engine (`rust/tests/ann.rs`,
//! `rust/tests/backend.rs`). Smaller probes trade recall for scanned
//! rows; the probed-rows counter (`logra_rows_probed_total`) makes the
//! saving observable.
//!
//! Index health is per shard: a shard whose index files are missing,
//! truncated, or stale opens as a fallback ([`IvfIndex::shard`] returns
//! `None`) and is scanned in full — degraded latency, never degraded
//! correctness.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::Metrics;
use crate::hessian::Preconditioner;
use crate::linalg::kernels::{auto_chunk_len, scan_q8_into};
use crate::linalg::ScanScratch;
use crate::obs::ScanObs;
use crate::store::quant::{blocks_of, quantize_rows, QuantShardedStore};
use crate::store::{IvfIndex, ShardedStore};
use crate::util::topk::TopK;

use super::backend::{
    BackendChoice, BackendConfig, BackendKind, GradQuery, PendingScores, QueryRequest,
    ReportCtx, ScanBackend, ValuationError,
};
use super::parallel::{
    cached_self_influences, resolve_chunk_len_self_inf, resolve_workers, scatter_gather,
};
use super::pool::ScanHandle;
use super::scorer::Normalization;
use super::twostage::PendingRescore;

/// IVF-probed influence scorer: stage-0 centroid probe, stage-1 int8
/// coarse scan of the probed rows, stage-2 exact rescore. `Send + Sync` —
/// share behind an `Arc` and query concurrently.
pub struct IvfEngine {
    quant: Arc<QuantShardedStore>,
    index: Arc<IvfIndex>,
    exact: Arc<ShardedStore>,
    precond: Arc<Preconditioner>,
    cfg: BackendConfig,
    /// Self-influence per GLOBAL row (RelatIF denominators), computed from
    /// the EXACT store — all stages divide by the same denominators.
    self_inf: Mutex<Option<Arc<Vec<f32>>>>,
}

impl IvfEngine {
    /// The index must have been built over THIS quantized store (same
    /// shard decomposition; stale shards degrade to full scans), and the
    /// quantized copy must mirror the exact store row-for-row. Rejects a
    /// mismatched pairing — and a zero `rescore_factor` or `nprobe` —
    /// with a typed [`ValuationError`] at construction.
    pub fn new(
        quant: Arc<QuantShardedStore>,
        index: Arc<IvfIndex>,
        exact: Arc<ShardedStore>,
        precond: Arc<Preconditioner>,
        cfg: BackendConfig,
    ) -> Result<Self, ValuationError> {
        if quant.k() != exact.k() {
            return Err(ValuationError::InvalidConfig(format!(
                "quantized store k={} disagrees with exact store k={}",
                quant.k(),
                exact.k()
            )));
        }
        if quant.rows() != exact.rows() {
            return Err(ValuationError::InvalidConfig(format!(
                "quantized store has {} rows, exact store {} — stale quantized copy?",
                quant.rows(),
                exact.rows()
            )));
        }
        if index.n_shards() != quant.n_shards() {
            return Err(ValuationError::InvalidConfig(format!(
                "IVF index covers {} shards, quantized store has {} — stale index?",
                index.n_shards(),
                quant.n_shards()
            )));
        }
        if cfg.rescore_factor == 0 {
            return Err(ValuationError::InvalidConfig(
                "rescore_factor must be ≥ 1 (stage-1 candidate pool multiplier)".into(),
            ));
        }
        if cfg.nprobe == 0 {
            return Err(ValuationError::InvalidConfig(
                "nprobe must be ≥ 1 (clusters probed per shard)".into(),
            ));
        }
        Ok(IvfEngine { quant, index, exact, precond, cfg, self_inf: Mutex::new(None) })
    }

    /// Stage-1 candidate pool size for a requested top-k.
    pub fn pool_size(&self, topk: usize) -> usize {
        self.cfg
            .rescore_factor
            .max(1)
            .saturating_mul(topk.max(1))
            .min(self.exact.rows().max(1))
    }

    /// Shards currently degraded to a full coarse scan (damaged or stale
    /// index files).
    pub fn fallback_shards(&self) -> usize {
        self.index.fallback_shards()
    }

    /// Self-influence of each stored row in global order, from the exact
    /// store (computed once in parallel, then cached).
    pub fn train_self_influences(&self) -> Arc<Vec<f32>> {
        cached_self_influences(
            &self.self_inf,
            &self.exact,
            &self.precond,
            resolve_workers(self.cfg.workers, self.exact.n_shards()),
            resolve_chunk_len_self_inf(self.cfg.chunk_len, self.exact.k()),
        )
    }

    /// Admission body behind [`ScanBackend::submit`]: probe the index,
    /// run (or enqueue) the stage-1 coarse scan over the probed rows; the
    /// returned handle's `wait` merges candidate pools and performs the
    /// exact rescore on the calling thread (shared with the two-stage
    /// engine via [`PendingRescore`]).
    fn submit_grads(&self, q: GradQuery, nprobe: usize) -> Result<PendingScores, ValuationError> {
        let GradQuery { rows: test_grads, nt, topk, norm } = q;
        let k = self.exact.k();
        let scan_obs = self.cfg.metrics.as_ref().map(|m| Arc::new(ScanObs::new(&m.obs)));
        let pre = self.precond.apply_rows(&test_grads, nt);
        let selfs: Option<Arc<Vec<f32>>> = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let pool_size = self.pool_size(topk);

        // ------------------------------------------------- stage 0: probe
        // Rank centroids per shard and name the candidate rows. Runs at
        // admission, on the admitting thread — it is tiny (clusters × k
        // per shard) next to the scan it prunes.
        let probe_start = self.cfg.metrics.as_ref().map(|m| m.obs.now_nanos());
        let tp = Instant::now();
        let probed: Vec<Vec<u32>> = (0..self.quant.n_shards())
            .map(|si| match self.index.shard(si) {
                Some(sh) => sh.probe(&pre, nt, nprobe),
                // Fallback shard: scan it in full.
                None => (0..self.quant.shard(si).rows() as u32).collect(),
            })
            .collect();
        let probed_rows: u64 = probed.iter().map(|p| p.len() as u64).sum();
        if let Some(m) = &self.cfg.metrics {
            m.rows_probed.fetch_add(probed_rows, std::sync::atomic::Ordering::Relaxed);
            if let Some(so) = &scan_obs {
                m.obs.span(
                    "probe",
                    so.query(),
                    None,
                    probe_start.unwrap_or(0),
                    tp.elapsed().as_nanos() as u64,
                );
            }
        }

        // The report's `rows_scanned` is the PROBED row count — the whole
        // point of the index is that it is below the corpus row count.
        let ctx = match (&self.cfg.metrics, &scan_obs) {
            (Some(m), Some(so)) => Some(ReportCtx::new(
                m.clone(),
                so.clone(),
                BackendKind::Ivf.name(),
                self.quant.n_shards() as u32,
                probed_rows,
            )),
            _ => None,
        };
        let t0 = Instant::now();

        // ------------------------------------------------ stage 1: coarse
        let scan = if self.exact.rows() == 0 {
            ScanHandle::Ready(Vec::new())
        } else {
            let (t_codes, t_scales) = quantize_rows(&pre, nt, k);
            let q8_row_bytes = k + blocks_of(k) * 4;
            let chunk_len = if self.cfg.chunk_len != 0 {
                self.cfg.chunk_len
            } else {
                auto_chunk_len(k, nt, q8_row_bytes)
            };
            if let Some(m) = &self.cfg.metrics {
                m.scan_chunk_len.store(chunk_len as u64, std::sync::atomic::Ordering::Relaxed);
            }
            match &self.cfg.pool {
                Some(pool) => {
                    let quant = self.quant.clone();
                    let metrics = self.cfg.metrics.clone();
                    let selfs = selfs.clone();
                    let scan_obs = scan_obs.clone();
                    let t_codes = Arc::new(t_codes);
                    let t_scales = Arc::new(t_scales);
                    let probed = Arc::new(probed);
                    ScanHandle::Pool(pool.submit_with_scratch(
                        self.quant.n_shards(),
                        move |si, scratch| {
                            scan_shard_q8_probed(
                                &quant,
                                si,
                                &probed[si],
                                &t_codes,
                                &t_scales,
                                nt,
                                pool_size,
                                selfs.as_ref().map(|s| s.as_slice()),
                                chunk_len,
                                metrics.as_deref(),
                                scan_obs.as_deref(),
                                scratch,
                            )
                        },
                    )?)
                }
                None => {
                    let quant = &self.quant;
                    let met = self.cfg.metrics.as_deref();
                    let so_ref = scan_obs.as_deref();
                    let tc: &[i8] = &t_codes;
                    let ts: &[f32] = &t_scales;
                    let selfs_ref: Option<&[f32]> = selfs.as_ref().map(|s| s.as_slice());
                    let probed_ref: &[Vec<u32>] = &probed;
                    ScanHandle::Ready(scatter_gather(
                        self.workers(),
                        quant.n_shards(),
                        &|si, scratch| {
                            scan_shard_q8_probed(
                                quant,
                                si,
                                &probed_ref[si],
                                tc,
                                ts,
                                nt,
                                pool_size,
                                selfs_ref,
                                chunk_len,
                                met,
                                so_ref,
                                scratch,
                            )
                        },
                    ))
                }
            }
        };
        Ok(PendingScores::rescore(PendingRescore::new(
            scan,
            pre,
            selfs,
            self.exact.clone(),
            self.cfg.metrics.clone(),
            nt,
            topk,
            pool_size,
            t0,
            ctx,
        )))
    }
}

impl ScanBackend for IvfEngine {
    fn submit(&self, req: QueryRequest) -> Result<PendingScores, ValuationError> {
        // Per-request probe width: the one IVF knob a request may carry.
        let nprobe = match req.backend {
            Some(BackendChoice::Ann { nprobe: Some(n) }) => {
                if n == 0 {
                    return Err(ValuationError::InvalidConfig(
                        "nprobe must be ≥ 1 (clusters probed per shard)".into(),
                    ));
                }
                n
            }
            _ => self.cfg.nprobe,
        };
        self.submit_grads(req.resolve(self.cfg.norm, self.exact.k())?, nprobe)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Ivf
    }

    fn rows(&self) -> usize {
        self.exact.rows()
    }

    fn k(&self) -> usize {
        self.exact.k()
    }

    /// Resolved stage-1 worker count (the pool's when attached).
    fn workers(&self) -> usize {
        match &self.cfg.pool {
            Some(pool) => pool.workers(),
            None => resolve_workers(self.cfg.workers, self.quant.n_shards()),
        }
    }

    /// Approximate twice over: the probe bounds recall before the rescore
    /// pool does.
    fn exact(&self) -> bool {
        false
    }

    fn gradient_row(&self, i: usize) -> Option<Vec<f32>> {
        (i < self.exact.rows()).then(|| self.exact.row(i).to_vec())
    }
}

/// Stage-1 scan of one shard's PROBED rows (local indices, sorted
/// ascending): contiguous index runs are coalesced into single kernel
/// calls capped at `chunk_len`, so a full probe degenerates into exactly
/// the two-stage engine's chunk walk. Pools hold (approximate score,
/// GLOBAL row index) like the full scan's.
#[allow(clippy::too_many_arguments)]
fn scan_shard_q8_probed(
    quant: &QuantShardedStore,
    si: usize,
    probed: &[u32],
    t_codes: &[i8],
    t_scales: &[f32],
    nt: usize,
    pool: usize,
    selfs: Option<&[f32]>,
    chunk_len: usize,
    metrics: Option<&Metrics>,
    scan_obs: Option<&ScanObs>,
    scratch: &mut ScanScratch,
) -> Vec<TopK> {
    let obs_start = metrics.map(|m| m.obs.now_nanos());
    if let (Some(m), Some(so)) = (metrics, scan_obs) {
        so.task_started(&m.obs);
    }
    let t0 = Instant::now();
    let k = quant.k();
    let shard = quant.shard(si);
    let base = quant.shard_start(si);
    let mut heaps: Vec<TopK> = (0..nt).map(|_| TopK::new(pool)).collect();
    let mut i = 0usize;
    while i < probed.len() {
        // Coalesce a contiguous ascending run, capped at the chunk size.
        let at = probed[i] as usize;
        let mut len = 1usize;
        while i + len < probed.len()
            && len < chunk_len
            && probed[i + len] as usize == at + len
        {
            len += 1;
        }
        let scores = scratch.score_buf(nt * len);
        scan_q8_into(
            t_codes,
            t_scales,
            nt,
            shard.codes_chunk(at, len),
            shard.scales_chunk(at, len),
            len,
            k,
            scores,
        );
        for (t, heap) in heaps.iter_mut().enumerate() {
            let srow = &scores[t * len..(t + 1) * len];
            for (j, &s) in srow.iter().enumerate() {
                let g = base + at + j;
                // Same RelatIF denominators as stage 2, so the pool chases
                // the ranking the rescore will finalize.
                let s = match selfs {
                    Some(si_all) => {
                        s as f64 / (si_all[g].max(0.0) as f64).sqrt().max(1e-12)
                    }
                    None => s as f64,
                };
                heap.push(s, g as u64);
            }
        }
        i += len;
    }
    if let Some(m) = metrics {
        m.shards_scanned.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dur = t0.elapsed();
        Metrics::add_seconds(&m.shard_scan_nanos, dur.as_secs_f64());
        let dur_nanos = dur.as_nanos() as u64;
        m.obs.shard_scan.record(dur_nanos);
        m.obs.span(
            "scan",
            scan_obs.map(|s| s.query()).unwrap_or(0),
            Some(si as u32),
            obs_start.unwrap_or(0),
            dur_nanos,
        );
    }
    heaps
}
