//! Persistent scan pool: the serving substrate for the paper's
//! "write projected gradients once, scan forever" cost trade (§4.2).
//!
//! The per-query scatter/gather path (scoped threads spawned per query in
//! [`super::parallel`]) is the right shape for one-shot CLI runs; a service
//! facing concurrent queries wants warm workers and interleaved admission.
//! This module provides both:
//!
//! - **N persistent workers** pull `(query, shard)` scan tasks off a
//!   bounded [`crate::util::pipeline`] channel and run them to completion,
//!   amortizing thread spawn across the service's lifetime.
//! - A **dispatcher** round-robins shard tasks across every in-flight
//!   query when feeding the (small, bounded) task queue, so a large query
//!   cannot head-of-line-block a small one: their shard tasks interleave.
//! - A per-query **completion tracker** stores each shard's result in a
//!   slot table indexed by shard; the submitter merges slots in shard
//!   order. Because [`crate::util::topk::TopK`]'s total order makes the
//!   kept set independent of push order, the merged result is
//!   **bit-identical** to the sequential scan for ANY interleaving of
//!   concurrent queries, worker count, or completion order (verified by
//!   `rust/tests/pool.rs`).
//! - **Panic isolation**: a poisoned scan task fails only its own query
//!   (the submitter gets an error; remaining tasks of that query are
//!   skipped fast) — the worker survives and the pool keeps serving.
//! - **Graceful shutdown**: [`ScanPool::shutdown`] stops admission, drains
//!   every task already submitted, and joins the threads; pending queries
//!   still complete.
//! - **Per-worker scratch**: each persistent worker owns one
//!   [`ScanScratch`] for its lifetime and hands it to every scan task it
//!   runs ([`ScanPool::submit_with_scratch`]), so the kernels' `_into`
//!   score buffers are reused across chunks, shards, and queries — the
//!   steady-state scan allocates nothing per chunk.
//!   [`PoolSnapshot::scratch_grows`] exposes the per-worker growth
//!   counters (they saturate after warmup; the zero-alloc observable).
//!
//! The pool is also the single authority for resolving
//! `BackendConfig::workers == 0` ([`auto_workers`]), so service
//! metrics can report the worker count actually spawned.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::linalg::ScanScratch;
use crate::util::pipeline::{bounded, Receiver, Sender};
use crate::util::topk::TopK;

use super::backend::ValuationError;

/// Resolve a requested worker count: 0 = one per available core, capped at
/// 16. THE single resolution point for `workers = 0` — the per-query
/// spawn path (`parallel::resolve_workers`) additionally clamps to the
/// shard count; the pool deliberately does not, because concurrent queries
/// keep workers busy beyond one query's shards.
pub fn auto_workers(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
    } else {
        requested
    }
}

/// Poll interval for non-cancellable waits expressed through the
/// cancellable seam (`should_cancel` is constantly false there, so the
/// interval only bounds how often the loop wakes for nothing).
pub(crate) const NEVER_POLL: Duration = Duration::from_secs(3600);

/// One scan job's shard closure: (shard index, the running worker's
/// reusable scratch) -> per-test-row heaps.
type ScanFn = Box<dyn Fn(usize, &mut ScanScratch) -> Vec<TopK> + Send + Sync>;

/// Per-shard results of one query, in shard order.
type ShardHeaps = Vec<Vec<TopK>>;

/// One in-flight query: its scan closure plus the completion tracker.
struct JobInner {
    scan: ScanFn,
    n_shards: usize,
    /// Slot table indexed by shard — completion order cannot perturb the
    /// merge order, which is what keeps concurrent admission deterministic.
    slots: Mutex<Vec<Option<Vec<TopK>>>>,
    /// Tasks not yet finished; the worker that takes this to zero merges.
    remaining: AtomicUsize,
    /// First panic message, if any task of this query panicked.
    failed: Mutex<Option<String>>,
    /// Set (by [`PendingScan`]'s drop or an explicit cancel) when nobody
    /// is waiting for this query anymore: workers fast-skip its unstarted
    /// shard tasks instead of scanning an abandoned query to completion.
    cancelled: Arc<AtomicBool>,
    done: Sender<Result<ShardHeaps, ValuationError>>,
    query_id: u64,
    metrics: Arc<PoolMetrics>,
}

type Task = (Arc<JobInner>, usize);

/// Handle to one submitted query's eventual result. Dropping the handle
/// without waiting **cancels** the query: workers skip its unstarted
/// shard tasks (counted as [`PoolSnapshot::tasks_cancelled`]) instead of
/// scanning an abandoned query to completion — the serve path's
/// client-disconnect semantics.
pub struct PendingScan {
    rx: Receiver<Result<ShardHeaps, ValuationError>>,
    cancelled: Arc<AtomicBool>,
    query_id: u64,
}

impl PendingScan {
    pub fn query_id(&self) -> u64 {
        self.query_id
    }

    /// Block until every shard task of this query has run; returns the
    /// per-shard heaps in shard order. A panicking shard task surfaces as
    /// [`ValuationError::QueryPoisoned`] — distinguishable from a pool
    /// shutdown, and scoped to this query alone.
    pub fn wait(self) -> Result<ShardHeaps, ValuationError> {
        match self.rx.recv() {
            Some(res) => res,
            None => Err(ValuationError::Internal(format!(
                "scan pool dropped query {} before completion",
                self.query_id
            ))),
        }
    }

    /// Like [`wait`](Self::wait), but re-checks `should_cancel` every
    /// `poll` interval while the scan is in flight. When it reports true,
    /// the query is cancelled (unstarted shard tasks will be skipped) and
    /// [`ValuationError::Cancelled`] is returned — the serve path's
    /// deadline/disconnect seam.
    pub fn wait_until(
        self,
        should_cancel: &mut dyn FnMut() -> bool,
        poll: Duration,
    ) -> Result<ShardHeaps, ValuationError> {
        loop {
            if let Some(res) = self.rx.recv_deadline(Instant::now() + poll) {
                return res;
            }
            if self.rx.is_disconnected() {
                return Err(ValuationError::Internal(format!(
                    "scan pool dropped query {} before completion",
                    self.query_id
                )));
            }
            if should_cancel() {
                self.cancelled.store(true, Ordering::Release);
                return Err(ValuationError::Cancelled { query_id: self.query_id });
            }
        }
    }
}

impl Drop for PendingScan {
    fn drop(&mut self) {
        // Nobody can receive this query's result anymore — let workers
        // skip whatever of it hasn't started. Harmless after a successful
        // wait (every task is already accounted for by then).
        self.cancelled.store(true, Ordering::Release);
    }
}

/// A scan that is either already computed (per-query spawn path) or in
/// flight on a [`ScanPool`]. Lets the engines expose one async surface
/// whether or not a pool is attached.
pub enum ScanHandle {
    Ready(ShardHeaps),
    Pool(PendingScan),
}

impl ScanHandle {
    pub fn wait(self) -> Result<ShardHeaps, ValuationError> {
        match self {
            ScanHandle::Ready(heaps) => Ok(heaps),
            ScanHandle::Pool(pending) => pending.wait(),
        }
    }

    /// Cancellable wait: already-computed scans return immediately;
    /// pooled scans poll `should_cancel` via [`PendingScan::wait_until`].
    pub fn wait_until(
        self,
        should_cancel: &mut dyn FnMut() -> bool,
        poll: Duration,
    ) -> Result<ShardHeaps, ValuationError> {
        match self {
            ScanHandle::Ready(heaps) => Ok(heaps),
            ScanHandle::Pool(pending) => pending.wait_until(should_cancel, poll),
        }
    }
}

/// Shared atomic counters (lock-free reads for snapshots).
#[derive(Default)]
struct PoolMetrics {
    in_flight: AtomicU64,
    queries_submitted: AtomicU64,
    tasks_completed: AtomicU64,
    tasks_failed: AtomicU64,
    tasks_skipped: AtomicU64,
    tasks_cancelled: AtomicU64,
}

/// Point-in-time view of pool health (the serving dashboard's scan row).
#[derive(Clone, Debug)]
pub struct PoolSnapshot {
    /// Workers actually spawned (after [`auto_workers`] resolution).
    pub workers: usize,
    /// Scan tasks sitting in the bounded queue right now.
    pub queue_depth: usize,
    /// Queries submitted but not yet completed.
    pub in_flight: u64,
    pub queries_submitted: u64,
    /// Tasks pulled and run to completion.
    pub tasks_completed: u64,
    /// Tasks that panicked (each fails exactly one query).
    pub tasks_failed: u64,
    /// Tasks fast-skipped because their query had already failed.
    pub tasks_skipped: u64,
    /// Tasks fast-skipped because their query was cancelled (the waiter
    /// dropped its [`PendingScan`] — client disconnect, deadline expiry).
    pub tasks_cancelled: u64,
    /// Per-worker busy seconds (time inside scan closures).
    pub busy_seconds: Vec<f64>,
    /// Per-worker scratch-buffer growth events. Saturates after the first
    /// few tasks (one growth per distinct buffer at its high-water size)
    /// and then stays flat — steady-state scans allocate nothing per
    /// chunk (`rust/tests/kernels.rs` pins this).
    pub scratch_grows: Vec<u64>,
    /// Per-worker trace lane ([`crate::obs::thread_lane`]) — matches the
    /// `tid` of that worker's spans in exported Chrome traces, so a trace
    /// row can be tied back to a pool worker. `u32::MAX` until the worker
    /// has run its first task.
    pub worker_lanes: Vec<u32>,
}

impl PoolSnapshot {
    /// Summed busy time across workers; divide by wall time for effective
    /// scan concurrency.
    pub fn total_busy_seconds(&self) -> f64 {
        self.busy_seconds.iter().sum()
    }
}

/// Long-lived scan worker pool. Spawn once per service, share via `Arc`,
/// submit concurrent queries from any thread.
pub struct ScanPool {
    job_tx: Mutex<Option<Sender<Arc<JobInner>>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    task_rx: Arc<Receiver<Task>>,
    metrics: Arc<PoolMetrics>,
    busy: Arc<Vec<AtomicU64>>,
    scratch_grows: Arc<Vec<AtomicU64>>,
    lanes: Arc<Vec<AtomicU32>>,
    n_workers: usize,
    next_query: AtomicU64,
}

impl ScanPool {
    /// Spawn `workers` persistent scan threads (0 = [`auto_workers`])
    /// plus one dispatcher. The task queue is bounded at ~2 tasks per
    /// worker: small enough that a newly admitted query starts
    /// interleaving within a couple of task grants.
    pub fn spawn(workers: usize) -> Self {
        let n_workers = auto_workers(workers).max(1);
        let metrics = Arc::new(PoolMetrics::default());
        let busy: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_workers).map(|_| AtomicU64::new(0)).collect());
        let scratch_grows: Arc<Vec<AtomicU64>> =
            Arc::new((0..n_workers).map(|_| AtomicU64::new(0)).collect());
        let lanes: Arc<Vec<AtomicU32>> =
            Arc::new((0..n_workers).map(|_| AtomicU32::new(u32::MAX)).collect());
        let (job_tx, job_rx) = bounded::<Arc<JobInner>>(64);
        let (task_tx, task_rx) = bounded::<Task>((n_workers * 2).max(4));
        let task_rx = Arc::new(task_rx);
        let mut handles = Vec::with_capacity(n_workers + 1);
        handles.push(
            std::thread::Builder::new()
                .name("scan-pool-dispatch".into())
                .spawn(move || dispatch(job_rx, task_tx))
                .expect("spawn scan pool dispatcher"),
        );
        for w in 0..n_workers {
            let rx = task_rx.clone();
            let busy = busy.clone();
            let grows = scratch_grows.clone();
            let lanes = lanes.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("scan-pool-{w}"))
                    .spawn(move || {
                        // Publish this worker's trace lane so snapshots can
                        // map Chrome-trace tids back to pool workers.
                        lanes[w].store(crate::obs::thread_lane(), Ordering::Relaxed);
                        // Worker-lifetime scratch: the kernels' score
                        // buffers warm up once and are reused by every
                        // task this worker ever runs.
                        let mut scratch = ScanScratch::new();
                        while let Some((job, si)) = rx.recv() {
                            run_task(&job, si, &busy[w], &mut scratch);
                            grows[w].store(scratch.grows(), Ordering::Relaxed);
                        }
                    })
                    .expect("spawn scan pool worker"),
            );
        }
        ScanPool {
            job_tx: Mutex::new(Some(job_tx)),
            handles: Mutex::new(handles),
            task_rx,
            metrics,
            busy,
            scratch_grows,
            lanes,
            n_workers,
            next_query: AtomicU64::new(0),
        }
    }

    /// Workers actually running — what service metrics should report.
    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Admit one query: `scan(shard_idx)` will be called once per shard in
    /// `0..n_shards`, possibly concurrently and interleaved with other
    /// queries' tasks. Returns immediately; [`PendingScan::wait`] blocks
    /// for the per-shard heaps (shard order). Scratch-oblivious
    /// convenience over [`submit_with_scratch`](Self::submit_with_scratch)
    /// (which the scan engines use to reach the zero-alloc kernels).
    pub fn submit<F>(&self, n_shards: usize, scan: F) -> Result<PendingScan, ValuationError>
    where
        F: Fn(usize) -> Vec<TopK> + Send + Sync + 'static,
    {
        self.submit_with_scratch(n_shards, move |si, _scratch| scan(si))
    }

    /// Admit one query whose scan closure receives the running worker's
    /// per-worker reusable [`ScanScratch`] alongside the shard index —
    /// the serving path's entry point: kernels write into the leased
    /// buffers, so a warm pool's scan loop performs no per-chunk heap
    /// allocation.
    pub fn submit_with_scratch<F>(
        &self,
        n_shards: usize,
        scan: F,
    ) -> Result<PendingScan, ValuationError>
    where
        F: Fn(usize, &mut ScanScratch) -> Vec<TopK> + Send + Sync + 'static,
    {
        let query_id = self.next_query.fetch_add(1, Ordering::Relaxed);
        let (done_tx, done_rx) = bounded::<Result<ShardHeaps, ValuationError>>(1);
        let cancelled = Arc::new(AtomicBool::new(false));
        if n_shards == 0 {
            // Nothing to scan: complete immediately, but still count the
            // query so PoolSnapshot totals match submit() calls.
            self.metrics.queries_submitted.fetch_add(1, Ordering::Relaxed);
            let _ = done_tx.send(Ok(Vec::new()));
            return Ok(PendingScan { rx: done_rx, cancelled, query_id });
        }
        let job = Arc::new(JobInner {
            scan: Box::new(scan),
            n_shards,
            slots: Mutex::new((0..n_shards).map(|_| None).collect()),
            remaining: AtomicUsize::new(n_shards),
            failed: Mutex::new(None),
            cancelled: cancelled.clone(),
            done: done_tx,
            query_id,
            metrics: self.metrics.clone(),
        });
        // Clone the sender OUT of the lock so a full job queue blocks only
        // this submitter, never shutdown or sibling submitters.
        let tx = self.job_tx.lock().unwrap().as_ref().cloned();
        let tx = tx.ok_or(ValuationError::Shutdown)?;
        self.metrics.in_flight.fetch_add(1, Ordering::Relaxed);
        self.metrics.queries_submitted.fetch_add(1, Ordering::Relaxed);
        if tx.send(job).is_err() {
            self.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
            return Err(ValuationError::Internal("scan pool dispatcher died".into()));
        }
        Ok(PendingScan { rx: done_rx, cancelled, query_id })
    }

    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            workers: self.n_workers,
            queue_depth: self.task_rx.depth(),
            in_flight: self.metrics.in_flight.load(Ordering::Relaxed),
            queries_submitted: self.metrics.queries_submitted.load(Ordering::Relaxed),
            tasks_completed: self.metrics.tasks_completed.load(Ordering::Relaxed),
            tasks_failed: self.metrics.tasks_failed.load(Ordering::Relaxed),
            tasks_skipped: self.metrics.tasks_skipped.load(Ordering::Relaxed),
            tasks_cancelled: self.metrics.tasks_cancelled.load(Ordering::Relaxed),
            busy_seconds: self
                .busy
                .iter()
                .map(|b| b.load(Ordering::Relaxed) as f64 / 1e9)
                .collect(),
            scratch_grows: self
                .scratch_grows
                .iter()
                .map(|g| g.load(Ordering::Relaxed))
                .collect(),
            worker_lanes: self.lanes.iter().map(|l| l.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Stop admission, drain every task already submitted (pending queries
    /// still complete), and join dispatcher + workers. Idempotent; also
    /// runs on drop.
    pub fn shutdown(&self) {
        let tx = self.job_tx.lock().unwrap().take();
        drop(tx);
        let mut handles = self.handles.lock().unwrap();
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ScanPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Dispatcher: round-robin one shard task per in-flight query into the
/// bounded task queue. Exits (dropping the task sender, which lets workers
/// drain and stop) once admission is closed AND every accepted query's
/// tasks have been handed out.
fn dispatch(job_rx: Receiver<Arc<JobInner>>, task_tx: Sender<Task>) {
    // (job, next shard to hand out) — front of the deque is next served.
    let mut active: std::collections::VecDeque<(Arc<JobInner>, usize)> =
        std::collections::VecDeque::new();
    let mut open = true;
    loop {
        if open {
            if active.is_empty() {
                // Idle: park on the job channel.
                match job_rx.recv() {
                    Some(j) => active.push_back((j, 0)),
                    None => open = false,
                }
            }
            // Admit whatever else has arrived without blocking, so new
            // queries start interleaving at the very next task grant.
            while let Some(j) = job_rx.try_recv() {
                active.push_back((j, 0));
            }
        }
        let Some((job, next)) = active.pop_front() else {
            if open {
                continue;
            }
            break;
        };
        if task_tx.send((job.clone(), next)).is_err() {
            // Workers are gone (pool tearing down hard); nothing to do.
            break;
        }
        if next + 1 < job.n_shards {
            active.push_back((job, next + 1));
        }
    }
}

/// Run one shard task with panic isolation, then complete the query if
/// this was its last outstanding task.
fn run_task(job: &Arc<JobInner>, si: usize, busy: &AtomicU64, scratch: &mut ScanScratch) {
    let poisoned = job.failed.lock().unwrap().is_some();
    if job.cancelled.load(Ordering::Acquire) {
        // Nobody is waiting for this query anymore (disconnect/deadline):
        // don't scan an abandoned query to completion.
        job.metrics.tasks_cancelled.fetch_add(1, Ordering::Relaxed);
    } else if poisoned {
        // Query already failed: don't burn pool time on its other shards.
        job.metrics.tasks_skipped.fetch_add(1, Ordering::Relaxed);
    } else {
        let t0 = Instant::now();
        match catch_unwind(AssertUnwindSafe(|| (job.scan)(si, scratch))) {
            Ok(heaps) => {
                job.slots.lock().unwrap()[si] = Some(heaps);
                job.metrics.tasks_completed.fetch_add(1, Ordering::Relaxed);
            }
            Err(panic) => {
                let mut failed = job.failed.lock().unwrap();
                if failed.is_none() {
                    *failed = Some(panic_message(&panic));
                }
                job.metrics.tasks_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        busy.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        finish(job);
    }
}

/// Last task of a query: collect slots (or the failure) and notify the
/// submitter. Failures never escape the query that caused them.
fn finish(job: &Arc<JobInner>) {
    let failed = job.failed.lock().unwrap().take();
    let res = if job.cancelled.load(Ordering::Acquire) {
        // Short-circuit: skipped shards left empty slots, and the waiter
        // (if any is still racing the cancel) must see Cancelled, not the
        // "pool bug" missing-slot error.
        Err(ValuationError::Cancelled { query_id: job.query_id })
    } else if let Some(message) = failed {
        Err(ValuationError::QueryPoisoned { query_id: job.query_id, message })
    } else {
        let mut slots = job.slots.lock().unwrap();
        let mut out = Vec::with_capacity(slots.len());
        let mut missing = None;
        for (si, slot) in slots.iter_mut().enumerate() {
            match slot.take() {
                Some(heaps) => out.push(heaps),
                None => {
                    missing = Some(si);
                    break;
                }
            }
        }
        match missing {
            Some(si) => Err(ValuationError::Internal(format!(
                "scan pool query {}: shard {si} produced no result (pool bug)",
                job.query_id
            ))),
            None => Ok(out),
        }
    };
    job.metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
    // The submitter may have given up (dropped its handle) — fine.
    let _ = job.done.send(res);
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_heap(score: f64, id: u64) -> Vec<TopK> {
        let mut t = TopK::new(1);
        t.push(score, id);
        vec![t]
    }

    #[test]
    fn results_arrive_in_shard_order() {
        let pool = ScanPool::spawn(3);
        let pending = pool
            .submit(7, |si| one_heap(si as f64, (100 + si) as u64))
            .unwrap();
        let out = pending.wait().unwrap();
        assert_eq!(out.len(), 7);
        for (si, heaps) in out.into_iter().enumerate() {
            assert_eq!(heaps.len(), 1);
            let sorted = heaps.into_iter().next().unwrap().into_sorted();
            assert_eq!(sorted, vec![(si as f64, (100 + si) as u64)]);
        }
        let snap = pool.snapshot();
        assert_eq!(snap.workers, 3);
        assert_eq!(snap.tasks_completed, 7);
        assert_eq!(snap.in_flight, 0);
        pool.shutdown();
    }

    #[test]
    fn zero_shards_completes_immediately() {
        let pool = ScanPool::spawn(1);
        let out = pool.submit(0, |_| Vec::new()).unwrap().wait().unwrap();
        assert!(out.is_empty());
        // Even no-op queries show up in the submission count.
        assert_eq!(pool.snapshot().queries_submitted, 1);
        assert_eq!(pool.snapshot().in_flight, 0);
    }

    #[test]
    fn submit_after_shutdown_is_refused() {
        let pool = ScanPool::spawn(1);
        pool.shutdown();
        assert!(pool.submit(1, |_| Vec::new()).is_err());
        // Idempotent.
        pool.shutdown();
    }

    #[test]
    fn auto_workers_resolution() {
        assert_eq!(auto_workers(5), 5);
        let auto = auto_workers(0);
        assert!(auto >= 1 && auto <= 16, "auto resolved to {auto}");
    }

    #[test]
    fn panicked_task_fails_only_its_query() {
        let pool = ScanPool::spawn(2);
        let healthy = pool.submit(4, |si| one_heap(1.0, si as u64)).unwrap();
        let poisoned = pool
            .submit(4, |si| {
                if si == 2 {
                    panic!("poisoned shard");
                }
                one_heap(2.0, si as u64)
            })
            .unwrap();
        let after = pool.submit(4, |si| one_heap(3.0, si as u64)).unwrap();
        assert_eq!(healthy.wait().unwrap().len(), 4);
        let err = poisoned.wait().unwrap_err();
        assert!(
            matches!(err, ValuationError::QueryPoisoned { .. }),
            "expected QueryPoisoned, got {err:?}"
        );
        let msg = err.to_string();
        assert!(msg.contains("panicked"), "unexpected error: {msg}");
        assert!(msg.contains("poisoned shard"), "message lost: {msg}");
        assert_eq!(after.wait().unwrap().len(), 4);
        let snap = pool.snapshot();
        assert_eq!(snap.tasks_failed, 1);
        assert_eq!(snap.in_flight, 0);
        pool.shutdown();
    }

    /// Block the single worker on one query so a second query's tasks
    /// provably cannot start; the assertions are then deterministic.
    fn blocking_query(
        pool: &ScanPool,
        gate: &Arc<AtomicBool>,
    ) -> PendingScan {
        let g = gate.clone();
        pool.submit(1, move |_| {
            while !g.load(Ordering::Acquire) {
                std::thread::sleep(Duration::from_millis(1));
            }
            one_heap(0.0, 0)
        })
        .unwrap()
    }

    #[test]
    fn dropping_handle_cancels_unstarted_tasks() {
        let pool = ScanPool::spawn(1);
        let gate = Arc::new(AtomicBool::new(false));
        let blocker = blocking_query(&pool, &gate);
        let doomed = pool.submit(4, |si| one_heap(1.0, si as u64)).unwrap();
        // The worker is parked inside the blocker's only shard, so none of
        // the doomed query's 4 tasks have started; dropping the handle
        // must make the worker skip all of them.
        drop(doomed);
        gate.store(true, Ordering::Release);
        assert_eq!(blocker.wait().unwrap().len(), 1);
        pool.shutdown(); // drains the skipped tasks
        let snap = pool.snapshot();
        assert_eq!(snap.tasks_cancelled, 4);
        assert_eq!(snap.tasks_completed, 1);
        assert_eq!(snap.in_flight, 0);
    }

    #[test]
    fn wait_until_cancels_on_signal() {
        let pool = ScanPool::spawn(1);
        let gate = Arc::new(AtomicBool::new(false));
        let blocker = blocking_query(&pool, &gate);
        let doomed = pool.submit(3, |si| one_heap(1.0, si as u64)).unwrap();
        let mut polls = 0u32;
        let err = doomed
            .wait_until(
                &mut || {
                    polls += 1;
                    polls >= 2
                },
                Duration::from_millis(5),
            )
            .unwrap_err();
        assert!(
            matches!(err, ValuationError::Cancelled { .. }),
            "expected Cancelled, got {err:?}"
        );
        gate.store(true, Ordering::Release);
        assert_eq!(blocker.wait().unwrap().len(), 1);
        pool.shutdown();
        let snap = pool.snapshot();
        assert_eq!(snap.tasks_cancelled, 3);
        assert_eq!(snap.in_flight, 0);
    }

    #[test]
    fn wait_until_returns_result_without_cancelling() {
        let pool = ScanPool::spawn(2);
        let pending = pool.submit(5, |si| one_heap(si as f64, si as u64)).unwrap();
        let out = pending
            .wait_until(&mut || false, Duration::from_millis(2))
            .unwrap();
        assert_eq!(out.len(), 5);
        pool.shutdown();
        assert_eq!(pool.snapshot().tasks_cancelled, 0);
    }
}
