//! Unified scan-backend abstraction + the [`Valuator`] session facade —
//! one-call data valuation over any store fabric.
//!
//! The paper's software contribution (LogIX, §5) is that valuation should
//! attach to existing code "with minimal effort". On the query side that
//! means ONE seam between callers and scan engines:
//!
//! - [`ScanBackend`]: any engine that can admit a [`QueryRequest`] and
//!   hand back a [`PendingScores`] completion handle. Implemented by the
//!   sequential reference ([`SequentialEngine`]), the parallel f32
//!   scan-and-merge engine
//!   ([`ParallelQueryEngine`](super::ParallelQueryEngine)), the two-stage
//!   quantized engine ([`TwoStageEngine`](super::TwoStageEngine)), and the
//!   IVF-probed sublinear engine ([`IvfEngine`](super::IvfEngine)).
//!   Future backends (remote shards) implement the same trait instead of
//!   growing another dispatch-enum arm.
//! - [`PendingScores`]: the ONE completion handle every backend returns —
//!   `wait()` yields per-test-row [`QueryResult`]s, and a pool-worker
//!   panic surfaces as [`ValuationError::QueryPoisoned`] (distinguishable
//!   from a shutdown, which is [`ValuationError::Shutdown`]).
//! - [`Valuator`]: the session facade. [`Valuator::open`] opens the store
//!   fabric once and auto-detects the codec from `shards.json`;
//!   [`ValuatorBuilder::build`] validates the whole configuration with
//!   typed [`ValuationError`]s (invalid states are rejected at
//!   construction, not deep inside a worker thread) and resolves
//!   [`Backend::Auto`] to a concrete engine.
//!
//! # `Backend::Auto` resolution rules
//!
//! | fabric codec | IVF index | shards | pool            | backend        |
//! |--------------|-----------|--------|-----------------|----------------|
//! | f32          | —         | 1      | `Off`/`Auto`    | sequential     |
//! | f32          | —         | 1      | `Shared`        | parallel-f32   |
//! | f32          | —         | >1     | any             | parallel-f32   |
//! | int8         | absent    | any    | any             | two-stage      |
//! | int8         | present   | any    | any             | ivf            |
//!
//! `Backend::Exact` follows the f32 rows of the table; on an int8 fabric
//! it opens the fabric's exact f32 companion (the `rescore_dir` the
//! manifest records at `logra store quantize` time, or an explicit
//! [`ValuatorBuilder::rescore_store`]) and scans that.
//! `Backend::Quantized` requires an int8 fabric (and stays two-stage even
//! when an index is present); `Backend::Ann` additionally requires the
//! `logra store index` IVF sidecar the manifest advertises.
//!
//! # Per-request backend selection
//!
//! The `Backend` passed to the builder only picks the DEFAULT engine. A
//! [`Valuator`] builds every engine its fabric can serve (the exact f32
//! scan always; two-stage and IVF on int8 fabrics) and routes each
//! request by its optional [`QueryRequest::backend`] choice
//! ([`BackendChoice`]) — `ann` queries can set a per-request `nprobe`. A
//! choice the fabric cannot serve (e.g. `quantized` over an f32 store)
//! is rejected at admission with [`ValuationError::InvalidConfig`].
//!
//! # Error taxonomy
//!
//! [`ValuationError`] splits failures by who must act: `InvalidConfig`
//! (fix the construction call), `StoreOpen` (fix the store directory),
//! `BadQuery` (fix the request), `QueryPoisoned` (one query lost to a
//! worker panic; the backend keeps serving), `Cancelled` (the waiter gave
//! up — deadline or disconnect — and the pool skipped the rest of the
//! query), `Shutdown` (the backend is gone), `Internal` (a bug in the
//! scan substrate).

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::hessian::{BlockHessian, PrecondBlock, Preconditioner};
use crate::linalg::ScanScratch;
use crate::obs::{QueryReport, ScanObs};
use crate::store::{
    IvfIndex, QuantShardedStore, ShardManifest, ShardedStore, StoreCodec, IVF_INDEX_NAME,
    QUANT_CODES_FILE, SHARD_MANIFEST,
};
use crate::util::topk::TopK;

use super::ann::IvfEngine;
use super::parallel::{
    cached_self_influences, resolve_chunk_len_f32, resolve_chunk_len_self_inf, scan_shard,
    PendingMerge,
};
use super::pool::ScanPool;
use super::scorer::{Normalization, QueryResult};
use super::twostage::PendingRescore;
use super::{ParallelQueryEngine, TwoStageEngine};

// ------------------------------------------------------------------ errors

/// Typed error for the valuation API. Everything a caller can hit at
/// construction, admission, or completion time — no stringly `anyhow!` in
/// the hot path.
#[derive(Clone, Debug)]
pub enum ValuationError {
    /// The configuration can never serve; fix the construction call.
    InvalidConfig(String),
    /// A store directory failed to open, or a companion store disagrees
    /// with it; fix the fabric on disk.
    StoreOpen { dir: PathBuf, message: String },
    /// The request itself is malformed (shape mismatch, token query on a
    /// runtime-free backend); fix the request.
    BadQuery(String),
    /// A pool worker panicked while scanning this query. Only this query
    /// failed — the backend keeps serving.
    QueryPoisoned { query_id: u64, message: String },
    /// The waiter cancelled this query (per-request deadline expired, or
    /// the serving client disconnected); the pool skips its unstarted
    /// shard tasks. Only this query is affected.
    Cancelled { query_id: u64 },
    /// The backend (or its scan pool) has shut down; no more admissions.
    Shutdown,
    /// Invariant violation inside the scan substrate (a bug, not a caller
    /// error).
    Internal(String),
}

impl std::fmt::Display for ValuationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValuationError::InvalidConfig(m) => write!(f, "invalid valuation config: {m}"),
            ValuationError::StoreOpen { dir, message } => {
                write!(f, "open store {}: {message}", dir.display())
            }
            ValuationError::BadQuery(m) => write!(f, "bad query: {m}"),
            ValuationError::QueryPoisoned { query_id, message } => write!(
                f,
                "scan pool query {query_id}: shard scan task panicked: {message}"
            ),
            ValuationError::Cancelled { query_id } => {
                write!(f, "scan pool query {query_id}: cancelled by the waiter")
            }
            ValuationError::Shutdown => write!(f, "valuation backend is shut down"),
            ValuationError::Internal(m) => write!(f, "internal valuation error: {m}"),
        }
    }
}

impl std::error::Error for ValuationError {}

/// Wrap an `anyhow` store-open failure with the directory it came from.
pub(crate) fn store_open_err(dir: &Path, err: anyhow::Error) -> ValuationError {
    ValuationError::StoreOpen { dir: dir.to_path_buf(), message: format!("{err:#}") }
}

// ----------------------------------------------------------------- request

/// What to score: a token sequence (needs a runtime-attached service) or
/// pre-projected gradient rows (any backend; the substrate for
/// query-by-gradient and cross-model comparisons).
#[derive(Clone, Debug)]
pub enum QueryInput {
    /// One token sequence of the artifact's `seq_len`. Only the
    /// [`ValuationService`](crate::coordinator::ValuationService) can
    /// resolve this (gradient extraction needs the PJRT runtime); scan
    /// backends reject it with [`ValuationError::BadQuery`].
    Tokens(Vec<i32>),
    /// `nt` row-major rows of RAW projected test gradients, each `k`
    /// floats (preconditioning happens inside the backend).
    Gradients { rows: Vec<f32>, nt: usize },
}

impl QueryInput {
    /// Gradient rows, or `BadQuery` for token input (scan backends are
    /// runtime-free).
    pub(crate) fn into_gradients(self) -> Result<(Vec<f32>, usize), ValuationError> {
        match self {
            QueryInput::Gradients { rows, nt } => Ok((rows, nt)),
            QueryInput::Tokens(_) => Err(ValuationError::BadQuery(
                "token queries need the runtime-attached ValuationService; \
                 scan backends accept pre-projected gradient rows"
                    .into(),
            )),
        }
    }
}

/// Per-request engine selection — the wire-level twin of the
/// construction-time [`Backend`] enum, carried on [`QueryRequest`]. `Auto`
/// (or an absent choice) serves on the valuator's default engine; the
/// other variants route to a specific engine in the fabric's roster, and
/// a choice the fabric cannot serve is rejected at admission with
/// [`ValuationError::InvalidConfig`]. Construction-time knobs
/// (`rescore_factor`) stay construction-time; only `nprobe`, the
/// per-query recall/latency dial, is overridable per request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Whatever engine the valuator resolved as its default.
    Auto,
    /// The exact full-precision full scan.
    Exact,
    /// The two-stage int8 coarse scan + exact rescore.
    Quantized,
    /// The IVF-probed sublinear scan; `nprobe` overrides the engine's
    /// configured probe width for this request (`None` = engine default).
    Ann { nprobe: Option<usize> },
}

impl BackendChoice {
    /// Parse the serve/CLI wire name (`auto | exact | quantized | ann`).
    pub fn parse(s: &str) -> Option<BackendChoice> {
        match s {
            "auto" => Some(BackendChoice::Auto),
            "exact" => Some(BackendChoice::Exact),
            "quantized" => Some(BackendChoice::Quantized),
            "ann" => Some(BackendChoice::Ann { nprobe: None }),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Exact => "exact",
            BackendChoice::Quantized => "quantized",
            BackendChoice::Ann { .. } => "ann",
        }
    }
}

/// One valuation request: input, per-request `topk`, an optional
/// per-request [`Normalization`] override, and an optional per-request
/// [`BackendChoice`] (the backend's configured defaults apply when `None`
/// — neither normalization nor engine selection is frozen at config
/// time).
#[derive(Clone, Debug)]
pub struct QueryRequest {
    pub input: QueryInput,
    pub topk: usize,
    pub norm: Option<Normalization>,
    pub backend: Option<BackendChoice>,
}

impl QueryRequest {
    /// Value one token sequence (service-only input).
    pub fn tokens(tokens: Vec<i32>, topk: usize) -> Self {
        QueryRequest { input: QueryInput::Tokens(tokens), topk, norm: None, backend: None }
    }

    /// Value `nt` pre-projected gradient rows (row-major, `nt × k`).
    pub fn gradients(rows: Vec<f32>, nt: usize, topk: usize) -> Self {
        QueryRequest {
            input: QueryInput::Gradients { rows, nt },
            topk,
            norm: None,
            backend: None,
        }
    }

    /// Override the backend's default normalization for this request.
    pub fn with_norm(mut self, norm: Normalization) -> Self {
        self.norm = Some(norm);
        self
    }

    /// Route this request to a specific engine (the [`Valuator`] honors
    /// it; a bare engine serves whatever it is).
    pub fn with_backend(mut self, backend: BackendChoice) -> Self {
        self.backend = Some(backend);
        self
    }

    /// The one admission preamble every backend shares: resolve the norm
    /// override against the backend default, clamp `topk`, reject token
    /// input, and validate the gradient shape against the fabric's `k`.
    pub(crate) fn resolve(
        self,
        default_norm: Normalization,
        k: usize,
    ) -> Result<GradQuery, ValuationError> {
        let norm = self.norm.unwrap_or(default_norm);
        let topk = self.topk.max(1);
        let (rows, nt) = self.input.into_gradients()?;
        if rows.len() != nt * k {
            return Err(ValuationError::BadQuery(format!(
                "{nt} rows x k={k} needs {} floats, got {}",
                nt * k,
                rows.len()
            )));
        }
        Ok(GradQuery { rows, nt, topk, norm })
    }
}

/// A validated gradient-rows request (the output of
/// [`QueryRequest::resolve`]) — what the engines' admission bodies take.
pub(crate) struct GradQuery {
    pub(crate) rows: Vec<f32>,
    pub(crate) nt: usize,
    pub(crate) topk: usize,
    pub(crate) norm: Normalization,
}

// ------------------------------------------------------------------ config

/// Shared construction knobs for every scan backend — the ONE place the
/// old per-engine `with_workers/with_chunk_len/with_metrics/with_pool`
/// builder stacks collapsed into.
#[derive(Clone)]
pub struct BackendConfig {
    /// Worker threads for the per-query spawn path; 0 = one per core
    /// (capped at 16). Ignored when `pool` is set — the pool's worker
    /// count is authoritative.
    pub workers: usize,
    /// Rows scored per kernel call; 0 (default) derives the chunk from the
    /// query shape so one train chunk + the test block fit L2.
    pub chunk_len: usize,
    /// Two-stage/IVF only: stage-1 candidate pool per test row as a
    /// multiple of the requested top-k (must be ≥ 1).
    pub rescore_factor: usize,
    /// IVF only: clusters probed per shard in stage 0 (must be ≥ 1; a
    /// request can override it via [`BackendChoice::Ann`]). Probing every
    /// cluster reproduces the two-stage engine bit-identically.
    pub nprobe: usize,
    /// Default normalization; any request can override per call.
    pub norm: Normalization,
    /// Record scan counters into shared service metrics.
    pub metrics: Option<Arc<Metrics>>,
    /// Run scans on a persistent [`ScanPool`] instead of per-query scoped
    /// threads.
    pub pool: Option<Arc<ScanPool>>,
}

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig {
            workers: 0,
            chunk_len: 0,
            rescore_factor: 4,
            nprobe: 4,
            norm: Normalization::None,
            metrics: None,
            pool: None,
        }
    }
}

// ------------------------------------------------------------------- trait

/// Which concrete engine serves a backend (introspection; also what
/// `logra store stat` reports as the auto-selected backend).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Sequential,
    Parallel,
    TwoStage,
    Ivf,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Sequential => "sequential",
            BackendKind::Parallel => "parallel-f32",
            BackendKind::TwoStage => "two-stage",
            BackendKind::Ivf => "ivf",
        }
    }
}

/// Any scan engine behind one admission call: submit a [`QueryRequest`],
/// get a [`PendingScores`] completion handle. Implementations are
/// `Send + Sync` so a `Box<dyn ScanBackend>` (or the [`Valuator`] facade)
/// can serve concurrent callers.
pub trait ScanBackend: Send + Sync {
    /// Admit one query. Backends attached to a [`ScanPool`] return
    /// immediately with the scan in flight; unpooled backends may scan
    /// eagerly on the calling thread and return a ready handle.
    fn submit(&self, req: QueryRequest) -> Result<PendingScores, ValuationError>;

    /// Which engine this is.
    fn kind(&self) -> BackendKind;

    /// Rows in the served fabric.
    fn rows(&self) -> usize;

    /// Projected gradient dimension.
    fn k(&self) -> usize;

    /// Resolved scan worker count (the pool's when one is attached).
    fn workers(&self) -> usize;

    /// Whether every request is served at exact full precision over the
    /// full corpus (false for the two-stage coarse-scan backend, whose
    /// exactness depends on the rescore pool covering the corpus).
    fn exact(&self) -> bool;

    /// Raw stored gradient row `i` in global order (from the exact f32
    /// substrate), if in range — the query-by-gradient convenience.
    fn gradient_row(&self, i: usize) -> Option<Vec<f32>>;

    /// Submit + wait.
    fn query(&self, req: QueryRequest) -> Result<Vec<QueryResult>, ValuationError> {
        self.submit(req)?.wait()
    }

    /// Submit + wait, returning the per-query [`QueryReport`] stage
    /// breakdown alongside the scores. The report is `Some` exactly when
    /// the backend was built with [`BackendConfig::metrics`].
    fn query_with_report(
        &self,
        req: QueryRequest,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        self.submit(req)?.wait_with_report()
    }
}

// ------------------------------------------------------------- completion

/// The ONE completion handle every backend returns. Replaces the old
/// per-engine `PendingQuery` / `PendingTwoStage` / service `Outcome`
/// triplet: `wait()` performs whatever deterministic merge or rescore the
/// originating backend still owes and yields per-test-row results.
pub struct PendingScores {
    inner: Pending,
}

pub(crate) enum Pending {
    /// Scanned eagerly at admission (sequential backend, empty fabrics),
    /// report already final.
    Ready(Vec<QueryResult>, Option<QueryReport>),
    /// Parallel f32 scan in flight; `wait` merges per-shard heaps.
    Merge(PendingMerge),
    /// Two-stage coarse scan in flight; `wait` merges candidate pools and
    /// runs the exact rescore on the calling thread.
    Rescore(PendingRescore),
}

impl PendingScores {
    pub(crate) fn ready(results: Vec<QueryResult>, report: Option<QueryReport>) -> Self {
        PendingScores { inner: Pending::Ready(results, report) }
    }

    pub(crate) fn merge(p: PendingMerge) -> Self {
        PendingScores { inner: Pending::Merge(p) }
    }

    pub(crate) fn rescore(p: PendingRescore) -> Self {
        PendingScores { inner: Pending::Rescore(p) }
    }

    /// Whether the scan work already ran at admission time, on the
    /// admitting thread: true for eagerly-scanned results (sequential
    /// backend, unpooled parallel scatter/gather) — `wait` then performs
    /// only the cheap local merge. False whenever meaningful work is
    /// still owed: a pool scan in flight, or the two-stage exact rescore
    /// (which always runs inside `wait`, whatever stage 1 did).
    pub fn is_ready(&self) -> bool {
        match &self.inner {
            Pending::Ready(..) => true,
            Pending::Merge(p) => p.is_eager(),
            Pending::Rescore(_) => false,
        }
    }

    /// Block until the scan completes; per-test-row results in request
    /// order. A pool-worker panic surfaces as
    /// [`ValuationError::QueryPoisoned`] — only this query is lost.
    pub fn wait(self) -> Result<Vec<QueryResult>, ValuationError> {
        self.wait_with_report().map(|(results, _)| results)
    }

    /// [`wait`](Self::wait), plus the per-query [`QueryReport`] stage
    /// breakdown (`Some` exactly when the backend carries a
    /// [`BackendConfig::metrics`] handle).
    pub fn wait_with_report(
        self,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        match self.inner {
            Pending::Ready(results, report) => Ok((results, report)),
            Pending::Merge(p) => p.finish(),
            Pending::Rescore(p) => p.finish(),
        }
    }

    /// Cancellable [`wait_with_report`](Self::wait_with_report): while a
    /// pool scan is in flight, `should_cancel` is re-checked every `poll`
    /// interval; when it reports true the query is cancelled (the pool
    /// skips its unstarted shard tasks, counted as `tasks_cancelled`) and
    /// [`ValuationError::Cancelled`] is returned. The serve path's
    /// deadline/disconnect seam. Already-computed results return
    /// immediately without consulting `should_cancel` — an eagerly-scanned
    /// query has no remaining work to cancel.
    pub fn wait_with_report_until(
        self,
        should_cancel: &mut dyn FnMut() -> bool,
        poll: Duration,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        match self.inner {
            Pending::Ready(results, report) => Ok((results, report)),
            Pending::Merge(p) => p.finish_until(should_cancel, poll),
            Pending::Rescore(p) => p.finish_until(should_cancel, poll),
        }
    }
}

// ---------------------------------------------------------- query reports

/// Everything a backend needs to finalize a [`QueryReport`] (and the
/// query-level histogram/trace records) at completion time. Built at
/// admission — which also records the `"admission"` span and marks the
/// [`ScanObs`] admission boundary — and carried inside the pending handle.
pub(crate) struct ReportCtx {
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) scan: Arc<ScanObs>,
    backend: &'static str,
    shards: u32,
    rows: u64,
}

impl ReportCtx {
    pub(crate) fn new(
        metrics: Arc<Metrics>,
        scan: Arc<ScanObs>,
        backend: &'static str,
        shards: u32,
        rows: u64,
    ) -> Self {
        scan.admission_done(&metrics.obs);
        ReportCtx { metrics, scan, backend, shards, rows }
    }

    /// Finalize at completion: record the `"merge"`, `"rescore"` (when
    /// candidates were rescored), and `"query"` spans, feed the
    /// end-to-end latency histogram and the aggregate
    /// `queue_wait_nanos` counter, and build the [`QueryReport`].
    /// `scan_done_nanos` / `rescore_start_nanos` are [`ScanObs`]-elapsed
    /// stamps taken when the shard results became available and when the
    /// exact rescore began (equal to merge-done on exact backends).
    pub(crate) fn complete(
        self,
        scan_done_nanos: u64,
        rescore_start_nanos: u64,
        candidates_rescored: u64,
    ) -> QueryReport {
        let total = self.scan.elapsed_nanos();
        let obs = &self.metrics.obs;
        let admitted = self.scan.admitted_nanos();
        let admission = self.scan.admission_nanos();
        let queue_wait = self.scan.queue_wait_nanos();
        let scan_nanos = scan_done_nanos.saturating_sub(admission + queue_wait);
        let merge_nanos = rescore_start_nanos.saturating_sub(scan_done_nanos);
        let rescore_nanos = total.saturating_sub(rescore_start_nanos);
        self.metrics
            .queue_wait_nanos
            .fetch_add(queue_wait, std::sync::atomic::Ordering::Relaxed);
        obs.query_latency.record(total);
        obs.span("merge", self.scan.query(), None, admitted + scan_done_nanos, merge_nanos);
        if candidates_rescored > 0 {
            obs.span(
                "rescore",
                self.scan.query(),
                None,
                admitted + rescore_start_nanos,
                rescore_nanos,
            );
        }
        obs.span("query", self.scan.query(), None, admitted, total);
        QueryReport {
            query_id: self.scan.query(),
            backend: self.backend,
            shards: self.shards,
            rows_scanned: self.rows,
            candidates_rescored,
            admission_nanos: admission,
            queue_wait_nanos: queue_wait,
            scan_nanos,
            merge_nanos,
            rescore_nanos,
            total_nanos: total,
            workers: self.scan.lanes(),
        }
    }
}

// ------------------------------------------------------- sequential engine

/// The sequential scan backend: one thread, shards scanned in order
/// through the shared kernel layer — the serving-shaped twin of the
/// [`QueryEngine`](super::QueryEngine) native reference (bit-identical to
/// it, like every backend; `rust/tests/backend.rs`). The right shape for
/// unsharded stores, where there is nothing to fan out over.
pub struct SequentialEngine {
    store: Arc<ShardedStore>,
    precond: Arc<Preconditioner>,
    cfg: BackendConfig,
    /// One scratch for the engine — scans are serialized through it, which
    /// is the point of this backend.
    scratch: Mutex<ScanScratch>,
    self_inf: Mutex<Option<Arc<Vec<f32>>>>,
}

impl SequentialEngine {
    pub fn new(store: Arc<ShardedStore>, precond: Arc<Preconditioner>, cfg: BackendConfig) -> Self {
        SequentialEngine {
            store,
            precond,
            cfg,
            scratch: Mutex::new(ScanScratch::new()),
            self_inf: Mutex::new(None),
        }
    }

    /// Self-influence of each stored row in global order (computed once,
    /// then cached across queries and threads).
    pub fn train_self_influences(&self) -> Arc<Vec<f32>> {
        cached_self_influences(
            &self.self_inf,
            &self.store,
            &self.precond,
            1,
            resolve_chunk_len_self_inf(self.cfg.chunk_len, self.store.k()),
        )
    }
}

impl ScanBackend for SequentialEngine {
    fn submit(&self, req: QueryRequest) -> Result<PendingScores, ValuationError> {
        let k = self.store.k();
        let GradQuery { rows, nt, topk, norm } = req.resolve(self.cfg.norm, k)?;
        let scan_obs = self.cfg.metrics.as_ref().map(|m| Arc::new(ScanObs::new(&m.obs)));
        let pre = self.precond.apply_rows(&rows, nt);
        let selfs: Option<Arc<Vec<f32>>> = match norm {
            Normalization::RelatIf => Some(self.train_self_influences()),
            Normalization::None => None,
        };
        let selfs_ref: Option<&[f32]> = selfs.as_ref().map(|s| s.as_slice());
        let chunk_len = resolve_chunk_len_f32(self.cfg.chunk_len, k, nt);
        if let Some(m) = &self.cfg.metrics {
            m.scan_chunk_len.store(chunk_len as u64, std::sync::atomic::Ordering::Relaxed);
        }
        let ctx = match (&self.cfg.metrics, &scan_obs) {
            (Some(m), Some(so)) => Some(ReportCtx::new(
                m.clone(),
                so.clone(),
                BackendKind::Sequential.name(),
                self.store.n_shards() as u32,
                self.store.rows() as u64,
            )),
            _ => None,
        };
        let mut scratch = self.scratch.lock().unwrap();
        let mut finals: Vec<TopK> = (0..nt).map(|_| TopK::new(topk)).collect();
        for si in 0..self.store.n_shards() {
            let heaps = scan_shard(
                &self.store,
                si,
                &pre,
                nt,
                topk,
                selfs_ref,
                chunk_len,
                self.cfg.metrics.as_deref(),
                scan_obs.as_deref(),
                &mut scratch,
            );
            for (t, h) in heaps.into_iter().enumerate() {
                finals[t].merge(h);
            }
        }
        // Scan and merge are interleaved here (heaps merge as each shard
        // finishes), so the whole loop reports as scan time.
        let report = ctx.map(|c| {
            let done = c.scan.elapsed_nanos();
            c.complete(done, done, 0)
        });
        Ok(PendingScores::ready(
            finals.into_iter().map(|h| QueryResult { top: h.into_sorted() }).collect(),
            report,
        ))
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Sequential
    }

    fn rows(&self) -> usize {
        self.store.rows()
    }

    fn k(&self) -> usize {
        self.store.k()
    }

    fn workers(&self) -> usize {
        1
    }

    fn exact(&self) -> bool {
        true
    }

    fn gradient_row(&self, i: usize) -> Option<Vec<f32>> {
        (i < self.store.rows()).then(|| self.store.row(i).to_vec())
    }
}

// ----------------------------------------------------------------- facade

/// Backend selection for [`ValuatorBuilder::backend`]. `Auto` (the
/// default) picks from the fabric's codec and shard count — see the
/// module docs for the resolution table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Codec-driven: exact engines for f32 fabrics, two-stage for int8.
    Auto,
    /// Full-precision full scan, even over an int8 fabric (serves its f32
    /// rescore companion).
    Exact,
    /// Int8 coarse scan + exact rescore of `rescore_factor × topk`
    /// candidates per test row. Requires an int8 fabric.
    Quantized { rescore_factor: usize },
    /// IVF stage-0 probe (`nprobe` nearest clusters per shard) feeding the
    /// int8 coarse scan + exact rescore. Requires an int8 fabric whose
    /// manifest advertises a `logra store index` sidecar.
    Ann { nprobe: usize, rescore_factor: usize },
}

/// How the [`Valuator`] runs its shard fan-out.
#[derive(Clone)]
pub enum PoolMode {
    /// Per-query scoped threads (the one-shot CLI shape). Default.
    Off,
    /// Spawn a pool owned by the Valuator when the resolved backend fans
    /// out (parallel / two-stage); sequential backends skip it.
    Auto,
    /// Attach an existing pool (share warm workers across valuators).
    Shared(Arc<ScanPool>),
}

enum Fabric {
    F32(Arc<ShardedStore>),
    Int8 {
        quant: Arc<QuantShardedStore>,
        rescore_dir: Option<PathBuf>,
        /// Manifest advertises a `logra store index` IVF sidecar.
        indexed: bool,
    },
}

/// A shard that failed validation during a [`Valuator::open_degraded`]
/// open and was excluded from the fabric instead of failing it.
#[derive(Clone, Debug)]
pub struct QuarantinedShard {
    /// Manifest directory name of the shard (e.g. `shard-0003`).
    pub name: String,
    /// Why validation rejected it (path + expected/actual rows included).
    pub error: String,
}

enum PrecondSource {
    Missing,
    Provided(Arc<Preconditioner>),
    /// Fit the projected Fisher from the stored rows themselves (they ARE
    /// projected gradients; their second moment is the projected Fisher).
    FitFromStore { damping: f32 },
    /// Fit the Fisher eigenbasis, then refit the eigenvalues the EKFAC way
    /// (mean squared rotated coordinate of the stored rows) — the
    /// `hessian::kfac` correction promoted from the `baselines::ekfac_if`
    /// baseline into the serving path.
    FitEkfacFromStore { damping: f32 },
}

/// Builder returned by [`Valuator::open`]: the single configuration point
/// for the whole query side.
pub struct ValuatorBuilder {
    dir: PathBuf,
    fabric: Fabric,
    backend: Backend,
    pool: PoolMode,
    norm: Normalization,
    workers: usize,
    chunk_len: usize,
    precond: PrecondSource,
    metrics: Option<Arc<Metrics>>,
    rescore_override: Option<PathBuf>,
    /// Manifest generation observed at open (0 for pre-generation
    /// manifests and bare directories) — carried into the Valuator so the
    /// serve layer can pin query snapshots to it.
    generation: u64,
    /// Shards excluded by a degraded open (empty on strict opens).
    quarantined: Vec<QuarantinedShard>,
}

impl ValuatorBuilder {
    /// Select the engine ([`Backend::Auto`] by default).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Scan execution substrate ([`PoolMode::Off`] by default).
    pub fn pool(mut self, pool: PoolMode) -> Self {
        self.pool = pool;
        self
    }

    /// Default normalization; any [`QueryRequest`] can override per call.
    pub fn normalization(mut self, norm: Normalization) -> Self {
        self.norm = norm;
        self
    }

    /// Scan workers (0 = one per core, capped at 16) — feeds both the
    /// per-query spawn path and [`PoolMode::Auto`]'s pool size.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Rows per kernel call; 0 (default) = L2-fit auto derivation.
    pub fn chunk_len(mut self, chunk_len: usize) -> Self {
        self.chunk_len = chunk_len;
        self
    }

    /// Record scan counters (and the spawned pool's worker count) into
    /// shared service metrics.
    pub fn metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Use a pre-fitted damped iHVP preconditioner (the logging phase's
    /// Fisher — the normal serving path).
    pub fn preconditioner(mut self, precond: Arc<Preconditioner>) -> Self {
        self.precond = PrecondSource::Provided(precond);
        self
    }

    /// Fit the preconditioner from the stored rows at `build` time
    /// (single-block projected Fisher, the paper's damping rule). The
    /// store-only shape: `logra query` uses this, no artifact needed.
    pub fn fit_from_store(mut self, damping: f32) -> Self {
        self.precond = PrecondSource::FitFromStore { damping };
        self
    }

    /// Fit an EKFAC-parameterized preconditioner from the stored rows at
    /// `build` time: the Fisher eigenbasis of
    /// [`fit_from_store`](Self::fit_from_store), with each eigenvalue
    /// replaced by the mean squared coordinate of the stored rows in that
    /// eigendirection (the `hessian::kfac::Ekfac` diagonal refit, promoted
    /// from `baselines::ekfac_if` into the serving path). Session stages
    /// opt in via `"preconditioner": "ekfac"` in `session.json`.
    pub fn fit_ekfac_from_store(mut self, damping: f32) -> Self {
        self.precond = PrecondSource::FitEkfacFromStore { damping };
        self
    }

    /// Explicitly pair the exact f32 store an int8 fabric rescoring
    /// against (overrides the manifest's recorded `rescore_dir`).
    pub fn rescore_store(mut self, dir: impl Into<PathBuf>) -> Self {
        self.rescore_override = Some(dir.into());
        self
    }

    /// The [`BackendKind`] that [`Backend::Auto`] resolves to for this
    /// fabric (with the default [`PoolMode::Off`]) — what
    /// `logra store stat` reports.
    pub fn auto_kind(&self) -> BackendKind {
        match &self.fabric {
            Fabric::Int8 { indexed: true, .. } => BackendKind::Ivf,
            Fabric::Int8 { indexed: false, .. } => BackendKind::TwoStage,
            Fabric::F32(s) => {
                if s.n_shards() > 1 {
                    BackendKind::Parallel
                } else {
                    BackendKind::Sequential
                }
            }
        }
    }

    /// Resolve the exact f32 store this builder's int8 fabric rescore
    /// against: the explicit override, else the manifest's `rescore_dir`
    /// (a relative recorded path resolves against the quantized store's
    /// own directory, so hand-edited manifests stay relocatable).
    fn exact_companion(
        &self,
        rescore_dir: &Option<PathBuf>,
    ) -> Result<Arc<ShardedStore>, ValuationError> {
        let dir = match (&self.rescore_override, rescore_dir) {
            (Some(d), _) => d.clone(),
            (None, Some(d)) if d.is_relative() => self.dir.join(d),
            (None, Some(d)) => d.clone(),
            (None, None) => {
                return Err(ValuationError::InvalidConfig(format!(
                    "quantized store {} records no exact companion (rescore_dir); \
                     re-run `logra store quantize`, pass ValuatorBuilder::rescore_store, \
                     or `logra query --rescore-store <dir>`",
                    self.dir.display()
                )))
            }
        };
        let store = ShardedStore::open(&dir).map_err(|e| store_open_err(&dir, e))?;
        Ok(Arc::new(store))
    }

    /// Validate and construct. All configuration errors surface here, as
    /// typed [`ValuationError`]s, before any query is admitted.
    ///
    /// Every engine the fabric can serve is built (sharing the stores,
    /// preconditioner, and pool), so per-request [`BackendChoice`]
    /// overrides route without re-opening anything; `self.backend` only
    /// picks which engine is the default.
    pub fn build(self) -> Result<Valuator, ValuationError> {
        enum PrimaryKind {
            ExactScan,
            TwoStage,
            Ivf,
        }

        // 1. Resolve the stores the engine roster shares: the exact f32
        // substrate (always), the quantized copy and the IVF index (int8
        // fabrics), and which engine `self.backend` makes primary.
        let (exact, quant, index, primary): (
            Arc<ShardedStore>,
            Option<Arc<QuantShardedStore>>,
            Option<Arc<IvfIndex>>,
            PrimaryKind,
        ) = match (&self.backend, &self.fabric) {
            (Backend::Auto | Backend::Exact, Fabric::F32(store)) => {
                (store.clone(), None, None, PrimaryKind::ExactScan)
            }
            (Backend::Quantized { .. }, Fabric::F32(_)) => {
                return Err(ValuationError::InvalidConfig(format!(
                    "store {} uses the f32 codec; Backend::Quantized needs an int8 fabric \
                     (`logra store quantize` one, then open the quantized copy)",
                    self.dir.display()
                )))
            }
            (Backend::Ann { .. }, Fabric::F32(_)) => {
                return Err(ValuationError::InvalidConfig(format!(
                    "store {} uses the f32 codec; Backend::Ann needs an int8 fabric with \
                     an IVF index (`logra store quantize`, then `logra store index`)",
                    self.dir.display()
                )))
            }
            (_, Fabric::Int8 { quant, rescore_dir, indexed }) => {
                let exact = self.exact_companion(rescore_dir)?;
                // The companion is advisory (the source may have moved):
                // reject one that no longer mirrors the quantized fabric.
                if exact.rows() != quant.rows() || exact.k() != quant.k() {
                    return Err(ValuationError::InvalidConfig(format!(
                        "exact companion ({} rows, k={}) does not mirror quantized store {} \
                         ({} rows, k={}) — re-run `logra store quantize` or pass \
                         ValuatorBuilder::rescore_store",
                        exact.rows(),
                        exact.k(),
                        self.dir.display(),
                        quant.rows(),
                        quant.k()
                    )));
                }
                let index = if *indexed {
                    let ix = IvfIndex::open(&self.dir, quant)
                        .map_err(|e| store_open_err(&self.dir, e))?;
                    Some(Arc::new(ix))
                } else {
                    None
                };
                let primary = match &self.backend {
                    Backend::Exact => PrimaryKind::ExactScan,
                    Backend::Quantized { .. } => PrimaryKind::TwoStage,
                    Backend::Ann { .. } if index.is_none() => {
                        return Err(ValuationError::InvalidConfig(format!(
                            "store {} has no IVF index; `logra store index` builds the \
                             stage-0 sidecar Backend::Ann probes",
                            self.dir.display()
                        )))
                    }
                    Backend::Ann { .. } => PrimaryKind::Ivf,
                    Backend::Auto if index.is_some() => PrimaryKind::Ivf,
                    Backend::Auto => PrimaryKind::TwoStage,
                };
                (exact, Some(quant.clone()), index, primary)
            }
        };
        // (Zero rescore_factor / nprobe are rejected by the engine
        // constructors below — the single owners of those rules.)

        // 2. Resolve the preconditioner (and validate its width).
        let precond = match self.precond {
            PrecondSource::Provided(p) => p,
            PrecondSource::FitFromStore { damping } => fit_preconditioner(&exact, damping)?,
            PrecondSource::FitEkfacFromStore { damping } => {
                fit_ekfac_preconditioner(&exact, damping)?
            }
            PrecondSource::Missing => {
                return Err(ValuationError::InvalidConfig(
                    "no preconditioner: pass ValuatorBuilder::preconditioner(...) \
                     or ValuatorBuilder::fit_from_store(damping)"
                        .into(),
                ))
            }
        };
        if precond.k_total != exact.k() {
            return Err(ValuationError::InvalidConfig(format!(
                "preconditioner width k={} disagrees with store k={}",
                precond.k_total,
                exact.k()
            )));
        }

        // 3. Resolve the pool, keyed off the PRIMARY engine's fan-out
        // shape (a sequential primary never takes one). A pool the
        // builder spawns belongs to this Valuator; a Shared one stays the
        // caller's, so shutdown leaves it serving its other attachees.
        let shared_pool = matches!(self.pool, PoolMode::Shared(_));
        let primary_fans_out = match primary {
            PrimaryKind::ExactScan => exact.n_shards() > 1 || shared_pool,
            PrimaryKind::TwoStage | PrimaryKind::Ivf => true,
        };
        let (pool, owns_pool): (Option<Arc<ScanPool>>, bool) =
            match (&self.pool, primary_fans_out) {
                (PoolMode::Off, _) | (_, false) => (None, false),
                (PoolMode::Auto, true) => (Some(Arc::new(ScanPool::spawn(self.workers))), true),
                (PoolMode::Shared(p), true) => (Some(p.clone()), false),
            };
        if let (Some(p), Some(m)) = (&pool, &self.metrics) {
            m.pool_workers
                .store(p.workers() as u64, std::sync::atomic::Ordering::Relaxed);
        }

        // 4. Build the roster behind the trait. Index 0 is always the
        // exact engine; two-stage and IVF follow on int8 fabrics.
        let base_cfg = BackendConfig {
            workers: self.workers,
            chunk_len: self.chunk_len,
            rescore_factor: 4,
            nprobe: 4,
            norm: self.norm,
            metrics: self.metrics,
            pool: pool.clone(),
        };
        let mut engines: Vec<Box<dyn ScanBackend>> = Vec::new();
        let exact_fans_out = exact.n_shards() > 1 || pool.is_some();
        let exact_engine: Box<dyn ScanBackend> = if exact_fans_out {
            Box::new(ParallelQueryEngine::new(exact.clone(), precond.clone(), base_cfg.clone()))
        } else {
            Box::new(SequentialEngine::new(exact.clone(), precond.clone(), base_cfg.clone()))
        };
        engines.push(exact_engine);
        if let Some(quant) = &quant {
            let two_cfg = BackendConfig {
                rescore_factor: match self.backend {
                    Backend::Quantized { rescore_factor } => rescore_factor,
                    _ => 4,
                },
                ..base_cfg.clone()
            };
            engines.push(Box::new(TwoStageEngine::new(
                quant.clone(),
                exact.clone(),
                precond.clone(),
                two_cfg,
            )?));
            if let Some(index) = &index {
                let ivf_cfg = BackendConfig {
                    rescore_factor: match self.backend {
                        Backend::Ann { rescore_factor, .. } => rescore_factor,
                        _ => 4,
                    },
                    // Auto default: probe a quarter of the clusters —
                    // sublinear out of the box, overridable per request.
                    nprobe: match self.backend {
                        Backend::Ann { nprobe, .. } => nprobe,
                        _ => index.max_clusters().div_ceil(4).max(1),
                    },
                    ..base_cfg.clone()
                };
                engines.push(Box::new(IvfEngine::new(
                    quant.clone(),
                    index.clone(),
                    exact.clone(),
                    precond.clone(),
                    ivf_cfg,
                )?));
            }
        }
        let primary = match primary {
            PrimaryKind::ExactScan => 0,
            PrimaryKind::TwoStage => 1,
            PrimaryKind::Ivf => engines.len() - 1,
        };
        let ivf_fallback = index.as_ref().map_or(0, |ix| ix.fallback_shards());
        Ok(Valuator {
            engines,
            primary,
            pool,
            owns_pool,
            generation: self.generation,
            quarantined: self.quarantined,
            ivf_fallback,
        })
    }
}

/// Open an f32 fabric from its manifest, excluding (and recording) every
/// shard that fails validation instead of failing the open. Fails only
/// when no shard survives. Finalized shards are immutable, so a
/// quarantined shard is either brand new (never served) or damaged on
/// disk — excluding it serves exactly the rows that still validate.
fn open_f32_degraded(
    dir: &Path,
    man: &ShardManifest,
    quarantined: &mut Vec<QuarantinedShard>,
) -> Result<ShardedStore, ValuationError> {
    let mut shards = Vec::with_capacity(man.n_shards());
    for (i, name) in man.shard_dirs.iter().enumerate() {
        match crate::store::shards::open_manifest_shard(man, dir, i) {
            Ok(s) if s.k() == man.k => shards.push(s),
            Ok(s) => quarantined.push(QuarantinedShard {
                name: name.clone(),
                error: format!(
                    "shard {name}: k={} disagrees with manifest k={}",
                    s.k(),
                    man.k
                ),
            }),
            Err(e) => quarantined.push(QuarantinedShard {
                name: name.clone(),
                error: format!("{e:#}"),
            }),
        }
    }
    if shards.is_empty() {
        let detail = quarantined
            .iter()
            .map(|q| q.error.as_str())
            .collect::<Vec<_>>()
            .join("; ");
        return Err(store_open_err(
            dir,
            anyhow::anyhow!("every shard failed validation: {detail}"),
        ));
    }
    Ok(ShardedStore::from_shards(shards, man.k))
}

/// Fit the single-block projected Fisher from the stored rows, chunk-wise.
fn fit_preconditioner(
    store: &ShardedStore,
    damping: f32,
) -> Result<Arc<Preconditioner>, ValuationError> {
    let k = store.k();
    let mut hess = BlockHessian::single_block(k);
    for si in 0..store.n_shards() {
        let shard = store.shard(si);
        let rows = shard.rows();
        let mut at = 0usize;
        while at < rows {
            let len = 1024.min(rows - at);
            hess.accumulate(shard.chunk(at, len), len);
            at += len;
        }
    }
    hess.preconditioner(damping).map(Arc::new).map_err(|e| {
        ValuationError::InvalidConfig(format!("fit preconditioner from store: {e:#}"))
    })
}

/// Fit the EKFAC-corrected preconditioner from the stored rows: pass 1 is
/// the Fisher eigendecomposition of [`fit_preconditioner`]; pass 2 walks
/// the store again and refits each eigenvalue as the mean squared rotated
/// coordinate `E[(Q^T g)_i^2]` of the stored rows — exactly the
/// `hessian::kfac::Ekfac` corrected-eigenvalue recipe, but over the
/// projected single-block Fisher a store-only session stage can fit
/// without the runtime. The damped iHVP then inverts the corrected
/// spectrum in the same eigenbasis, with the paper's damping rule applied
/// to the corrected mean.
fn fit_ekfac_preconditioner(
    store: &ShardedStore,
    damping: f32,
) -> Result<Arc<Preconditioner>, ValuationError> {
    let fisher = fit_preconditioner(store, damping)?;
    let k = store.k();
    // fit_preconditioner built a single-block preconditioner over k dims.
    let basis = &fisher.blocks[0];
    let mut lambda = vec![0.0f64; k];
    let mut fitted_rows = 0u64;
    for si in 0..store.n_shards() {
        let shard = store.shard(si);
        let rows = shard.rows();
        let mut at = 0usize;
        while at < rows {
            let len = 1024.min(rows - at);
            let chunk = shard.chunk(at, len);
            for r in 0..len {
                let g = &chunk[r * k..(r + 1) * k];
                for (i, l) in lambda.iter_mut().enumerate() {
                    let mut c = 0.0f32;
                    for (rr, gv) in g.iter().enumerate() {
                        c += basis.q.at(rr, i) * gv;
                    }
                    *l += (c as f64) * (c as f64);
                }
            }
            fitted_rows += len as u64;
            at += len;
        }
    }
    // fit_preconditioner already rejected an empty store.
    let corrected: Vec<f32> = lambda
        .iter()
        .map(|l| (l / fitted_rows.max(1) as f64) as f32)
        .collect();
    let mean = corrected.iter().sum::<f32>() / k.max(1) as f32;
    let damp = (damping * mean).max(1e-12);
    Ok(Arc::new(Preconditioner {
        blocks: vec![PrecondBlock {
            off: 0,
            q: basis.q.clone(),
            eigenvalues: corrected,
            damp,
        }],
        k_total: k,
    }))
}

/// Session facade: ONE object that opens the store fabric, owns the
/// resolved engine roster (and its scan pool, if any), and answers
/// queries — routing each request by its per-request [`BackendChoice`],
/// defaulting to the builder-selected primary engine. See the crate docs
/// for a runnable quickstart.
pub struct Valuator {
    /// Every engine the fabric can serve; index 0 is always the exact
    /// f32 scan, so per-request `exact` routing never misses.
    engines: Vec<Box<dyn ScanBackend>>,
    /// Index of the builder-selected default engine.
    primary: usize,
    pool: Option<Arc<ScanPool>>,
    /// True when the builder spawned `pool` ([`PoolMode::Auto`]);
    /// [`PoolMode::Shared`] pools belong to the caller and survive
    /// [`Valuator::shutdown`].
    owns_pool: bool,
    /// Manifest generation this snapshot was opened at (0 for bare
    /// directories and pre-generation manifests).
    generation: u64,
    /// Shards a degraded open excluded from the fabric.
    quarantined: Vec<QuarantinedShard>,
    /// IVF-indexed shards serving via the per-shard full-scan fallback.
    ivf_fallback: usize,
}

impl std::fmt::Debug for Valuator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Valuator")
            .field("kind", &self.primary_engine().kind())
            .field("engines", &self.engines.len())
            .field("rows", &self.primary_engine().rows())
            .field("k", &self.primary_engine().k())
            .field("workers", &self.primary_engine().workers())
            .field("pooled", &self.pool.is_some())
            .field("generation", &self.generation)
            .field("quarantined", &self.quarantined.len())
            .finish()
    }
}

impl std::fmt::Debug for ValuatorBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ValuatorBuilder")
            .field("dir", &self.dir)
            .field("backend", &self.backend)
            .field("auto_kind", &self.auto_kind())
            .finish()
    }
}

impl Valuator {
    /// Open the store fabric at `dir` once, auto-detecting the codec from
    /// `shards.json` (a bare v1 f32 directory and a bare quantized
    /// directory both work). Configuration continues on the returned
    /// builder; validation happens at [`ValuatorBuilder::build`].
    pub fn open(dir: impl AsRef<Path>) -> Result<ValuatorBuilder, ValuationError> {
        Self::open_with(dir.as_ref(), false)
    }

    /// Like [`Valuator::open`], but an f32 shard failing validation is
    /// **quarantined** — excluded from the fabric and reported via
    /// [`Valuator::quarantined`] — instead of failing the open. This is
    /// the reload path of a live-serving process: a newly appended (or
    /// damaged) shard must degrade the new snapshot, never poison it;
    /// the previously served generation keeps serving until the swap, so
    /// every row that still validates stays available. The open still
    /// fails if *every* shard is rejected, and int8 fabrics keep strict
    /// validation (their global row numbering feeds exact rescoring, so
    /// skipping a shard would mis-map candidates — a reload error there
    /// keeps the previous generation serving instead).
    pub fn open_degraded(dir: impl AsRef<Path>) -> Result<ValuatorBuilder, ValuationError> {
        Self::open_with(dir.as_ref(), true)
    }

    fn open_with(dir: &Path, tolerate: bool) -> Result<ValuatorBuilder, ValuationError> {
        let dir = dir.to_path_buf();
        let mut generation = 0u64;
        let mut quarantined: Vec<QuarantinedShard> = Vec::new();
        let fabric = if dir.join(SHARD_MANIFEST).exists() {
            let man = ShardManifest::load(&dir).map_err(|e| store_open_err(&dir, e))?;
            generation = man.generation;
            match man.codec {
                StoreCodec::F32 => {
                    let s = if tolerate {
                        open_f32_degraded(&dir, &man, &mut quarantined)?
                    } else {
                        ShardedStore::open(&dir).map_err(|e| store_open_err(&dir, e))?
                    };
                    Fabric::F32(Arc::new(s))
                }
                StoreCodec::Int8 => {
                    let q =
                        QuantShardedStore::open(&dir).map_err(|e| store_open_err(&dir, e))?;
                    Fabric::Int8 {
                        quant: Arc::new(q),
                        rescore_dir: man.rescore_dir.as_ref().map(PathBuf::from),
                        indexed: man.index.as_deref() == Some(IVF_INDEX_NAME),
                    }
                }
            }
        } else if dir.join(QUANT_CODES_FILE).exists() {
            // A bare quantized shard directory (no manifest): int8 fabric
            // with no recorded companion.
            let q = QuantShardedStore::open(&dir).map_err(|e| store_open_err(&dir, e))?;
            Fabric::Int8 { quant: Arc::new(q), rescore_dir: None, indexed: false }
        } else {
            let s = ShardedStore::open(&dir).map_err(|e| store_open_err(&dir, e))?;
            Fabric::F32(Arc::new(s))
        };
        Ok(ValuatorBuilder {
            dir,
            fabric,
            backend: Backend::Auto,
            pool: PoolMode::Off,
            norm: Normalization::None,
            workers: 0,
            chunk_len: 0,
            precond: PrecondSource::Missing,
            metrics: None,
            rescore_override: None,
            generation,
            quarantined,
        })
    }

    fn primary_engine(&self) -> &dyn ScanBackend {
        self.engines[self.primary].as_ref()
    }

    /// Manifest generation this valuator's snapshot was opened at (0 for
    /// bare directories and manifests that predate the field). A serving
    /// process reports this per response: every query is answered by
    /// exactly one generation's fabric.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Shards a [`Valuator::open_degraded`] open excluded from the
    /// fabric (empty after a strict [`Valuator::open`]).
    pub fn quarantined(&self) -> &[QuarantinedShard] {
        &self.quarantined
    }

    /// IVF-indexed shards currently serving through the per-shard
    /// full-scan fallback (0 when the fabric has no index).
    pub fn ivf_fallback_shards(&self) -> usize {
        self.ivf_fallback
    }

    /// The engine a per-request [`BackendChoice`] routes to. `None` /
    /// `Auto` serve on the primary; a choice this fabric cannot serve is
    /// an [`ValuationError::InvalidConfig`] — the admission-time twin of
    /// the builder's backend/codec validation.
    fn engine_for(
        &self,
        choice: Option<BackendChoice>,
    ) -> Result<&dyn ScanBackend, ValuationError> {
        let want = match choice {
            None | Some(BackendChoice::Auto) => return Ok(self.primary_engine()),
            Some(BackendChoice::Exact) => {
                // Index 0 is the exact engine by construction.
                return Ok(self.engines[0].as_ref());
            }
            Some(BackendChoice::Quantized) => BackendKind::TwoStage,
            Some(BackendChoice::Ann { .. }) => BackendKind::Ivf,
        };
        self.engines
            .iter()
            .map(|e| e.as_ref())
            .find(|e| e.kind() == want)
            .ok_or_else(|| {
                let (name, hint) = match want {
                    BackendKind::Ivf => (
                        "ann",
                        "the store has no IVF index — `logra store quantize` it, \
                         then `logra store index`",
                    ),
                    _ => (
                        "quantized",
                        "the store uses the f32 codec — `logra store quantize` it, \
                         then open the quantized copy",
                    ),
                };
                ValuationError::InvalidConfig(format!(
                    "this valuator cannot serve backend \"{name}\": {hint}"
                ))
            })
    }

    /// The [`BackendKind`] a request carrying `choice` would be served by
    /// (what the serve layer reports as the actually-serving backend), or
    /// the same [`ValuationError::InvalidConfig`] admission would raise.
    pub fn resolved_kind(
        &self,
        choice: Option<BackendChoice>,
    ) -> Result<BackendKind, ValuationError> {
        self.engine_for(choice).map(|e| e.kind())
    }

    /// Submit + wait (blocking).
    pub fn query(&self, req: QueryRequest) -> Result<Vec<QueryResult>, ValuationError> {
        self.engine_for(req.backend)?.query(req)
    }

    /// Submit + wait, returning the per-query [`QueryReport`] stage
    /// breakdown alongside the scores (`Some` exactly when the valuator
    /// was built with [`ValuatorBuilder::metrics`]).
    pub fn query_with_report(
        &self,
        req: QueryRequest,
    ) -> Result<(Vec<QueryResult>, Option<QueryReport>), ValuationError> {
        self.engine_for(req.backend)?.query_with_report(req)
    }

    /// Admit a query without blocking on the scan.
    pub fn query_async(&self, req: QueryRequest) -> Result<PendingScores, ValuationError> {
        self.engine_for(req.backend)?.submit(req)
    }

    /// Admit a batch of requests, then complete them in admission order.
    /// On a pool-backed backend the requests' shard tasks interleave on
    /// warm workers. The batch succeeds or fails as a unit: the first
    /// error (a bad request at admission, or one poisoned query at
    /// completion) aborts it. Callers who need per-request error
    /// isolation should hold one [`query_async`](Self::query_async)
    /// handle per request instead.
    pub fn query_batch(
        &self,
        reqs: Vec<QueryRequest>,
    ) -> Result<Vec<Vec<QueryResult>>, ValuationError> {
        let pending: Vec<PendingScores> = reqs
            .into_iter()
            .map(|r| self.query_async(r))
            .collect::<Result<_, _>>()?;
        pending.into_iter().map(PendingScores::wait).collect()
    }

    /// The scan pool this valuator runs on, if any (snapshot it for queue
    /// depth and per-worker busy time).
    pub fn scan_pool(&self) -> Option<&Arc<ScanPool>> {
        self.pool.as_ref()
    }

    /// Stop the scan pool this valuator spawned (drains in-flight scans
    /// first); dropping the valuator does the same via the pool's own
    /// `Drop`. A [`PoolMode::Shared`] pool is the caller's — it keeps
    /// serving its other attachees and is left untouched.
    pub fn shutdown(self) {
        if self.owns_pool {
            if let Some(p) = &self.pool {
                p.shutdown();
            }
        }
    }
}

/// The facade is itself a [`ScanBackend`]: anything serving through a
/// `Box<dyn ScanBackend>` can hold a whole `Valuator` in that slot.
/// Introspection reports the primary engine; `submit` honors per-request
/// [`BackendChoice`] routing like the inherent query methods do.
impl ScanBackend for Valuator {
    fn submit(&self, req: QueryRequest) -> Result<PendingScores, ValuationError> {
        self.engine_for(req.backend)?.submit(req)
    }

    fn kind(&self) -> BackendKind {
        self.primary_engine().kind()
    }

    fn rows(&self) -> usize {
        self.primary_engine().rows()
    }

    fn k(&self) -> usize {
        self.primary_engine().k()
    }

    fn workers(&self) -> usize {
        self.primary_engine().workers()
    }

    fn exact(&self) -> bool {
        self.primary_engine().exact()
    }

    fn gradient_row(&self, i: usize) -> Option<Vec<f32>> {
        self.primary_engine().gradient_row(i)
    }
}
